"""End-to-end driver: train the paper's ABPN model on synthetic SR pairs.

A few hundred steps on CPU; PSNR vs the nearest-neighbour anchor baseline
is printed every 25 steps.  (--steps 300 default; the paper's model is
43K params, so this trains in minutes.)

    PYTHONPATH=src python examples/train_abpn.py --steps 300
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import sr_pair_batch
from repro.models.abpn import ABPNConfig, apply_abpn, init_abpn, make_anchor, depth_to_space


def psnr(a, b):
    mse = float(jnp.mean((a - b) ** 2))
    return 10 * np.log10(1.0 / max(mse, 1e-12))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--size", type=int, default=24)
    args = ap.parse_args()

    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(0), cfg)

    def loss_fn(layers, lr_b, hr_b):
        out = jax.vmap(lambda im: apply_abpn(layers, im, cfg))(lr_b)
        return jnp.mean(jnp.abs(out - hr_b))

    @jax.jit
    def step(layers, lr_b, hr_b):
        l, g = jax.value_and_grad(loss_fn)(layers, lr_b, hr_b)
        return jax.tree_util.tree_map(lambda p, gg: p - args.lr * gg, layers, g), l

    val_lr, val_hr = sr_pair_batch(10_000, 8, lr_shape=(args.size, args.size))
    anchor_up = jax.vmap(lambda im: depth_to_space(make_anchor(im, 3), 3))(val_lr)
    print(f"anchor (nearest-neighbour) baseline PSNR: {psnr(anchor_up, val_hr):.2f} dB")

    t0 = time.time()
    for i in range(args.steps):
        lr_b, hr_b = sr_pair_batch(i, args.batch, lr_shape=(args.size, args.size))
        layers, l = step(layers, lr_b, hr_b)
        if i % 25 == 0 or i == args.steps - 1:
            out = jax.vmap(lambda im: apply_abpn(layers, im, cfg))(val_lr)
            print(f"step {i:4d}  loss {float(l):.4f}  val PSNR {psnr(out, val_hr):.2f} dB"
                  f"  ({(time.time()-t0)/(i+1):.2f}s/step)")
    print("done — the model beats its anchor whenever PSNR exceeds the baseline")


if __name__ == "__main__":
    main()
