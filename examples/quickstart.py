"""Quickstart: tilted layer fusion in three executors.

Runs the paper's ABPN x3 super-resolution model over a synthetic image via
(1) the plain layer-by-layer reference, (2) the pure-JAX tilted fusion
scan, and (3) the Pallas TPU kernel (interpret mode on CPU), then prints
the equivalence deltas and the modeled buffer/bandwidth numbers that the
paper's Tables I/II report.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core.analysis import buffer_sizes, dram_reduction, pe_throughput_model
from repro.data.synthetic import sr_pair_batch
from repro.models.abpn import ABPNConfig, init_abpn


def main():
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(0), cfg)
    lr, _ = sr_pair_batch(0, 1, lr_shape=(120, 64), scale=3)
    print(f"LR {lr.shape[1:]} -> HR x{cfg.scale}")

    # One plan per backend; each runs the (here: single-frame) batch in one
    # jitted engine call.
    def plan(backend, policy="zero"):
        return engine.make_plan(layers, lr.shape[1:], backend=backend,
                                vertical_policy=policy, scale=cfg.scale)

    ref = engine.run(plan("reference"), layers, lr)[0]
    tilted = engine.run(plan("tilted", "halo"), layers, lr)[0]
    kernel = engine.run(plan("kernel"), layers, lr)[0]
    print(f"reference vs tilted(halo): max|d| = "
          f"{np.abs(np.asarray(ref) - np.asarray(tilted)).max():.2e}  (exact)")
    print(f"reference vs Pallas kernel: max|d| = "
          f"{np.abs(np.asarray(ref) - np.asarray(kernel)).max():.2e}  "
          f"(band-boundary rows only)")

    # Shape/batch-agnostic serving: the same weights behind an SRSession —
    # any request shape, plans derived + compiled on demand into the cache.
    session = engine.SRSession.open("abpn_x3", layers=layers, backend="tilted")
    session.upscale(lr)            # (T, H, W, C) clip
    session.upscale(lr[0, :60])    # a single half-height frame, new plan
    c = session.cache_stats()
    print(f"SRSession: {c['misses']} compiles, {c['hits']} hits for "
          f"{[tuple(e['lr_shape'][:2]) for e in c['entries']]}")

    b = buffer_sizes()
    print(f"\non-chip buffers: {b['total_kb']:.2f} KB (paper: 102.36 KB)")
    print(f"DRAM bandwidth reduction: {dram_reduction()*100:.1f}% (paper: 92%)")
    pe = pe_throughput_model()
    print(f"throughput model: {pe['mpix_s_at_target']:.1f} Mpix/s @ "
          f"{pe['utilization']*100:.0f}% MAC utilisation (paper: 124.4 @ 87%)")


if __name__ == "__main__":
    main()
