"""Batched LM serving: prefill + decode with KV caches.

Thin wrapper over repro.launch.serve showing the serving API on a reduced
config of any assigned architecture:

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["--arch", "qwen2-0.5b", "--batch", "4",
                                   "--prompt-len", "32", "--gen", "16"]))
