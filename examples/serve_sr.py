"""Serve super-resolution through the batched engine (``repro.engine``).

Builds one ``SRPlan`` (geometry + numerics + backend), compiles it once,
then streams batched LR frames through a ``VideoStream`` — the paper's use
case (real-time video SR) as a service: one jitted call per batch, latency
tracked per request.

    PYTHONPATH=src python examples/serve_sr.py --frames 16 --batch 4
    PYTHONPATH=src python examples/serve_sr.py --backend tilted --precision bf16
"""

import argparse

import jax

from repro.data.synthetic import sr_pair_batch
from repro.engine import VideoStream, make_plan
from repro.models.abpn import ABPNConfig, init_abpn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=8, help="total frames to serve")
    ap.add_argument("--batch", type=int, default=4, help="frames per engine call")
    ap.add_argument("--height", type=int, default=120)  # paper: 360
    ap.add_argument("--width", type=int, default=64)    # paper: 640
    ap.add_argument("--band-rows", type=int, default=60)
    ap.add_argument("--backend", default="kernel",
                    choices=["reference", "tilted", "kernel"])
    ap.add_argument("--precision", default="int8",
                    choices=["fp32", "bf16", "int8"],
                    help="int8 = the accelerator's weight storage numerics")
    ap.add_argument("--policy", default="zero",
                    choices=["zero", "halo", "replicate"],
                    help="vertical band boundary policy (all backends)")
    args = ap.parse_args()

    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(0), cfg)
    plan = make_plan(
        layers,
        (args.height, args.width, cfg.in_channels),
        band_rows=args.band_rows,
        backend=args.backend,
        vertical_policy=args.policy,
        precision=args.precision,
        scale=cfg.scale,
    )

    stream = VideoStream(plan, layers, batch_size=args.batch)
    compile_s = stream.warmup()

    lr_frames, _ = sr_pair_batch(
        0, args.frames, lr_shape=(args.height, args.width), scale=cfg.scale
    )
    hr = stream.run(lr_frames)
    s = stream.stats()

    print(f"plan: {plan.backend}/{plan.precision}, {plan.num_bands} bands x "
          f"{plan.schedule.num_tiles} tiles, compile {compile_s:.2f}s")
    print(f"served {s['frames']} frames {args.height}x{args.width} -> "
          f"{hr.shape[1]}x{hr.shape[2]} in batches of {args.batch}")
    print(f"throughput {s['fps']:.1f} frames/s  latency p50 {s['p50_ms']:.1f} ms  "
          f"p95 {s['p95_ms']:.1f} ms ({jax.default_backend()} backend)")
    pix = args.height * args.width * cfg.scale ** 2
    print(f"modeled accelerator: {pix/1e6:.2f} Mpix/frame at 124.4 Mpix/s -> "
          f"{pix/124.4e6*1e3:.2f} ms/frame @600 MHz")


if __name__ == "__main__":
    main()
