"""Serve super-resolution through the SRSession API (``repro.engine``).

One session = one model + serving policy; every request shape is handled
internally: the session derives the band geometry per resolution, buckets
batch sizes to powers of two, and compiles executors on demand into an
LRU plan cache.  This demo streams batched requests at the main
resolution, then a second resolution through the SAME session, and prints
the compile-cache counters alongside the latency stats.

    PYTHONPATH=src python examples/serve_sr.py --frames 16 --batch 4
    PYTHONPATH=src python examples/serve_sr.py --backend tilted --precision bf16
"""

import argparse

import jax

from repro.data.synthetic import sr_pair_batch
from repro.engine import SRSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="abpn_x3",
                    help="registered SR model (weights via models.registry)")
    ap.add_argument("--frames", type=int, default=8, help="total frames to serve")
    ap.add_argument("--batch", type=int, default=4, help="frames per request")
    ap.add_argument("--height", type=int, default=120)  # paper: 360
    ap.add_argument("--width", type=int, default=64)    # paper: 640
    ap.add_argument("--backend", default="kernel",
                    choices=["reference", "tilted", "kernel"])
    ap.add_argument("--precision", default="int8",
                    choices=["fp32", "bf16", "int8"],
                    help="int8 = the accelerator's weight storage numerics")
    ap.add_argument("--policy", default="zero",
                    choices=["zero", "halo", "replicate"],
                    help="vertical band boundary policy (all backends)")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="chunks in flight per request (1 = blocking, "
                         "2 = double-buffered dispatch)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    session = SRSession.open(
        args.model,
        backend=args.backend,
        precision=args.precision,
        vertical_policy=args.policy,
        pipeline_depth=args.pipeline_depth,
        seed=args.seed,
    )

    # Stream the clip as batched requests; the first request per
    # (resolution, bucket) compiles — on a dummy, outside the latency stats.
    if args.frames > 0:
        lr_frames, _ = sr_pair_batch(
            0, args.frames, lr_shape=(args.height, args.width),
            scale=session.scale
        )
        for i in range(0, args.frames, args.batch):
            session.upscale(lr_frames[i : i + args.batch])

    s = session.stats()  # main-resolution stats only (snapshot before lr2)

    # Same session, different resolution: no new object graph, just a new
    # plan-cache entry (shape-agnostic serving is the point of the API).
    h2, w2 = args.height // 2, args.width
    if h2 > 0:
        lr2, _ = sr_pair_batch(1, 2, lr_shape=(h2, w2), scale=session.scale)
        session.upscale(lr2)

    plan = session.plan_for((args.height, args.width, session.layers[0].ci))
    c = session.cache_stats()
    print(f"session: {session.model} {plan.backend}/{plan.precision}, "
          f"{plan.num_bands} bands x {plan.schedule.num_tiles} tiles")
    print(f"served {s['frames']} frames over {s['batches']} requests "
          f"({args.height}x{args.width} -> {plan.hr_shape[0]}x{plan.hr_shape[1]}, "
          f"plus a {h2}x{w2} request)")
    print(f"throughput {s['fps']:.1f} frames/s  complete p50 {s['p50_ms']:.1f} ms  "
          f"p99 {s['p99_ms']:.1f} ms  dispatch p50 {s['dispatch_p50_ms']:.2f} ms  "
          f"(depth {args.pipeline_depth}, peak in-flight {s['peak_inflight']}, "
          f"{jax.default_backend()} backend)")
    print(f"plan cache: {c['misses']} compiles, {c['hits']} hits, "
          f"hit rate {c['hit_rate']:.2f}; buckets "
          f"{[(tuple(e['lr_shape'][:2]), e['bucket'], round(e['compile_s'], 2)) for e in c['entries']]}")
    pix = args.height * args.width * session.scale ** 2
    print(f"modeled accelerator: {pix/1e6:.2f} Mpix/frame at 124.4 Mpix/s -> "
          f"{pix/124.4e6*1e3:.2f} ms/frame @600 MHz")


if __name__ == "__main__":
    main()
