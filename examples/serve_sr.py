"""Serve super-resolution through the SRServer front door (``repro.engine``).

One server = one or more models behind a micro-batching scheduler: callers
``submit(frames)`` and get an ``SRFuture`` back; concurrent requests that
share a ``(model, plan, dtype)`` key are coalesced into single bucket-sized
dispatches (real frames fill the power-of-two buckets instead of padding),
and ``server.stream(...)`` serves frame-at-a-time live video.  This demo:

1. submits a burst of concurrent small requests and resolves them together
   (the scheduler packs the burst into full buckets),
2. streams single frames through the async generator,
3. sends a second resolution through the SAME server (a new plan-cache
   entry, no new object graph),

then prints the coalescing counters next to the serving latency stats.

When more than one device is visible the server runs MESH-SHARDED: frame
rows are band-sharded over a ``bands`` device axis (halo exchange at shard
edges keeps outputs bit-exact) and dispatches are routed across replicas.
``--mesh auto`` (the default) picks the largest topology every demo
resolution can shard across; on a single device it falls back to ordinary
serving.

``--delta`` demos TEMPORAL DELTA SERVING instead: a synthetic
static-camera clip (identical frames after the first, then a few frames
with one moving patch) streams through ``server.stream(delta=True)`` —
only changed bands (dilated by the halo reach) are dispatched, clean
bands splice from the output cache bit-exact, and the reuse counters
print at the end.

    PYTHONPATH=src python examples/serve_sr.py --frames 16 --batch 4
    PYTHONPATH=src python examples/serve_sr.py --backend tilted --precision bf16
    PYTHONPATH=src python examples/serve_sr.py --delta --frames 8
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/serve_sr.py --mesh auto
"""

import argparse
import asyncio

import jax
import numpy as np

from repro.data.synthetic import sr_pair_batch
from repro.engine import SRServer
from repro.engine.plan import shardable_band_rows


async def stream_clip(server, clip):
    outs = []
    async for hr in server.stream(list(clip), lookahead=4):
        outs.append(hr)
    return outs


async def stream_delta(server, clip):
    outs = []
    async for hr in server.stream(list(clip), delta=True):
        outs.append(hr)
    return outs


def run_delta_demo(server, session, args):
    """Static-camera clip through the delta path; prints reuse counters."""
    base, _ = sr_pair_batch(
        args.seed, 1, lr_shape=(args.height, args.width), scale=session.scale
    )
    base = np.asarray(base[0])
    clip = [base.copy() for _ in range(max(2, args.frames))]
    # a small "moving object" crosses one band in the last two frames —
    # everything else is a static camera
    patch = args.height // 6
    clip[-2][:patch, :patch] += 0.25
    clip[-1][patch : 2 * patch, :patch] += 0.25
    outs = asyncio.run(stream_delta(server, clip))
    ref = np.asarray(session.upscale(np.stack(clip)))
    exact = all(np.array_equal(o, r) for o, r in zip(outs, ref))
    t = session.temporal_stats()
    cache = t["cache"]
    print(f"delta serving: {t['frames']} frames, "
          f"{t['bands_skipped']}/{t['bands_total']} bands spliced from "
          f"cache (reuse {t['reuse_ratio']:.2f}), "
          f"{t['band_rows_served']}/{t['band_rows_total']} band-rows computed")
    print(f"output cache: {cache['hits']} hits / {cache['misses']} misses, "
          f"{cache['bytes_saved'] / 1e6:.2f} MB recompute avoided, "
          f"{cache['entries']} entries ({cache['bytes'] / 1e6:.2f} MB), "
          f"{cache['evictions']} evictions")
    print(f"effective HBM traffic {t['effective_hbm_bytes_per_frame'] / 1e6:.2f} "
          f"MB/frame vs {t['full_hbm_bytes_per_frame'] / 1e6:.2f} MB/frame full "
          f"re-upscale; splice bit-exact vs full: {exact}")


def pick_mesh(heights, devices):
    """The largest (replicas, band_shards) serving mesh that fits the
    visible devices AND can band-shard every resolution the demo serves;
    None when only single-device serving is possible."""
    for shards in range(min(devices, 8), 1, -1):
        if all(shardable_band_rows(h, shards) is not None for h in heights):
            return (max(1, devices // shards), shards)
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="abpn_x3",
                    help="registered SR model (weights via models.registry)")
    ap.add_argument("--frames", type=int, default=8, help="total frames to serve")
    ap.add_argument("--batch", type=int, default=4,
                    help="frames per submitted request")
    ap.add_argument("--height", type=int, default=120)  # paper: 360
    ap.add_argument("--width", type=int, default=64)    # paper: 640
    ap.add_argument("--backend", default="kernel",
                    choices=["reference", "tilted", "kernel"])
    ap.add_argument("--precision", default="int8",
                    choices=["fp32", "bf16", "int8"],
                    help="int8 = the accelerator's weight storage numerics")
    ap.add_argument("--policy", default="zero",
                    choices=["zero", "halo", "replicate"],
                    help="vertical band boundary policy (all backends)")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="dispatches in flight per session (1 = blocking, "
                         "2 = double-buffered)")
    ap.add_argument("--max-inflight", type=int, default=None,
                    help="queue bound in frames (backpressure); default unbounded")
    ap.add_argument("--mesh", default="auto",
                    help='serving mesh "RxS" (replicas x band shards), '
                         '"auto" to derive one from the visible devices, '
                         '"off" to force single-device serving')
    ap.add_argument("--route", default="least_loaded",
                    choices=["round_robin", "least_loaded"],
                    help="replica routing policy (multi-replica meshes)")
    ap.add_argument("--delta", action="store_true",
                    help="demo temporal delta serving on a synthetic "
                         "static-camera clip (reuse counters, bit-exact "
                         "splice)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    devices = jax.device_count()
    if args.mesh == "auto":
        heights = (args.height, args.height // 2)
        mesh = pick_mesh(heights, devices)
        # say what auto decided and WHY — a silent fallback reads as the
        # sharded path running when it is not
        if mesh is None:
            print(f"auto mesh: no topology can band-shard heights {heights} "
                  f"across the {devices} visible device(s) -> falling back "
                  "to single-device serving")
        else:
            print(f"auto mesh: picked {mesh[0]}x{mesh[1]} (replicas x band "
                  f"shards) from the {devices} visible device(s)")
    elif args.mesh == "off":
        mesh = None
    else:
        r, s = (int(x) for x in args.mesh.split("x"))
        mesh = (r, s)
    if mesh is not None and mesh[0] * mesh[1] <= 1:
        mesh = None
    if mesh is None:
        print(f"single-device serving ({devices} device(s) visible; set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 to demo "
              "the sharded path)")
    else:
        print(f"mesh serving: {mesh[0]} replica(s) x {mesh[1]} band "
              f"shard(s) over {mesh[0] * mesh[1]} of {devices} visible "
              f"device(s), route={args.route}")
    mesh_kw = {} if mesh is None else {"mesh": mesh, "route": args.route}

    server = SRServer.open(
        args.model,
        backend=args.backend,
        precision=args.precision,
        vertical_policy=args.policy,
        pipeline_depth=args.pipeline_depth,
        max_inflight_frames=args.max_inflight,
        seed=args.seed,
        **mesh_kw,
    )
    session = server.session()

    if args.delta:
        run_delta_demo(server, session, args)
        return

    # 1) A burst of concurrent requests: submit them ALL, then resolve —
    # the first request per (resolution, bucket) compiles on a dummy,
    # outside the latency stats; the scheduler coalesces the queued burst
    # into shared bucket-sized dispatches.
    if args.frames > 0:
        lr_frames, _ = sr_pair_batch(
            0, args.frames, lr_shape=(args.height, args.width),
            scale=session.scale
        )
        futures = [
            server.submit(lr_frames[i : i + args.batch])
            for i in range(0, args.frames, args.batch)
        ]
        for f in futures:
            f.result()

    # 2) Frame-at-a-time live video through the async generator (the
    # lookahead keeps the coalescer's queue full even for one stream).
    stream_frames, _ = sr_pair_batch(
        3, 4, lr_shape=(args.height, args.width), scale=session.scale
    )
    asyncio.run(stream_clip(server, stream_frames))

    s = session.stats()  # main-resolution stats (snapshot before lr2)

    # 3) Same server, different resolution: just a new plan-cache entry
    # (shape-agnostic serving is the point of the API).
    h2, w2 = args.height // 2, args.width
    if h2 > 0:
        lr2, _ = sr_pair_batch(1, 2, lr_shape=(h2, w2), scale=session.scale)
        server.submit(lr2).result()

    plan = session.plan_for((args.height, args.width, session.layers[0].ci))
    c = session.cache_stats()
    g = server.scheduler_stats()
    print(f"server: {server.models[0]} {plan.backend}/{plan.precision}, "
          f"{plan.num_bands} bands x {plan.schedule.num_tiles} tiles")
    print(f"served {s['frames']} frames over {s['batches']} dispatches "
          f"({args.height}x{args.width} -> {plan.hr_shape[0]}x{plan.hr_shape[1]}, "
          f"plus a {h2}x{w2} request)")
    print(f"throughput {s['fps']:.1f} frames/s  complete p50 {s['p50_ms']:.1f} ms  "
          f"p99 {s['p99_ms']:.1f} ms  dispatch p50 {s['dispatch_p50_ms']:.2f} ms  "
          f"(depth {args.pipeline_depth}, peak in-flight {s['peak_inflight']}, "
          f"{jax.default_backend()} backend)")
    print(f"scheduler: {g['submitted_requests']} requests -> "
          f"{g['dispatches']} dispatches ({g['coalesced_dispatches']} coalesced), "
          f"mean bucket fill {g['mean_fill_ratio']:.2f}, "
          f"{g['padded_frames']} padded frames, peak queue "
          f"{g['peak_pending_frames']} frames")
    print(f"plan cache: {c['misses']} compiles, {c['hits']} hits, "
          f"hit rate {c['hit_rate']:.2f}; buckets "
          f"{[(tuple(e['lr_shape'][:2]), e['bucket'], round(e['compile_s'], 2)) for e in c['entries']]}")
    sh = session.sharding_stats()
    if sh is not None:
        print(f"sharding: mesh {sh['mesh']} ({sh['policy']}), replica fill "
              f"{sh['replica_fill']:.2f}, halo "
              f"{sh['halo_bytes_per_frame'] / 1e3:.1f} kB/frame, "
              f"dispatches per replica "
              f"{[r['dispatches'] for r in sh['replicas']]}")
    pix = args.height * args.width * session.scale ** 2
    print(f"modeled accelerator: {pix/1e6:.2f} Mpix/frame at 124.4 Mpix/s -> "
          f"{pix/124.4e6*1e3:.2f} ms/frame @600 MHz")


if __name__ == "__main__":
    main()
