"""Serve super-resolution through the SRServer front door (``repro.engine``).

One server = one or more models behind a micro-batching scheduler: callers
``submit(frames)`` and get an ``SRFuture`` back; concurrent requests that
share a ``(model, plan, dtype)`` key are coalesced into single bucket-sized
dispatches (real frames fill the power-of-two buckets instead of padding),
and ``server.stream(...)`` serves frame-at-a-time live video.  This demo:

1. submits a burst of concurrent small requests and resolves them together
   (the scheduler packs the burst into full buckets),
2. streams single frames through the async generator,
3. sends a second resolution through the SAME server (a new plan-cache
   entry, no new object graph),

then prints the coalescing counters next to the serving latency stats.

    PYTHONPATH=src python examples/serve_sr.py --frames 16 --batch 4
    PYTHONPATH=src python examples/serve_sr.py --backend tilted --precision bf16
"""

import argparse
import asyncio

import jax

from repro.data.synthetic import sr_pair_batch
from repro.engine import SRServer


async def stream_clip(server, clip):
    outs = []
    async for hr in server.stream(list(clip), lookahead=4):
        outs.append(hr)
    return outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="abpn_x3",
                    help="registered SR model (weights via models.registry)")
    ap.add_argument("--frames", type=int, default=8, help="total frames to serve")
    ap.add_argument("--batch", type=int, default=4,
                    help="frames per submitted request")
    ap.add_argument("--height", type=int, default=120)  # paper: 360
    ap.add_argument("--width", type=int, default=64)    # paper: 640
    ap.add_argument("--backend", default="kernel",
                    choices=["reference", "tilted", "kernel"])
    ap.add_argument("--precision", default="int8",
                    choices=["fp32", "bf16", "int8"],
                    help="int8 = the accelerator's weight storage numerics")
    ap.add_argument("--policy", default="zero",
                    choices=["zero", "halo", "replicate"],
                    help="vertical band boundary policy (all backends)")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="dispatches in flight per session (1 = blocking, "
                         "2 = double-buffered)")
    ap.add_argument("--max-inflight", type=int, default=None,
                    help="queue bound in frames (backpressure); default unbounded")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    server = SRServer.open(
        args.model,
        backend=args.backend,
        precision=args.precision,
        vertical_policy=args.policy,
        pipeline_depth=args.pipeline_depth,
        max_inflight_frames=args.max_inflight,
        seed=args.seed,
    )
    session = server.session()

    # 1) A burst of concurrent requests: submit them ALL, then resolve —
    # the first request per (resolution, bucket) compiles on a dummy,
    # outside the latency stats; the scheduler coalesces the queued burst
    # into shared bucket-sized dispatches.
    if args.frames > 0:
        lr_frames, _ = sr_pair_batch(
            0, args.frames, lr_shape=(args.height, args.width),
            scale=session.scale
        )
        futures = [
            server.submit(lr_frames[i : i + args.batch])
            for i in range(0, args.frames, args.batch)
        ]
        for f in futures:
            f.result()

    # 2) Frame-at-a-time live video through the async generator (the
    # lookahead keeps the coalescer's queue full even for one stream).
    stream_frames, _ = sr_pair_batch(
        3, 4, lr_shape=(args.height, args.width), scale=session.scale
    )
    asyncio.run(stream_clip(server, stream_frames))

    s = session.stats()  # main-resolution stats (snapshot before lr2)

    # 3) Same server, different resolution: just a new plan-cache entry
    # (shape-agnostic serving is the point of the API).
    h2, w2 = args.height // 2, args.width
    if h2 > 0:
        lr2, _ = sr_pair_batch(1, 2, lr_shape=(h2, w2), scale=session.scale)
        server.submit(lr2).result()

    plan = session.plan_for((args.height, args.width, session.layers[0].ci))
    c = session.cache_stats()
    g = server.scheduler_stats()
    print(f"server: {server.models[0]} {plan.backend}/{plan.precision}, "
          f"{plan.num_bands} bands x {plan.schedule.num_tiles} tiles")
    print(f"served {s['frames']} frames over {s['batches']} dispatches "
          f"({args.height}x{args.width} -> {plan.hr_shape[0]}x{plan.hr_shape[1]}, "
          f"plus a {h2}x{w2} request)")
    print(f"throughput {s['fps']:.1f} frames/s  complete p50 {s['p50_ms']:.1f} ms  "
          f"p99 {s['p99_ms']:.1f} ms  dispatch p50 {s['dispatch_p50_ms']:.2f} ms  "
          f"(depth {args.pipeline_depth}, peak in-flight {s['peak_inflight']}, "
          f"{jax.default_backend()} backend)")
    print(f"scheduler: {g['submitted_requests']} requests -> "
          f"{g['dispatches']} dispatches ({g['coalesced_dispatches']} coalesced), "
          f"mean bucket fill {g['mean_fill_ratio']:.2f}, "
          f"{g['padded_frames']} padded frames, peak queue "
          f"{g['peak_pending_frames']} frames")
    print(f"plan cache: {c['misses']} compiles, {c['hits']} hits, "
          f"hit rate {c['hit_rate']:.2f}; buckets "
          f"{[(tuple(e['lr_shape'][:2]), e['bucket'], round(e['compile_s'], 2)) for e in c['entries']]}")
    pix = args.height * args.width * session.scale ** 2
    print(f"modeled accelerator: {pix/1e6:.2f} Mpix/frame at 124.4 Mpix/s -> "
          f"{pix/124.4e6*1e3:.2f} ms/frame @600 MHz")


if __name__ == "__main__":
    main()
