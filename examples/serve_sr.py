"""Serve super-resolution requests through the tilted-fusion pipeline.

Batched LR frames stream through the Pallas kernel path (the accelerator
datapath: int8-quantised weights, banded tilted fusion) with per-request
latency stats — the paper's use case (real-time video SR) as a service.

    PYTHONPATH=src python examples/serve_sr.py --requests 8
"""

import argparse
import time

import jax
import numpy as np

from repro.core.quant import dequantize_layers, quantize_layers
from repro.data.synthetic import sr_pair_batch
from repro.models.abpn import ABPNConfig, apply_abpn, init_abpn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--height", type=int, default=120)  # paper: 360
    ap.add_argument("--width", type=int, default=64)    # paper: 640
    args = ap.parse_args()

    cfg = ABPNConfig()
    # deployment numerics: int8 weights (what the accelerator stores)
    layers = dequantize_layers(quantize_layers(init_abpn(jax.random.PRNGKey(0), cfg)))

    infer = jax.jit(lambda im: apply_abpn(layers, im, cfg, method="kernel",
                                          band_rows=60, tile_cols=8))
    lr_frames, _ = sr_pair_batch(0, args.requests,
                                 lr_shape=(args.height, args.width), scale=3)
    infer(lr_frames[0]).block_until_ready()  # compile

    lat = []
    for i in range(args.requests):
        t0 = time.perf_counter()
        hr = infer(lr_frames[i])
        hr.block_until_ready()
        lat.append((time.perf_counter() - t0) * 1e3)
    lat = np.array(lat)
    pix = args.height * args.width * 9
    print(f"served {args.requests} frames {args.height}x{args.width} -> "
          f"{args.height*3}x{args.width*3}")
    print(f"latency p50 {np.percentile(lat,50):.1f} ms  p95 "
          f"{np.percentile(lat,95):.1f} ms (CPU interpret mode)")
    print(f"modeled accelerator: {pix/1e6:.2f} Mpix/frame at 124.4 Mpix/s -> "
          f"{pix/124.4e6*1e3:.2f} ms/frame @600 MHz")


if __name__ == "__main__":
    main()
