"""Unified model/run configuration for every assigned architecture.

One frozen dataclass covers all six families (dense / moe / ssm / hybrid /
encdec / vlm); family-specific blocks are optional fields.  Exact published
numbers live in ``repro/configs/<arch>.py``; reduced smoke-test variants are
derived with :meth:`ModelConfig.reduced`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "TrainConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # ---- identity ----
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    # ---- trunk ----
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    # ---- attention ----
    attention: str = "gqa"  # gqa | mla | none
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 128
    qk_norm: bool = False  # qwen3 family
    qkv_bias: bool = False  # qwen2 family
    rope_theta: float = 1e6
    attn_chunk: int = 1024  # flash-style KV chunk for long sequences
    # ---- MLA (deepseek-v2) ----
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 128
    # ---- MoE ----
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # expert hidden size (d_ff used for the dense path)
    num_shared_experts: int = 0  # deepseek: always-on experts
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    first_k_dense: int = 0  # deepseek: first k layers use dense MLP
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    # ---- SSM (mamba2 / zamba2) ----
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    # ---- hybrid (zamba2): shared attention block every N ssm layers ----
    shared_attn_period: int = 0
    num_shared_blocks: int = 0
    # ---- encoder-decoder (seamless) ----
    encoder_layers: int = 0
    # ---- multimodal stub frontend (vlm: patch embeds; audio: frame embeds) ----
    frontend_tokens: int = 0
    # ---- numerics / execution ----
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    scan_layers: bool = True
    remat: str = "none"  # none | dots | full
    fsdp: bool = False  # ZeRO-3 weight sharding over the data axis
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    mlp_act: str = "silu"  # silu (SwiGLU) | gelu | relu

    # ------------------------------------------------------------------
    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def uses_attention(self) -> bool:
        return self.attention != "none"

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    @property
    def supports_long_context(self) -> bool:
        """True for sub-quadratic archs (SSM/hybrid) — long_500k eligibility."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs generate tokens (no encoder-only)

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests.

        Shrinks depth/width/experts/vocab while preserving every structural
        feature (GQA ratios, qk_norm, MLA ranks, shared blocks, ...).
        """
        changes = dict(
            num_layers=min(self.num_layers, 4),
            d_model=min(self.d_model, 64),
            d_ff=min(self.d_ff, 128),
            vocab_size=min(self.vocab_size, 512),
            attn_chunk=64,
            ssm_chunk=32,
            dtype="float32",
            param_dtype="float32",
        )
        if self.uses_attention and self.num_heads:
            q_per_kv = max(1, self.num_heads // max(self.num_kv_heads, 1))
            changes["num_kv_heads"] = min(self.num_kv_heads, 2)
            changes["num_heads"] = changes["num_kv_heads"] * min(q_per_kv, 4)
            changes["head_dim"] = min(self.head_dim, 16)
        if self.attention == "mla":
            changes.update(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
                           v_head_dim=16, head_dim=16)
        if self.is_moe:
            changes.update(
                num_experts=min(self.num_experts, 8),
                experts_per_token=min(self.experts_per_token, 2),
                moe_d_ff=min(self.moe_d_ff or self.d_ff, 64),
            )
        if self.family in ("ssm", "hybrid"):
            changes.update(ssm_state=min(self.ssm_state, 16), ssm_headdim=16)
        if self.shared_attn_period:
            changes.update(shared_attn_period=2, num_layers=4, num_shared_blocks=2)
        if self.encoder_layers:
            changes["encoder_layers"] = min(self.encoder_layers, 2)
        if self.frontend_tokens:
            changes["frontend_tokens"] = 8
        changes.update(overrides)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer / schedule / runtime knobs for the training driver."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    optimizer_dtype: str = "float32"  # adam moment dtype (bf16 for ≥200B archs)
    microbatches: int = 1  # gradient accumulation
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    grad_compression: str = "none"  # none | int8_ef
    seed: int = 0
