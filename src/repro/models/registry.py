"""Model registry: family -> unified model API.

Every family module exposes:
  schema(cfg)                          parameter ParamSpec tree
  cache_schema(cfg, batch, max_len)    decode-cache ParamSpec tree
  loss(params, cfg, batch)             -> (scalar loss, metrics)
  prefill(params, cfg, batch, cache)   -> (last logits (B,V), cache)
  decode_step(params, cfg, tok, cache, pos) -> (logits (B,V), cache)
"""

from __future__ import annotations

import types

from repro.models import encdec, lm, mamba_lm, zamba

__all__ = ["get_model"]

_FAMILY = {
    "dense": lm,
    "moe": lm,
    "vlm": lm,
    "ssm": mamba_lm,
    "hybrid": zamba,
    "encdec": encdec,
}


def get_model(cfg) -> types.ModuleType:
    try:
        return _FAMILY[cfg.family]
    except KeyError:
        raise ValueError(
            f"unknown family {cfg.family!r}; expected one of {sorted(_FAMILY)}"
        ) from None
