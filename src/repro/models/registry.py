"""Model registry: LM families -> unified model API, SR models -> specs.

LM side — every family module exposes:
  schema(cfg)                          parameter ParamSpec tree
  cache_schema(cfg, batch, max_len)    decode-cache ParamSpec tree
  loss(params, cfg, batch)             -> (scalar loss, metrics)
  prefill(params, cfg, batch, cache)   -> (last logits (B,V), cache)
  decode_step(params, cfg, tok, cache, pos) -> (logits (B,V), cache)

SR side — a registered :class:`SRModelSpec` (canonical name, config, weight
initialiser) is how ``repro.engine.SRSession.open("abpn_x3")`` resolves a
model name into a servable conv stack without the caller touching plans or
weights.
"""

from __future__ import annotations

import dataclasses
import difflib
import functools
import types
from typing import Callable, Dict, Sequence, Tuple

from repro.models import encdec, lm, mamba_lm, zamba
from repro.models.abpn import ABPNConfig, init_abpn

__all__ = [
    "get_model",
    "get_sr_model",
    "list_sr_models",
    "register_sr_model",
    "SRModelSpec",
]

_FAMILY = {
    "dense": lm,
    "moe": lm,
    "vlm": lm,
    "ssm": mamba_lm,
    "hybrid": zamba,
    "encdec": encdec,
}


def get_model(cfg) -> types.ModuleType:
    try:
        return _FAMILY[cfg.family]
    except KeyError:
        raise ValueError(
            f"unknown family {cfg.family!r}; expected one of {sorted(_FAMILY)}"
        ) from None


# ----------------------------------------------------------------------
# SR models (served through repro.engine.SRSession)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SRModelSpec:
    """A servable SR model.

    ``config`` carries at least ``scale`` and ``clip`` (the session's
    epilogue defaults); ``init(key) -> Sequence[ConvLayer]`` produces the
    weight stack (a trained stack can be passed to ``SRSession.open``
    directly instead).
    """

    name: str
    config: ABPNConfig
    init: Callable[..., Sequence]


_SR_MODELS: Dict[str, SRModelSpec] = {}


def register_sr_model(
    name: str,
    config,
    init: Callable[..., Sequence],
    aliases: Tuple[str, ...] = (),
) -> SRModelSpec:
    """Register an SR model under ``name`` (plus aliases)."""
    spec = SRModelSpec(name=name, config=config, init=init)
    names = (name, *aliases)
    taken = [n for n in names if n in _SR_MODELS]
    if taken:  # reject up front — a failed call must not half-register
        raise ValueError(f"SR model name(s) already registered: {taken}")
    for n in names:
        _SR_MODELS[n] = spec
    return spec


def list_sr_models() -> Tuple[str, ...]:
    """Canonical names of every registered SR model (aliases excluded) —
    what ``SRServer.open`` / ``SRSession.open`` accept."""
    return tuple(sorted({s.name for s in _SR_MODELS.values()}))


def get_sr_model(name: str) -> SRModelSpec:
    try:
        return _SR_MODELS[name]
    except KeyError:
        # name every accepted spelling (canonical names AND aliases) and
        # suggest the closest one — a bare KeyError or a canonical-only
        # list leaves "abpn-3x" users guessing at "abpn-x3"
        known = sorted(_SR_MODELS)
        close = difflib.get_close_matches(str(name), known, n=1)
        hint = f" (did you mean {close[0]!r}?)" if close else ""
        raise ValueError(
            f"unknown SR model {name!r}{hint}; registered: "
            f"{list(list_sr_models())}, aliases included: {known}"
        ) from None


# The paper's model: ABPN x3 (same design point as configs/abpn_x3.py).
register_sr_model(
    "abpn_x3",
    ABPNConfig(),
    functools.partial(init_abpn, cfg=ABPNConfig()),
    aliases=("abpn-x3", "abpn"),
)
