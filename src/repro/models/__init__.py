"""Model zoo: the paper's ABPN plus the assigned LM-family architectures."""
