"""Encoder-decoder backbone (seamless-m4t-large-v2 assignment).

The modality frontend is a stub per the assignment: ``src`` arrives as
precomputed frame embeddings ``(B, S_enc, d_model)``.  The backbone is a
standard pre-norm transformer enc-dec: encoder self-attention is
bidirectional; the decoder stacks causal self-attention, cross-attention
over the encoder output, and the FFN.  RoPE replaces the original
sinusoidal/relative positions (adaptation recorded in DESIGN.md); cross
attention carries no positional rotation.

Decode caches: per decoder layer a causal self-KV cache plus the
cross-attention KV computed once at prefill from the encoder output.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import pshard
from repro.layers import attention as attn_lib
from repro.layers.attention import flash_attention
from repro.layers.common import cross_entropy, embed_lookup, rmsnorm
from repro.layers.mlp import mlp_block, mlp_schema
from repro.layers.params import ParamSpec, stack_schema
from repro.layers.rope import apply_rope

__all__ = ["schema", "cache_schema", "loss", "prefill", "decode_step"]


def _enc_block_schema(cfg) -> dict:
    d = cfg.d_model
    return {
        "ln1": ParamSpec((d,), ("norm",), init="ones"),
        "attn": attn_lib.gqa_schema(cfg),
        "ln2": ParamSpec((d,), ("norm",), init="ones"),
        "mlp": mlp_schema(cfg),
    }


def _dec_block_schema(cfg) -> dict:
    s = _enc_block_schema(cfg)
    s["ln_x"] = ParamSpec((cfg.d_model,), ("norm",), init="ones")
    s["xattn"] = attn_lib.gqa_schema(cfg)
    return s


def schema(cfg) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    return {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="embed", scale=0.02),
        "enc_blocks": stack_schema(_enc_block_schema(cfg), cfg.encoder_layers),
        "enc_norm": ParamSpec((d,), ("norm",), init="ones"),
        "dec_blocks": stack_schema(_dec_block_schema(cfg), cfg.num_layers),
        "final_norm": ParamSpec((d,), ("norm",), init="ones"),
        "lm_head": ParamSpec((d, v), ("embed", "vocab")),
    }


def cache_schema(cfg, batch: int, max_len: int, enc_len: int) -> dict:
    kv_shape, kv_dtype, kv_axes = attn_lib.init_kv_cache_spec(cfg, batch, max_len)
    self_kv = ParamSpec(kv_shape, kv_axes, init="zeros", dtype=str(kv_dtype))
    x_shape = (batch, enc_len, cfg.num_kv_heads, cfg.head_dim)
    cross_kv = ParamSpec(x_shape, kv_axes, init="zeros", dtype=str(kv_dtype))
    layer = {"k": self_kv, "v": self_kv, "xk": cross_kv, "xv": cross_kv}
    return {"layers": stack_schema(layer, cfg.num_layers)}


def _cross_kv(p, cfg, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


def _cross_attend(p, cfg, x, k, v):
    B, S, _ = x.shape
    h, kh = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q = q.reshape(B, S, kh, h // kh, cfg.head_dim)
    out = flash_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    out = out.reshape(B, S, h, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def encode(params, cfg, src: jax.Array) -> jax.Array:
    """src (B, S_enc, d) stub frame embeddings -> encoder output."""
    x = src.astype(cfg.activation_dtype)
    x = pshard(x, "batch", "act_seq", "embed")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, lp):
        h = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
        # bidirectional self-attention
        q, k, v = attn_lib._project_qkv(lp["attn"], cfg, h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kh = cfg.num_kv_heads
        q = q.reshape(B, S, kh, cfg.num_heads // kh, cfg.head_dim)
        out = flash_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
        out = out.reshape(B, S, cfg.num_heads, cfg.head_dim)
        a = jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"].astype(h.dtype))
        x2 = carry + a
        h2 = rmsnorm(x2, lp["ln2"], cfg.norm_eps)
        return x2 + mlp_block(lp["mlp"], cfg, h2), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _decoder(params, cfg, tokens, enc_out=None, cache=None, cache_pos=None,
             mode="train", last_logit_only=False):
    act = cfg.activation_dtype
    x = embed_lookup(params["embed"], tokens, act)
    x = pshard(x, "batch", "act_seq", "embed")
    B, S, _ = x.shape
    if mode == "decode":
        positions = jnp.full((B, 1), cache_pos, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, xs):
        lp, lc = xs if cache is not None else (xs, None)
        h = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
        a, kv = attn_lib.attention_block(
            lp["attn"], cfg, h, positions,
            cache=None if lc is None else (lc["k"], lc["v"]),
            cache_pos=cache_pos, mode=mode)
        x2 = carry + a
        h2 = rmsnorm(x2, lp["ln_x"], cfg.norm_eps)
        if mode == "decode":
            xk, xv = lc["xk"], lc["xv"]
        else:
            xk, xv = _cross_kv(lp["xattn"], cfg, enc_out)
        x2 = x2 + _cross_attend(lp["xattn"], cfg, h2, xk, xv)
        h3 = rmsnorm(x2, lp["ln2"], cfg.norm_eps)
        x2 = x2 + mlp_block(lp["mlp"], cfg, h3)
        nc = None
        if mode in ("prefill", "decode") and lc is not None:
            nc = {"k": kv[0], "v": kv[1],
                  "xk": xk.astype(lc["xk"].dtype), "xv": xv.astype(lc["xv"].dtype)}
        return x2, nc

    if cache is None:
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        new_cache = None
    else:
        x, ncs = jax.lax.scan(body, x, (params["dec_blocks"], cache["layers"]))
        new_cache = {"layers": ncs}

    if last_logit_only:
        x = x[:, -1:]  # §Perf: skip the unembedding over S-1 unused positions
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return pshard(logits, "batch", "seq", "vocab"), new_cache


def loss(params, cfg, batch):
    enc_out = encode(params, cfg, batch["src"])
    logits, _ = _decoder(params, cfg, batch["tokens"], enc_out, mode="train")
    l, metrics = cross_entropy(logits, batch["targets"], batch.get("mask"))
    metrics["total_loss"] = l
    return l, metrics


def prefill(params, cfg, batch, cache):
    enc_out = encode(params, cfg, batch["src"])
    logits, new_cache = _decoder(
        params, cfg, batch["tokens"], enc_out, cache=cache,
        cache_pos=jnp.int32(0), mode="prefill", last_logit_only=True,
    )
    return logits[:, -1, :], new_cache


def decode_step(params, cfg, tokens, cache, pos):
    logits, new_cache = _decoder(
        params, cfg, tokens, cache=cache, cache_pos=pos, mode="decode"
    )
    return logits[:, -1, :], new_cache
