"""Decoder-only LM covering the dense / MoE / MLA / VLM-prefix families.

One config-driven assembly:
  * attention: GQA (qwen2/3, arctic) or MLA (deepseek-v2)
  * FFN: SwiGLU MLP, MoE (+shared experts), or MoE + parallel dense
    residual (arctic); ``first_k_dense`` prologue layers (deepseek)
  * optional multimodal prefix: precomputed frontend embeddings (internvl2
    stub ViT) are concatenated ahead of the token embeddings
  * layers run under ``lax.scan`` (homogeneous stack -> constant-size HLO,
    constant compile time in depth) with a configurable remat policy

The same forward serves train, prefill (fills the KV cache, returns
last-position logits) and single-token decode.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import pshard
from repro.layers import attention as attn_lib
from repro.layers import mla as mla_lib
from repro.layers import moe as moe_lib
from repro.layers.common import cross_entropy, embed_lookup, rmsnorm
from repro.layers.mlp import mlp_block, mlp_schema
from repro.layers.params import ParamSpec, stack_schema

__all__ = ["schema", "cache_schema", "loss", "prefill", "decode_step", "forward"]


# ----------------------------------------------------------------------
# Schemas
# ----------------------------------------------------------------------
def _block_schema(cfg, moe: bool) -> dict:
    d = cfg.d_model
    s: Dict[str, Any] = {
        "ln1": ParamSpec((d,), ("norm",), init="ones"),
        "ln2": ParamSpec((d,), ("norm",), init="ones"),
    }
    s["attn"] = mla_lib.mla_schema(cfg) if cfg.attention == "mla" else attn_lib.gqa_schema(cfg)
    if moe:
        s["moe"] = moe_lib.moe_schema(cfg)
        if cfg.dense_residual:
            s["dense"] = mlp_schema(cfg)
    else:
        s["mlp"] = mlp_schema(cfg)
    return s


def _n_scan(cfg) -> int:
    return cfg.num_layers - cfg.first_k_dense


def schema(cfg) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    s: Dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="embed", scale=0.02),
        "blocks": stack_schema(_block_schema(cfg, moe=cfg.is_moe), _n_scan(cfg)),
        "final_norm": ParamSpec((d,), ("norm",), init="ones"),
    }
    for i in range(cfg.first_k_dense):
        s[f"prologue_{i}"] = _block_schema(cfg, moe=False)
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    return s


def cache_schema(cfg, batch: int, max_len: int) -> dict:
    """ParamSpec tree (init=zeros) describing the decode cache."""
    if cfg.attention == "mla":
        shape, dtype, axes = mla_lib.init_mla_cache_spec(cfg, batch, max_len)
        one = ParamSpec(shape, axes, init="zeros", dtype=str(dtype))
        layer = {"ckv": one}
    else:
        shape, dtype, axes = attn_lib.init_kv_cache_spec(cfg, batch, max_len)
        one = ParamSpec(shape, axes, init="zeros", dtype=str(dtype))
        layer = {"k": one, "v": one}
    s = {"layers": stack_schema(layer, _n_scan(cfg))}
    for i in range(cfg.first_k_dense):
        s[f"prologue_{i}"] = dict(layer)
    return s


# ----------------------------------------------------------------------
# Blocks
# ----------------------------------------------------------------------
def _apply_block(p, cfg, x, positions, cache, cache_pos, mode, moe: bool):
    """Pre-norm residual block. Returns (x, new_cache, metrics)."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.attention == "mla":
        a, new_cache = mla_lib.mla_block(
            p["attn"], cfg, h, positions,
            cache=None if cache is None else cache["ckv"],
            cache_pos=cache_pos, mode=mode)
        new_cache = None if new_cache is None else {"ckv": new_cache}
    else:
        a, kv = attn_lib.attention_block(
            p["attn"], cfg, h, positions,
            cache=None if cache is None else (cache["k"], cache["v"]),
            cache_pos=cache_pos, mode=mode)
        new_cache = None if kv is None else {"k": kv[0], "v": kv[1]}
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    metrics = {}
    if moe:
        f, metrics = moe_lib.moe_block(p["moe"], cfg, h)
        if cfg.dense_residual:
            f = f + mlp_block(p["dense"], cfg, h)
    else:
        f = mlp_block(p["mlp"], cfg, h)
    return x + f, new_cache, metrics


def _remat(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


# ----------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------
def forward(
    params,
    cfg,
    tokens: jax.Array,  # (B, S)
    *,
    frontend: Optional[jax.Array] = None,  # (B, F, d) precomputed embeds
    cache=None,
    cache_pos=None,
    mode: str = "train",
    last_logit_only: bool = False,
):
    """Returns (logits (B, S_total, V), new_cache, metrics)."""
    act = cfg.activation_dtype
    x = embed_lookup(params["embed"], tokens, act)
    if frontend is not None:
        x = jnp.concatenate([frontend.astype(act), x], axis=1)
    B, S, _ = x.shape
    x = pshard(x, "batch", "act_seq", "embed")
    if mode == "decode":
        positions = jnp.full((B, 1), cache_pos, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    new_cache: Dict[str, Any] = {}
    all_metrics = []
    for i in range(cfg.first_k_dense):
        c = None if cache is None else cache[f"prologue_{i}"]
        x, nc, m = _apply_block(
            params[f"prologue_{i}"], cfg, x, positions, c, cache_pos, mode, moe=False
        )
        if nc is not None:
            new_cache[f"prologue_{i}"] = nc
        all_metrics.append(m)

    block = functools.partial(_apply_block, cfg=cfg, mode=mode, moe=cfg.is_moe)

    def body(carry, xs):
        lp, lc = xs
        y, nc, m = _remat(
            lambda c, p, cch: block(p, x=c, positions=positions, cache=cch,
                                    cache_pos=cache_pos),
            cfg,
        )(carry, lp, lc)
        return y, (nc, m)

    layer_caches = None if cache is None else cache["layers"]
    if layer_caches is None:
        # supply a dummy xs tree so scan has uniform structure
        xs = (params["blocks"], None)
        def body_nc(carry, lp):
            y, nc, m = _remat(
                lambda c, p: block(p, x=c, positions=positions, cache=None,
                                   cache_pos=cache_pos),
                cfg,
            )(carry, lp)
            return y, m
        x, ms = jax.lax.scan(body_nc, x, params["blocks"])
        scan_metrics = ms
    else:
        x, (ncs, ms) = jax.lax.scan(body, x, (params["blocks"], layer_caches))
        new_cache["layers"] = ncs
        scan_metrics = ms

    if last_logit_only:
        # §Perf (prefill cells): the unembedding matmul + its vocab-sharded
        # collectives over all S positions is pure waste when only the last
        # position's logits are consumed — slice the hidden state first.
        x = x[:, -1:]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    logits = pshard(logits, "batch", "seq", "vocab")

    metrics = {}
    if cfg.is_moe and scan_metrics:
        metrics = {k: jnp.mean(v) for k, v in scan_metrics.items()}
    return logits, (new_cache if new_cache else None), metrics


# ----------------------------------------------------------------------
# Unified API
# ----------------------------------------------------------------------
def loss(params, cfg, batch):
    logits, _, metrics = forward(
        params, cfg, batch["tokens"], frontend=batch.get("frontend"), mode="train"
    )
    if batch.get("frontend") is not None:
        logits = logits[:, batch["frontend"].shape[1] :]
    l, ce_metrics = cross_entropy(logits, batch["targets"], batch.get("mask"))
    metrics.update(ce_metrics)
    if cfg.is_moe:
        l = (
            l
            + cfg.router_aux_weight * metrics["moe_aux_loss"]
            + cfg.router_z_weight * metrics["moe_z_loss"]
        )
    metrics["total_loss"] = l
    return l, metrics


def prefill(params, cfg, batch, cache):
    """Fill the cache; return (last-position logits (B, V), cache)."""
    logits, new_cache, _ = forward(
        params, cfg, batch["tokens"], frontend=batch.get("frontend"),
        cache=cache, cache_pos=jnp.int32(0), mode="prefill",
        last_logit_only=True,
    )
    return logits[:, -1, :], new_cache


def decode_step(params, cfg, tokens, cache, pos):
    """One decode step at position ``pos``; returns (logits (B, V), cache)."""
    logits, new_cache, _ = forward(
        params, cfg, tokens, cache=cache, cache_pos=pos, mode="decode"
    )
    return logits[:, -1, :], new_cache
