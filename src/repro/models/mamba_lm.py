"""Mamba2 language model (attention-free SSM; mamba2-130m).

Embedding -> scanned (norm + Mamba2 block) residual layers -> norm ->
tied logits.  Decode is O(1) per token: the cache is the conv window plus
the (H, P, N) SSM state per layer — this is why the ``long_500k`` shape
runs here while pure-attention archs skip it.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import pshard
from repro.layers.common import cross_entropy, embed_lookup, rmsnorm
from repro.layers.params import ParamSpec, stack_schema
from repro.layers.ssd import init_ssm_cache_spec, mamba_block, mamba_schema

__all__ = ["schema", "cache_schema", "loss", "prefill", "decode_step", "forward"]


def _block_schema(cfg) -> dict:
    return {
        "ln": ParamSpec((cfg.d_model,), ("norm",), init="ones"),
        "mamba": mamba_schema(cfg),
    }


def schema(cfg) -> dict:
    s: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           init="embed", scale=0.02),
        "blocks": stack_schema(_block_schema(cfg), cfg.num_layers),
        "final_norm": ParamSpec((cfg.d_model,), ("norm",), init="ones"),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return s


def cache_schema(cfg, batch: int, max_len: int) -> dict:
    (conv_shape, conv_axes), (ssm_shape, ssm_axes) = init_ssm_cache_spec(cfg, batch)
    layer = {
        "conv": ParamSpec(conv_shape, conv_axes, init="zeros", dtype=cfg.dtype),
        "ssm": ParamSpec(ssm_shape, ssm_axes, init="zeros", dtype="float32"),
    }
    return {"layers": stack_schema(layer, cfg.num_layers)}


def forward(params, cfg, tokens, *, cache=None, cache_pos=None, mode="train",
            last_logit_only=False):
    act = cfg.activation_dtype
    x = embed_lookup(params["embed"], tokens, act)
    x = pshard(x, "batch", "act_seq", "embed")

    def body(carry, xs):
        lp, lc = xs
        h = rmsnorm(carry, lp["ln"], cfg.norm_eps)
        c = None if lc is None else (lc["conv"], lc["ssm"])
        y, nc = mamba_block(lp["mamba"], cfg, h, cache=c, mode=mode)
        out_cache = None if nc is None else {"conv": nc[0], "ssm": nc[1]}
        return carry + y, out_cache

    if cache is None:
        def body_nc(carry, lp):
            h = rmsnorm(carry, lp["ln"], cfg.norm_eps)
            y, _ = mamba_block(lp["mamba"], cfg, h, cache=None, mode=mode)
            return carry + y, None
        x, _ = jax.lax.scan(body_nc, x, params["blocks"])
        new_cache = None
    else:
        x, ncs = jax.lax.scan(body, x, (params["blocks"], cache["layers"]))
        new_cache = {"layers": ncs}

    if last_logit_only:
        # §Perf (prefill cells): the unembedding matmul + its vocab-sharded
        # collectives over all S positions is pure waste when only the last
        # position's logits are consumed — slice the hidden state first.
        x = x[:, -1:]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return pshard(logits, "batch", "seq", "vocab"), new_cache, {}


def loss(params, cfg, batch):
    logits, _, metrics = forward(params, cfg, batch["tokens"], mode="train")
    l, ce = cross_entropy(logits, batch["targets"], batch.get("mask"))
    metrics.update(ce)
    metrics["total_loss"] = l
    return l, metrics


def prefill(params, cfg, batch, cache):
    logits, new_cache, _ = forward(
        params, cfg, batch["tokens"], cache=cache, cache_pos=jnp.int32(0),
        mode="prefill", last_logit_only=True,
    )
    return logits[:, -1, :], new_cache


def decode_step(params, cfg, tokens, cache, pos):
    logits, new_cache, _ = forward(
        params, cfg, tokens, cache=cache, cache_pos=pos, mode="decode"
    )
    return logits[:, -1, :], new_cache
