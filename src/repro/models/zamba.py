"""Zamba2-style hybrid: Mamba2 backbone + shared attention blocks.

``num_layers`` Mamba2 residual blocks are interleaved with applications of
``num_shared_blocks`` weight-shared transformer blocks (attention + MLP):
after every ``shared_attn_period`` Mamba layers, shared block
``(app_index % num_shared_blocks)`` runs.  Shared-block weights are stored
once — the parameter saving that lets Zamba2 punch above its size — while
each application keeps its own KV cache.

Simplifications vs the released checkpoints (recorded in DESIGN.md):
per-application LoRA deltas on the shared blocks and the concatenated
residual input are omitted; block structure, GQA geometry, SSM sizes and
the sharing schedule follow the assigned config.

Fusion note (DESIGN.md §5): the Mamba segments between attention points
stream with O(1) carried state — tilted-fusion-style; the shared full
attention is the global barrier that bounds the fusable span.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import pshard
from repro.layers import attention as attn_lib
from repro.layers.common import cross_entropy, embed_lookup, rmsnorm
from repro.layers.mlp import mlp_block, mlp_schema
from repro.layers.params import ParamSpec, stack_schema
from repro.layers.ssd import init_ssm_cache_spec, mamba_block, mamba_schema

__all__ = ["schema", "cache_schema", "loss", "prefill", "decode_step", "forward"]


def _num_apps(cfg) -> int:
    return cfg.num_layers // cfg.shared_attn_period


def _shared_block_schema(cfg) -> dict:
    d = cfg.d_model
    return {
        "ln1": ParamSpec((d,), ("norm",), init="ones"),
        "attn": attn_lib.gqa_schema(cfg),
        "ln2": ParamSpec((d,), ("norm",), init="ones"),
        "mlp": mlp_schema(cfg),
    }


def schema(cfg) -> dict:
    s: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           init="embed", scale=0.02),
        "blocks": stack_schema(
            {"ln": ParamSpec((cfg.d_model,), ("norm",), init="ones"),
             "mamba": mamba_schema(cfg)},
            cfg.num_layers,
        ),
        "shared": stack_schema(_shared_block_schema(cfg), cfg.num_shared_blocks,
                               axis_name="layers"),
        "final_norm": ParamSpec((cfg.d_model,), ("norm",), init="ones"),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return s


def cache_schema(cfg, batch: int, max_len: int) -> dict:
    (conv_shape, conv_axes), (ssm_shape, ssm_axes) = init_ssm_cache_spec(cfg, batch)
    mamba_layer = {
        "conv": ParamSpec(conv_shape, conv_axes, init="zeros", dtype=cfg.dtype),
        "ssm": ParamSpec(ssm_shape, ssm_axes, init="zeros", dtype="float32"),
    }
    kv_shape, kv_dtype, kv_axes = attn_lib.init_kv_cache_spec(cfg, batch, max_len)
    kv = ParamSpec(kv_shape, kv_axes, init="zeros", dtype=str(kv_dtype))
    # one KV cache per shared-block APPLICATION (not per shared block)
    return {
        "layers": stack_schema(mamba_layer, cfg.num_layers),
        "shared_kv": stack_schema({"k": kv, "v": kv}, _num_apps(cfg),
                                  axis_name="layers"),
    }


def _take(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _shared_apply(p, cfg, x, positions, kv, cache_pos, mode):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, new_kv = attn_lib.attention_block(
        p["attn"], cfg, h, positions,
        cache=None if kv is None else (kv["k"], kv["v"]),
        cache_pos=cache_pos, mode=mode)
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_block(p["mlp"], cfg, h)
    return x, (None if new_kv is None else {"k": new_kv[0], "v": new_kv[1]})


def forward(params, cfg, tokens, *, cache=None, cache_pos=None, mode="train",
            last_logit_only=False):
    act = cfg.activation_dtype
    period, n_apps = cfg.shared_attn_period, _num_apps(cfg)
    x = embed_lookup(params["embed"], tokens, act)
    x = pshard(x, "batch", "act_seq", "embed")
    B, S, _ = x.shape
    if mode == "decode":
        positions = jnp.full((B, 1), cache_pos, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def seg_body(carry, xs):
        if cache is None:
            lp = xs
            lc = None
        else:
            lp, lc = xs
        h = rmsnorm(carry, lp["ln"], cfg.norm_eps)
        c = None if lc is None else (lc["conv"], lc["ssm"])
        y, nc = mamba_block(lp["mamba"], cfg, h, cache=c, mode=mode)
        out = None if nc is None else {"conv": nc[0], "ssm": nc[1]}
        return carry + y, (out if cache is not None else None)

    new_mamba, new_kv = [], []
    for app in range(n_apps):
        sl = slice(app * period, (app + 1) * period)
        seg_params = _take(params["blocks"], sl)
        if cache is None:
            x, _ = jax.lax.scan(seg_body, x, seg_params)
        else:
            seg_cache = _take(cache["layers"], sl)
            x, ncs = jax.lax.scan(seg_body, x, (seg_params, seg_cache))
            new_mamba.append(ncs)
        shared_p = _take(params["shared"], app % cfg.num_shared_blocks)
        kv = None if cache is None else _take(cache["shared_kv"], app)
        x, nkv = _shared_apply(shared_p, cfg, x, positions, kv, cache_pos, mode)
        if nkv is not None:
            new_kv.append(nkv)

    # trailing mamba layers not followed by a shared application
    rem = cfg.num_layers - n_apps * period
    if rem:
        sl = slice(n_apps * period, cfg.num_layers)
        seg_params = _take(params["blocks"], sl)
        if cache is None:
            x, _ = jax.lax.scan(seg_body, x, seg_params)
        else:
            seg_cache = _take(cache["layers"], sl)
            x, ncs = jax.lax.scan(seg_body, x, (seg_params, seg_cache))
            new_mamba.append(ncs)

    new_cache = None
    if cache is not None:
        stack = lambda trees: jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *trees
        )
        new_cache = {
            "layers": stack(new_mamba),
            "shared_kv": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs, axis=0), *new_kv
            ),
        }

    if last_logit_only:
        # §Perf (prefill cells): the unembedding matmul + its vocab-sharded
        # collectives over all S positions is pure waste when only the last
        # position's logits are consumed — slice the hidden state first.
        x = x[:, -1:]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return pshard(logits, "batch", "seq", "vocab"), new_cache, {}


def loss(params, cfg, batch):
    logits, _, metrics = forward(params, cfg, batch["tokens"], mode="train")
    l, ce = cross_entropy(logits, batch["targets"], batch.get("mask"))
    metrics.update(ce)
    metrics["total_loss"] = l
    return l, metrics


def prefill(params, cfg, batch, cache):
    logits, new_cache, _ = forward(
        params, cfg, batch["tokens"], cache=cache, cache_pos=jnp.int32(0),
        mode="prefill", last_logit_only=True,
    )
    return logits[:, -1, :], new_cache


def decode_step(params, cfg, tokens, cache, pos):
    logits, new_cache, _ = forward(
        params, cfg, tokens, cache=cache, cache_pos=pos, mode="decode"
    )
    return logits[:, -1, :], new_cache
