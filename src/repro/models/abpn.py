"""ABPN — Anchor-based Plain Net (Du et al., CVPR-W 2021), the paper's model.

Seven layers (paper §III-A): six 3x3 convs with ReLU (3->28, then 28->28 x5)
and a final 3x3 conv to ``3 * scale**2`` channels followed by the
"residual-like structure" — the *anchor*: the input image replicated
``scale**2`` times per channel is added to the final conv output so the
network only learns the residual against a nearest-neighbour upsample; a
pixel shuffle (depth-to-space) then produces the HR image.

Execution is delegated to the batched engine subsystem (``repro.engine``):
build an ``SRPlan`` (backend ``reference`` | ``tilted`` | ``kernel``) and run
frame batches through one jitted call.  ``apply_abpn(method=...)`` remains as
a deprecated single-frame shim over that API.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.fusion import ConvLayer

__all__ = [
    "ABPNConfig",
    "init_abpn",
    "depth_to_space",
    "make_anchor",
    "apply_abpn",
    "param_count",
]


@dataclasses.dataclass(frozen=True)
class ABPNConfig:
    in_channels: int = 3
    feature_channels: int = 28  # paper: all intermediate layers have 28
    num_layers: int = 7
    scale: int = 3  # x3 SR: 640x360 -> 1920x1080
    clip: bool = True  # clip output to [0, 1] (8-bit image range)

    @property
    def out_channels(self) -> int:
        return self.in_channels * self.scale * self.scale

    @property
    def channels(self) -> List[int]:
        """F_0..F_L channel counts — feeds ``core.analysis.HWConfig``."""
        return (
            [self.in_channels]
            + [self.feature_channels] * (self.num_layers - 1)
            + [self.out_channels]
        )


def init_abpn(key: jax.Array, cfg: ABPNConfig = ABPNConfig(), dtype=jnp.float32) -> List[ConvLayer]:
    """He-initialised ABPN conv stack."""
    ch = cfg.channels
    layers = []
    for i in range(cfg.num_layers):
        key, wk = jax.random.split(key)
        ci, co = ch[i], ch[i + 1]
        fan_in = 9 * ci
        w = jax.random.normal(wk, (3, 3, ci, co), dtype) * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((co,), dtype)
        layers.append(ConvLayer(w=w, b=b, relu=(i < cfg.num_layers - 1)))
    return layers


def depth_to_space(x: jax.Array, block: int) -> jax.Array:
    """(H, W, C*block^2) -> (H*block, W*block, C), channel-major blocks.

    Convention: ``out[y*b+dy, x*b+dx, c] = in[y, x, c*b*b + dy*b + dx]`` —
    chosen so that replicating each input channel ``b*b`` times yields an
    exact nearest-neighbour upsample (the ABPN anchor), which is tested.
    """
    H, W, CB = x.shape
    b = block
    C = CB // (b * b)
    if C * b * b != CB:
        raise ValueError(f"channels {CB} not divisible by block^2 {b * b}")
    x = x.reshape(H, W, C, b, b)
    x = x.transpose(0, 3, 1, 4, 2)  # H, dy, W, dx, C
    return x.reshape(H * b, W * b, C)


def make_anchor(lr: jax.Array, scale: int) -> jax.Array:
    """The ABPN anchor: each input channel repeated scale^2 times.

    ``depth_to_space(make_anchor(lr, s), s)`` == nearest-neighbour upsample.
    In the accelerator this is the residual SRAM path added in the second
    accumulator stage (paper §III-C); its buffer cost is eq. (3).
    """
    return jnp.repeat(lr, scale * scale, axis=-1)


def apply_abpn(
    layers: Sequence[ConvLayer],
    lr: jax.Array,
    cfg: ABPNConfig = ABPNConfig(),
    method: str = "reference",
    band_rows: int = 60,
    tile_cols: int = 8,
    vertical_policy: str = "zero",
) -> jax.Array:
    """LR (H, W, in_ch) -> HR (H*scale, W*scale, in_ch).

    .. deprecated::
        Thin shim over :mod:`repro.engine` kept for existing callers — it
        rebuilds an :class:`~repro.engine.SRPlan` per call and runs a
        single-frame batch.  New code should build a plan once with
        :func:`repro.engine.make_plan` and use :func:`repro.engine.run` /
        :class:`repro.engine.VideoStream` over frame batches instead.
    """
    from repro import engine  # local import: models must not hard-cycle engine

    if method not in ("reference", "tilted", "kernel"):
        raise ValueError(f"unknown method {method!r}")
    plan = engine.make_plan(
        layers,
        lr.shape,
        band_rows=band_rows,
        tile_cols=tile_cols,
        vertical_policy=vertical_policy,
        backend=method,
        scale=cfg.scale,
        clip=cfg.clip,
    )
    return engine.run(plan, layers, lr[None])[0]


def param_count(layers: Sequence[ConvLayer]) -> int:
    return sum(int(l.w.size + l.b.size) for l in layers)
