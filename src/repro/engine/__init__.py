"""Batched SR execution engine (the serving subsystem).

``SRPlan`` (plan.py) describes an execution — geometry, numerics, boundary
policy, backend — once; ``build_executor``/``run`` (executor.py) compile it
into a single jitted call over a batch of LR frames; ``VideoStream``
(stream.py) drives that call as a latency-tracked serving loop.

The legacy entry point ``models.abpn.apply_abpn(method=...)`` is now a thin
shim over this package.
"""

from repro.engine.executor import build_executor, prepare_layers, run, sr_features
from repro.engine.plan import (
    BACKENDS,
    PRECISIONS,
    VERTICAL_POLICIES,
    SRPlan,
    make_plan,
)
from repro.engine.stream import StreamStats, VideoStream

__all__ = [
    "SRPlan",
    "make_plan",
    "BACKENDS",
    "PRECISIONS",
    "VERTICAL_POLICIES",
    "build_executor",
    "prepare_layers",
    "run",
    "sr_features",
    "VideoStream",
    "StreamStats",
]
