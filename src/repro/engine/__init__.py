"""Batched SR execution engine (the serving subsystem).

``SRServer`` (server.py) is the serving front door:
``SRServer.open(models...)`` hosts one or more named sessions,
``server.submit(frames, model=..., priority=...)`` returns an
:class:`SRFuture`, and ``server.stream(...)`` is an async generator for
frame-at-a-time live video.  A micro-batching scheduler (scheduler.py)
coalesces concurrent requests that share a ``(model, plan, dtype)`` key
into single bucket-sized dispatches (real frames instead of padding) and
enforces a bounded queue with backpressure (``max_inflight_frames``,
block-or-reject admission).

``SRSession`` (session.py) is the per-model layer underneath:
``SRSession.open(model)`` resolves weights through the model registry and
``session.upscale(frames)`` — now a thin synchronous shim over
``session.submit(frames).result()`` — serves any ``(H, W, C)`` /
``(T, H, W, C)`` / ``(B, T, H, W, C)`` request, deriving the
:class:`SRPlan` per resolution (``SRPlan.from_request``), bucketing
batches to powers of two, and compiling executors on demand into an LRU
:class:`PlanCache` (``session.cache_stats()``).  Serving is pipelined:
weights are prepared once per session into a device-resident
:class:`PreparedStack`, dispatches keep up to ``pipeline_depth`` chunks in
flight (double buffering), and executors can donate the frame slab back to
XLA (``donate_frames``).

Schedules are TUNED, not hard-coded: the autotuner (autotune.py) sweeps
the legal (band_rows, pipeline_depth, bucket policy) space per
configuration — roofline-pruned, then compiled and measured — and
persists winners in a JSON :class:`TuningDB`; sessions consult it on
cold start (``SRSession.open(..., autotune="off"|"cached"|"full")``,
``session.tuning_stats()``).

Serving is MESH-AWARE (sharding/): ``SRSession.open(..., mesh=(R, S))``
band-shards every executor over a ``bands`` device axis (``shard_map`` +
ppermute halo exchange at shard edges, bit-exact vs single-device) and
routes coalesced dispatches across ``R`` replicas
(:class:`ReplicaRouter`: round-robin / least-loaded, per-replica compile
caches; ``session.sharding_stats()``).

Serving is DELTA-AWARE for video (temporal/): ``server.stream(...,
delta=True)`` (or a :class:`DeltaSession` directly) band-diffs each
frame against the previous one, dilates the changed bands by the halo
reach, dispatches only the dirty bands as partial-band dispatches
(``submit_bands`` -> ``Dispatch.band_subset`` through the same
scheduler), and splices clean bands from a bounded refcounted
:class:`OutputBandCache` keyed by receptive-field window digest —
bit-exact vs full re-upscale (``session.stats()['temporal']``).

Underneath: ``SRPlan`` (plan.py) describes one execution — geometry,
numerics, boundary policy, backend — and ``build_executor``/``run``
(executor.py) compile it into a single jitted call over a batch of LR
frames.  ``VideoStream`` (stream.py) is a deprecated fixed-batch shim over
a pinned session; ``models.abpn.apply_abpn(method=...)`` is an older shim
over ``run``.
"""

from repro.engine.autotune import (
    PlanTuner,
    TuningDB,
    TuningEntry,
    TuningKey,
    tune,
)
from repro.engine.executor import (
    PreparedStack,
    build_executor,
    build_stack_executor,
    executor_artifacts,
    output_spec,
    plan_cost,
    prepare_layers,
    prepare_stack,
    run,
    sr_epilogue,
    sr_features,
)
from repro.engine.plan import (
    BACKENDS,
    PRECISIONS,
    VERTICAL_POLICIES,
    SRPlan,
    derive_band_rows,
    legal_band_rows,
    make_plan,
    shardable_band_rows,
)
from repro.engine.scheduler import (
    DeadlineExceededError,
    MicroBatchScheduler,
    QueueFullError,
    RequestShedError,
)
from repro.engine.server import (
    DEGRADE_LADDER,
    DegradePolicy,
    RequestCancelledError,
    SRFuture,
    SRServer,
)
from repro.engine.session import (
    AUTOTUNE_MODES,
    PlanCache,
    SRSession,
    StreamStats,
    bucket_batch,
)
from repro.engine.sharding import (
    ROUTE_POLICIES,
    MeshSpec,
    ReplicaRouter,
    ShardedPlan,
    build_sharded_executor,
)
from repro.engine.stream import VideoStream
from repro.engine.temporal import DeltaSession, OutputBandCache

__all__ = [
    "SRServer",
    "SRFuture",
    "MicroBatchScheduler",
    "QueueFullError",
    "DeadlineExceededError",
    "RequestShedError",
    "RequestCancelledError",
    "DegradePolicy",
    "DEGRADE_LADDER",
    "DeltaSession",
    "OutputBandCache",
    "SRSession",
    "PlanCache",
    "bucket_batch",
    "SRPlan",
    "make_plan",
    "derive_band_rows",
    "legal_band_rows",
    "AUTOTUNE_MODES",
    "PlanTuner",
    "TuningDB",
    "TuningEntry",
    "TuningKey",
    "tune",
    "BACKENDS",
    "PRECISIONS",
    "VERTICAL_POLICIES",
    "build_executor",
    "build_stack_executor",
    "executor_artifacts",
    "output_spec",
    "plan_cost",
    "prepare_layers",
    "prepare_stack",
    "PreparedStack",
    "run",
    "sr_epilogue",
    "sr_features",
    "shardable_band_rows",
    "MeshSpec",
    "ShardedPlan",
    "ReplicaRouter",
    "ROUTE_POLICIES",
    "build_sharded_executor",
    "VideoStream",
    "StreamStats",
]
