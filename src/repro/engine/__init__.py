"""Batched SR execution engine (the serving subsystem).

``SRSession`` (session.py) is the serving API: ``SRSession.open(model)``
resolves weights through the model registry, ``session.upscale(frames)``
serves any ``(H, W, C)`` / ``(T, H, W, C)`` / ``(B, T, H, W, C)`` request —
deriving the :class:`SRPlan` per resolution (``SRPlan.from_request``),
bucketing batches to powers of two, and compiling executors on demand into
an LRU :class:`PlanCache` (``session.cache_stats()``).  Serving is
pipelined: weights are prepared once per session into a device-resident
:class:`PreparedStack`, multi-bucket requests keep up to ``pipeline_depth``
chunks in flight (double-buffered dispatch), and executors can donate the
frame slab back to XLA (``donate_frames``).

Underneath: ``SRPlan`` (plan.py) describes one execution — geometry,
numerics, boundary policy, backend — and ``build_executor``/``run``
(executor.py) compile it into a single jitted call over a batch of LR
frames.  ``VideoStream`` (stream.py) is a deprecated fixed-batch shim over
a pinned session; ``models.abpn.apply_abpn(method=...)`` is an older shim
over ``run``.
"""

from repro.engine.executor import (
    PreparedStack,
    build_executor,
    build_stack_executor,
    output_spec,
    plan_cost,
    prepare_layers,
    prepare_stack,
    run,
    sr_features,
)
from repro.engine.plan import (
    BACKENDS,
    PRECISIONS,
    VERTICAL_POLICIES,
    SRPlan,
    derive_band_rows,
    make_plan,
)
from repro.engine.session import PlanCache, SRSession, StreamStats, bucket_batch
from repro.engine.stream import VideoStream

__all__ = [
    "SRSession",
    "PlanCache",
    "bucket_batch",
    "SRPlan",
    "make_plan",
    "derive_band_rows",
    "BACKENDS",
    "PRECISIONS",
    "VERTICAL_POLICIES",
    "build_executor",
    "build_stack_executor",
    "output_spec",
    "plan_cost",
    "prepare_layers",
    "prepare_stack",
    "PreparedStack",
    "run",
    "sr_features",
    "VideoStream",
    "StreamStats",
]
