"""SRServer — the request/future serving front door over SRSessions.

``SRSession.upscale`` is caller-batched and blocking: every request pays
its own padded bucket and two concurrent half-bucket requests can never
share a dispatch.  ``SRServer`` moves admission and batching into the
engine, the way the block-streaming schedulers of ACNPU/BSRA own their
datapath's work queue:

* ``SRServer.open("abpn_x3", ...)`` hosts one or more named
  :class:`~repro.engine.session.SRSession`\\ s (multi-model traffic routes
  through each session's own ``PlanCache``/``PreparedStack`` machinery).
* ``server.submit(frames, model=..., priority=...)`` validates and queues
  a request and returns an :class:`SRFuture` immediately; requests that
  share a ``(model, plan, dtype)`` key are COALESCED by the
  :class:`~repro.engine.scheduler.MicroBatchScheduler` into bucket-sized
  dispatches — concurrent small requests fill one power-of-two bucket with
  real frames instead of each padding its own.
* ``async for hr in server.stream(frames)`` serves frame-at-a-time live
  video: each frame is submitted (a small lookahead keeps the coalescer
  fed) and HR frames are yielded in order; concurrent streams share
  dispatches.
* ``max_inflight_frames`` bounds the queue (pending + dispatched frames);
  at the bound, ``admission="block"`` drains the queue to make space,
  ``admission="reject"`` raises
  :class:`~repro.engine.scheduler.QueueFullError`, and
  ``admission="shed"`` evicts the lowest-priority, latest-deadline queued
  work (never the newcomer) — victims fail with
  :class:`~repro.engine.scheduler.RequestShedError`.
* ``submit(frames, deadline=..., timeout=...)`` attaches a per-request
  deadline: a request still fully queued when it passes is cancelled with
  :class:`~repro.engine.scheduler.DeadlineExceededError` before it ever
  compiles or dispatches — its coalesced neighbors are untouched.
* :class:`DegradePolicy` is the overload pressure valve: it watches a
  rolling p99 of end-to-end request latency (the EMA mean/var core shared
  with ``runtime.resilience.StragglerDetector``) and on sustained SLO
  breach steps down a documented ladder — bf16 dispatch dtype, halved
  ``stream()`` lookahead, halved buckets — stepping back up on recovery;
  every transition is logged in ``stats()``.
* A ``runtime.resilience.FailureInjector`` passed as ``injector=``
  intercepts every launch (fail the k-th dispatch, delay a replica,
  poison a model): injected faults flow through the normal
  dispatch-failure isolation, so only the affected requests fail.

Execution is the PIPELINED drain loop that previously lived inside
``SRSession``: each dispatch is assembled (host frames through the
session's one reused staging buffer, device frames through a fused pad /
concatenate), launched asynchronously, and completed in order, with up to
``session.pipeline_depth`` dispatches in flight per session.  Latency,
span and peak-inflight numbers are recorded on the owning session —
``session.stats()`` means the same thing whether a batch arrived through
``upscale``, ``submit`` or a stream.  Dispatch formation runs under one
server lock, but device waits release it: ``SRFuture.result()`` from any
thread drives the drain, and while one thread blocks on the device other
threads' submits are admitted — and coalesce into the next dispatch.

``SRSession.upscale`` is now a thin synchronous shim over
``session.submit(frames).result()`` — routed through the server hosting
the session (one scheduler and one lock govern all traffic into it), or
through an embedded single-model server when none does — so the blocking
API and the future API are the same code path.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Deque, Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.scheduler import (
    DeadlineExceededError,
    Dispatch,
    MicroBatchScheduler,
    QueueFullError,
    RequestShedError,
    SchedRequest,
)
from repro.engine.session import SRSession
from repro.runtime.resilience import EMAMeanVar

__all__ = [
    "SRServer",
    "SRFuture",
    "QueueFullError",
    "DeadlineExceededError",
    "RequestShedError",
    "RequestCancelledError",
    "DegradePolicy",
    "DEGRADE_LADDER",
]

ADMISSION_POLICIES = ("block", "reject", "shed")


class RequestCancelledError(RuntimeError):
    """The submitter cancelled the request (e.g. an abandoned stream)."""

# The degradation ladder, mildest first; level k applies steps 1..k.
DEGRADE_LADDER = ("full", "bf16", "half_lookahead", "half_buckets")


class DegradePolicy:
    """Degrade-under-pressure controller for :class:`SRServer`.

    Watches a rolling p99 estimate of END-TO-END request latency
    (admission to future resolution, milliseconds): an
    :class:`~repro.runtime.resilience.EMAMeanVar` — the same moving
    mean/variance core ``StragglerDetector`` uses for training-step
    latencies — approximates p99 as ``mean + 2.326 sigma``.  O(1) per
    observation, no reservoir, and monotone in both load and jitter,
    which is what a pressure signal needs.

    The ladder (:data:`DEGRADE_LADDER`), mildest first; level k applies
    every step up to k:

    1. ``bf16`` — fp32 requests dispatch in bf16 (half the slab traffic
       per frame; the paper's own on-chip compute precision).
    2. ``half_lookahead`` — ``stream()`` halves its lookahead window
       (fewer speculative frames queued per live stream).
    3. ``half_buckets`` — freshly derived dispatch buckets are halved
       (lower per-dispatch latency at some throughput cost; carry-pinned
       buckets are never resized mid-clip).

    Hysteresis: stepping DOWN takes ``breach_steps`` consecutive
    observations with the p99 estimate over ``slo_p99_ms``; stepping UP
    takes ``recover_steps`` consecutive observations at or under
    ``recover_fraction * slo_p99_ms``.  One outlier cannot flap the
    ladder.  Every transition is recorded (``transitions``, surfaced by
    ``SRServer.stats()``).

    Thread-safety: the server calls :meth:`observe` and reads the level
    under its own lock; the policy object itself keeps no lock.
    """

    #: z for the normal-approximation p99 (Phi(2.326) ~ 0.99)
    P99_Z = 2.326

    def __init__(self, slo_p99_ms: float, *, alpha: float = 0.1,
                 breach_steps: int = 3, recover_steps: int = 8,
                 recover_fraction: float = 0.5):
        if slo_p99_ms <= 0:
            raise ValueError(f"slo_p99_ms={slo_p99_ms} must be > 0")
        if breach_steps < 1 or recover_steps < 1:
            raise ValueError("breach_steps and recover_steps must be >= 1")
        if not 0 < recover_fraction <= 1:
            raise ValueError(
                f"recover_fraction={recover_fraction} must be in (0, 1]"
            )
        self.slo_p99_ms = float(slo_p99_ms)
        self.breach_steps = int(breach_steps)
        self.recover_steps = int(recover_steps)
        self.recover_fraction = float(recover_fraction)
        self._ema = EMAMeanVar(alpha)
        self.level = 0
        self.observations = 0
        self.degraded_requests = 0  # requests admitted at level > 0
        self.transitions: list = []
        self._breach = 0
        self._recover = 0

    @property
    def p99_ms(self) -> float:
        """The rolling p99 estimate (0.0 until the first observation)."""
        return self._ema.upper(self.P99_Z)

    def observe(self, latency_ms: float) -> Optional[dict]:
        """Fold one completed request's end-to-end latency; returns the
        transition record if this observation moved the ladder."""
        self.observations += 1
        self._ema.fold(latency_ms)
        p99 = self.p99_ms
        if p99 > self.slo_p99_ms:
            self._breach += 1
            self._recover = 0
            if (self._breach >= self.breach_steps
                    and self.level < len(DEGRADE_LADDER) - 1):
                return self._transition(self.level + 1, p99, "slo_breach")
        elif p99 <= self.recover_fraction * self.slo_p99_ms:
            self._recover += 1
            self._breach = 0
            if self._recover >= self.recover_steps and self.level > 0:
                return self._transition(self.level - 1, p99, "recovered")
        else:
            # between the recovery band and the SLO: steady state, reset
            # both streaks — neither direction is earning a transition
            self._breach = 0
            self._recover = 0
        return None

    def _transition(self, to: int, p99: float, reason: str) -> dict:
        t = {
            "from": self.level,
            "to": to,
            "from_step": DEGRADE_LADDER[self.level],
            "to_step": DEGRADE_LADDER[to],
            "p99_ms": round(p99, 3),
            "slo_p99_ms": self.slo_p99_ms,
            "reason": reason,
            "observation": self.observations,
        }
        self.level = to
        self._breach = 0
        self._recover = 0
        self.transitions.append(t)
        return t

    # --- the knobs the server consults, one per ladder step -----------
    def serve_dtype(self, dtype: np.dtype) -> np.dtype:
        """Dispatch dtype at the current level (level >= 1: fp32 -> bf16)."""
        if self.level >= 1 and np.dtype(dtype) == np.float32:
            return np.dtype(jnp.bfloat16)
        return np.dtype(dtype)

    def lookahead(self, base: int) -> int:
        """Stream lookahead at the current level (level >= 2: halved)."""
        return max(1, base // 2) if self.level >= 2 else base

    def bucket_cap(self, bucket: int) -> int:
        """Dispatch bucket at the current level (level >= 3: halved)."""
        return max(1, bucket // 2) if self.level >= 3 else bucket

    def stats(self) -> dict:
        return {
            "level": self.level,
            "step": DEGRADE_LADDER[self.level],
            "ladder": list(DEGRADE_LADDER),
            "slo_p99_ms": self.slo_p99_ms,
            "p99_ms": round(self.p99_ms, 3),
            "observations": self.observations,
            "degraded_requests": self.degraded_requests,
            "transitions": list(self.transitions),
        }


class SRFuture:
    """The result handle ``SRServer.submit`` returns.

    ``result()`` drives the server's drain loop until this request's
    frames are served (so a single-threaded caller needs no background
    worker), then returns the HR array in the request's original rank —
    or re-raises the error that failed the dispatch.  Thread-safe: any
    number of threads may wait; whoever gets the server lock drains,
    the rest block until notified.
    """

    def __init__(self, server: "SRServer"):
        self._server = server
        self._cond = threading.Condition()
        self._done = False
        self._result = None
        self._exc: Optional[BaseException] = None
        self._callbacks = []
        # backref to the admitted SchedRequest — what SRServer.cancel
        # uses to drop the queued remainder of an abandoned request
        self._request = None

    def done(self) -> bool:
        return self._done

    def _wait_done(self, timeout: Optional[float]) -> None:
        """Drive the drain, then wait for completion — both bounded by one
        monotonic deadline.

        ``timeout`` is WALL-CLOCK from this call: a drain this call
        performs itself checks the deadline between steps (so a caller
        driving the drain still gets a timely ``TimeoutError``), and the
        wait loops on the condition until done or due — a single
        ``cond.wait(timeout)`` could return early on a spurious wakeup
        and then either under-wait or over-wait.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        if not self._done:
            self._server._drain_until(self, deadline=deadline)
        with self._cond:
            while not self._done:
                if deadline is None:
                    self._cond.wait()
                    continue
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError("request not complete within timeout")
                self._cond.wait(remaining)

    def result(self, timeout: Optional[float] = None):
        """The request's HR output (blocking; drives the server's drain),
        or re-raises the error that failed the request."""
        self._wait_done(timeout)
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        """The error that failed this request, or ``None`` (blocking; a
        stored failure is RETURNED — even a ``TimeoutError`` raised by the
        dispatch — while an unfinished wait raises ``TimeoutError``)."""
        self._wait_done(timeout)
        return self._exc

    def add_done_callback(self, fn) -> None:
        """Run ``fn(self)`` when the future resolves (immediately if it
        already has).  Callbacks run on the draining thread, OUTSIDE the
        server lock — a callback may submit follow-up work or wait on
        other futures without deadlocking."""
        with self._cond:
            if not self._done:
                self._callbacks.append(fn)
                return
        fn(self)

    def _finish(self, result=None, exc: Optional[BaseException] = None) -> None:
        """Set the outcome and wake waiters.  Callbacks are NOT run here —
        this executes under the server lock; the server runs
        :meth:`_run_callbacks` after releasing it."""
        with self._cond:
            self._result = result
            self._exc = exc
            self._done = True
            self._cond.notify_all()

    def _run_callbacks(self) -> None:
        with self._cond:
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            fn(self)


class _Inflight:
    """One launched dispatch: the async HR handle plus its timing and
    whether it staged through the session's shared host buffer."""

    __slots__ = ("dispatch", "hr", "t0", "used_staging")

    def __init__(self, dispatch: Dispatch, hr, t0: float, used_staging: bool):
        self.dispatch = dispatch
        self.hr = hr
        self.t0 = t0
        self.used_staging = used_staging


class SRServer:
    """One serving endpoint hosting named sessions behind a micro-batcher.

    ``sessions`` maps model names to :class:`SRSession`\\ s (a bare session
    is accepted and hosted under its model name).  ``default_model`` is the
    target when ``submit`` is called without ``model=`` (defaults to the
    first session).  ``max_inflight_frames`` bounds pending + dispatched
    frames; ``admission`` picks the full-queue behavior (``"block"`` drains
    to make space, ``"reject"`` raises :class:`QueueFullError`, ``"shed"``
    evicts the lowest-priority latest-deadline queued work to make room —
    or rejects the newcomer when it is itself the least urgent).
    ``degrade`` installs a :class:`DegradePolicy`; ``injector`` a
    :class:`~repro.runtime.resilience.FailureInjector` consulted before
    every launch (tests/load harness only — injected faults fail exactly
    the dispatch they target).
    """

    def __init__(
        self,
        sessions: Union[SRSession, Mapping[str, SRSession]],
        *,
        default_model: Optional[str] = None,
        max_inflight_frames: Optional[int] = None,
        admission: str = "block",
        degrade: Optional[DegradePolicy] = None,
        injector=None,
    ):
        if isinstance(sessions, SRSession):
            sessions = {sessions.model or "default": sessions}
        sessions = dict(sessions)
        if not sessions:
            raise ValueError("SRServer needs at least one session")
        for name, s in sessions.items():
            if not isinstance(name, str):
                raise ValueError(f"model name {name!r} must be a string")
            if not isinstance(s, SRSession):
                raise ValueError(
                    f"model {name!r} must map to an SRSession, got {type(s).__name__}"
                )
        if max_inflight_frames is not None and max_inflight_frames < 1:
            raise ValueError(
                f"max_inflight_frames={max_inflight_frames} must be >= 1 "
                "(or None for an unbounded queue)"
            )
        if admission not in ADMISSION_POLICIES:
            raise ValueError(
                f"admission {admission!r} not in {ADMISSION_POLICIES}"
            )
        if admission == "shed" and max_inflight_frames is None:
            raise ValueError(
                'admission="shed" needs a max_inflight_frames bound — '
                "an unbounded queue never sheds"
            )
        if degrade is not None and not isinstance(degrade, DegradePolicy):
            raise ValueError(
                f"degrade must be a DegradePolicy, got {type(degrade).__name__}"
            )
        if injector is not None and not hasattr(injector, "on_dispatch"):
            raise ValueError(
                "injector must expose on_dispatch(model=, replica=) — "
                "see repro.runtime.resilience.FailureInjector"
            )
        if default_model is None:
            default_model = next(iter(sessions))
        if default_model not in sessions:
            raise ValueError(
                f"default_model {default_model!r} not among hosted models "
                f"{sorted(sessions)}"
            )
        self._sessions = sessions
        self._default = default_model
        self.max_inflight_frames = max_inflight_frames
        self.admission = admission
        self._degrade = degrade
        self._injector = injector
        # hosted sessions route their own submit()/upscale() through THIS
        # server, so one lock + one scheduler govern all traffic into the
        # session; a SECOND front door over the same mutable session state
        # (staging buffer, caches, stats) would race it, so hosting an
        # already-served session is an error rather than a silent hazard
        for s in sessions.values():
            if s._server is None:
                s._server = self
            elif s._server is not self:
                raise ValueError(
                    "session is already served by another SRServer (its "
                    "upscale()/submit() traffic routes there); host each "
                    "session in exactly one server — construct the hosting "
                    "server before serving through the session directly"
                )
        self._sched = MicroBatchScheduler()
        # one lock guards scheduler + inflight state; the condition lets a
        # thread RELEASE it while blocking on the device (completions in
        # progress are counted in _completing and waited on via the cv),
        # so concurrent submits are admitted — and coalesce — while a
        # drain is waiting on compute
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._completing = 0  # dispatches being block_until_ready'd off-lock
        self._inflight: Deque[_Inflight] = deque()
        self._inflight_frames = 0  # dispatched, not yet complete (real)
        self._session_inflight: Dict[int, int] = {}
        self._window_start: Dict[int, float] = {}
        # per-session count of in-flight dispatches staged through the
        # session's SHARED host buffer: while one is outstanding, the next
        # host dispatch stages through a fresh buffer instead — the H2D
        # copy of dispatch t may still be reading the buffer when t+1
        # assembles (a hazard only on overlapped host dispatches)
        self._staging_busy: Dict[int, int] = {}
        # futures finished inside a locked region, whose done-callbacks
        # still need to run once the lock is released
        self._just_finished: list = []
        self._closed = False

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------
    @classmethod
    def open(
        cls,
        *models: str,
        default_model: Optional[str] = None,
        max_inflight_frames: Optional[int] = None,
        admission: str = "block",
        degrade: Optional[DegradePolicy] = None,
        injector=None,
        seed: int = 0,
        autotune: Union[str, Mapping[str, str], None] = None,
        **session_kwargs,
    ) -> "SRServer":
        """Open a server hosting registered SR models by name.

        Each name resolves through ``repro.models.registry``
        (``list_sr_models()`` enumerates them); ``session_kwargs``
        (backend, precision, pipeline_depth, max_bucket, ...) apply to
        every hosted session.  ``autotune`` sets each session's schedule
        policy (``"off"`` | ``"cached"`` | ``"full"`` — see
        ``session.AUTOTUNE_MODES``): a single string applies to every
        hosted model, a mapping sets it per model name (unnamed models
        keep the session default).  With no names, hosts the paper's
        ``abpn_x3``.
        """
        names = models or ("abpn_x3",)

        def _kwargs_for(name: str) -> dict:
            kw = dict(session_kwargs)
            if isinstance(autotune, Mapping):
                if name in autotune:
                    kw["autotune"] = autotune[name]
            elif autotune is not None:
                kw["autotune"] = autotune
            return kw

        sessions = {
            name: SRSession.open(name, seed=seed, **_kwargs_for(name))
            for name in names
        }
        return cls(
            sessions,
            default_model=default_model,
            max_inflight_frames=max_inflight_frames,
            admission=admission,
            degrade=degrade,
            injector=injector,
        )

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def models(self) -> Tuple[str, ...]:
        return tuple(self._sessions)

    def session(self, model: Optional[str] = None) -> SRSession:
        """The hosted session serving ``model`` (default model if None)."""
        return self._sessions[self._resolve_model(model)]

    def scheduler_stats(self) -> dict:
        """The micro-batcher's coalescing/queue counters plus the server's
        in-flight state (see ``MicroBatchScheduler.stats``)."""
        with self._lock:
            stats = self._sched.stats()
            stats["inflight_dispatches"] = len(self._inflight)
            stats["inflight_frames"] = self._inflight_frames
            stats["recent_dispatches"] = list(self._sched.recent_dispatches)
        return stats

    def stats(self) -> dict:
        """Scheduler counters, each hosted session's serving stats, and —
        when a :class:`DegradePolicy` is installed — its level, rolling
        p99 estimate and full transition log."""
        out = {
            "scheduler": self.scheduler_stats(),
            "models": {
                name: dict(s.stats()) for name, s in self._sessions.items()
            },
        }
        if self._degrade is not None:
            with self._lock:
                out["degrade"] = self._degrade.stats()
        return out

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    def _resolve_model(self, model: Optional[str]) -> str:
        name = self._default if model is None else model
        if name not in self._sessions:
            raise ValueError(
                f"unknown model {name!r}; this server hosts {sorted(self._sessions)}"
            )
        return name

    def _name_for(self, session: SRSession) -> str:
        """The hosted name of a session (identity lookup)."""
        for name, s in self._sessions.items():
            if s is session:
                return name
        raise ValueError("session is not hosted by this server")

    def submit_for(self, session: SRSession, frames, *, priority: int = 0,
                   deadline: Optional[float] = None,
                   timeout: Optional[float] = None) -> SRFuture:
        """Submit addressed by hosted session identity rather than name —
        what ``SRSession.submit`` calls on its hosting server."""
        return self.submit(frames, model=self._name_for(session),
                           priority=priority, deadline=deadline,
                           timeout=timeout)

    def submit(self, frames, *, model: Optional[str] = None,
               priority: int = 0, deadline: Optional[float] = None,
               timeout: Optional[float] = None) -> SRFuture:
        """Queue a request; returns its :class:`SRFuture` immediately.

        ``frames`` is any rank ``upscale`` accepts (``(H, W, C)``,
        ``(T, H, W, C)``, ``(B, T, H, W, C)``); validation (array-ness,
        numeric dtype, rank, channel count) happens HERE, synchronously,
        so malformed input fails with a clear ``ValueError`` instead of
        surfacing from plan derivation or compilation.  Higher
        ``priority`` keys dispatch first.  The actual dispatch runs when
        the drain loop next turns over (``result()``/``flush()``/a
        concurrent waiter), coalescing whatever compatible requests are
        queued by then.

        ``deadline`` (absolute ``time.monotonic()`` seconds) or
        ``timeout`` (seconds from now; the two are exclusive) bounds how
        long the request may sit QUEUED: when it passes before the first
        frame dispatches, the future fails with
        :class:`DeadlineExceededError` — checked at every admission and
        drain turn, so an expired request never compiles or dispatches.
        Once frames are in flight the request runs to completion (a torn
        half-clip helps nobody); the deadline bounds queueing, not
        compute.
        """
        if self._closed:
            raise RuntimeError("server is closed")
        if deadline is not None and timeout is not None:
            raise ValueError("pass deadline= or timeout=, not both")
        if timeout is not None:
            deadline = time.monotonic() + float(timeout)
        name = self._resolve_model(model)
        session = self._sessions[name]
        flat, ndim, lead = session.flatten_request(frames)
        degraded = False
        if self._degrade is not None:
            # apply the ladder's dispatch dtype BEFORE key derivation, so
            # a degraded request coalesces with (and compiles as) bf16
            # traffic — the downcast happens here, on the host copy
            wanted = self._degrade.serve_dtype(flat.dtype)
            if wanted != flat.dtype:
                flat = flat.astype(wanted)
                degraded = True
        shape = tuple(int(x) for x in flat.shape[1:])
        n = int(flat.shape[0])
        fut = SRFuture(self)
        if deadline is not None and time.monotonic() >= deadline:
            # dead on arrival: fail before plan derivation, let alone
            # compilation — the caller's clock budget is already spent
            with self._lock:
                self._sched.expired += 1
            fut._finish(exc=DeadlineExceededError(
                "deadline exceeded on submit: the request's budget "
                "elapsed before admission"
            ))
            fut._run_callbacks()
            return fut
        # the request's frame count keys the tuning-DB lookup on a new
        # shape (bucket rounding policy is tuned per batch size)
        plan = session.plan_for(shape, batch_hint=n or None)
        dtype = session.serving_dtype(flat.dtype)
        if n == 0:
            out = jnp.zeros((0, *plan.hr_shape), session.output_dtype(plan, dtype))
            if ndim == 5:
                out = out.reshape(*lead, *plan.hr_shape)
            with self._lock:
                self._sched.note_empty_request()
            fut._finish(result=out)
            return fut
        req = SchedRequest(
            seq=0,  # assigned under the lock below
            key=(name, plan, dtype.name),
            session=session,
            plan=plan,
            flat=flat,
            n=n,
            priority=int(priority),
            future=fut,
            ndim=ndim,
            lead=lead,
            deadline=deadline,
        )
        fut._request = req
        self._admit(req)
        if degraded:
            with self._lock:
                self._degrade.degraded_requests += 1
        return fut

    def submit_bands(self, slabs, bands, *, plan, model: Optional[str] = None,
                     priority: int = 0) -> SRFuture:
        """Queue a partial-band request (the temporal delta path).

        ``slabs`` is a host ``(k, rows, W, C)`` array of per-band input
        slabs in the plan's band-input geometry (``rows = R + 2L`` under
        ``halo``, the ``core.fusion.halo_slabs`` layout; ``R`` rows
        otherwise) and ``bands`` the matching strictly-increasing band
        indices.  The future resolves to the ``(k, R*s, W*s, C)`` HR
        band stack.  Band requests ride the same scheduler as frames
        under a ``"bands"``-suffixed coalescing key (queue units are
        BANDS, so backpressure/expiry/shedding apply unchanged, but a
        band slab never shares a dispatch with a frame).  The degrade
        policy's dtype ladder is deliberately NOT applied: delta
        streams' contract is bit-exactness with full re-upscale, and a
        mid-clip downcast would poison the output cache.
        """
        if self._closed:
            raise RuntimeError("server is closed")
        from repro.engine.temporal.band_diff import band_input_rows

        name = self._resolve_model(model)
        session = self._sessions[name]
        bands = tuple(int(b) for b in bands)
        if not bands:
            raise ValueError("submit_bands needs at least one band")
        if any(b2 <= b1 for b1, b2 in zip(bands, bands[1:])):
            raise ValueError(f"bands must be strictly increasing: {bands}")
        if bands[0] < 0 or bands[-1] >= plan.num_bands:
            raise ValueError(
                f"bands {bands} out of range [0, {plan.num_bands})"
            )
        flat = np.asarray(slabs)
        dtype = session.serving_dtype(flat.dtype)
        flat = np.ascontiguousarray(flat.astype(dtype, copy=False))
        rows = band_input_rows(
            plan.band_rows, plan.num_layers, plan.vertical_policy
        )
        want = (len(bands), rows, plan.width, plan.in_channels)
        if flat.shape != want:
            raise ValueError(
                f"band slabs shape {flat.shape} != expected {want} for "
                f"{len(bands)} band(s) of plan {plan.height}x{plan.width} "
                f"({plan.vertical_policy})"
            )
        fut = SRFuture(self)
        req = SchedRequest(
            seq=0,  # assigned under the lock in _admit
            key=(name, plan, dtype.name, "bands"),
            session=session,
            plan=plan,
            flat=flat,
            n=len(bands),
            priority=int(priority),
            future=fut,
            ndim=4,  # identity assembly: the future gets the raw stack
            lead=None,
            bands=bands,
        )
        fut._request = req
        self._admit(req)
        return fut

    def cancel(self, fut: SRFuture) -> bool:
        """Best-effort cancel of a submitted request (the stream-abandon
        path).  The queued remainder is dropped — releasing any
        carry-pinned bucket — and the future fails with
        :class:`RequestCancelledError`; frames already inside an
        in-flight dispatch complete on-device and are discarded.
        Returns False if the future is already resolved (its result
        stands) or was never admitted."""
        req = fut._request
        if req is None:
            return False
        with self._lock:
            if fut.done():
                return False
            req.failed = True
            self._sched.drop(req)
            fut._finish(exc=RequestCancelledError(
                "request cancelled by its submitter"
            ))
            self._just_finished.append(fut)
            finished = self._take_finished()
        self._run_finished(finished)
        return True

    def _expire_locked(self, now: float) -> None:
        """Cancel queued past-deadline requests (call holding the lock):
        each fails with :class:`DeadlineExceededError` before compiling or
        dispatching; callbacks run via ``_just_finished`` off-lock."""
        for r in self._sched.expire_due(now):
            r.failed = True
            r.future._finish(exc=DeadlineExceededError(
                f"deadline exceeded: {r.n} frames still queued when the "
                "request's deadline passed (never dispatched)"
            ))
            self._just_finished.append(r.future)

    def _admit(self, req: SchedRequest) -> None:
        bound = self.max_inflight_frames
        if bound is not None and req.n > bound:
            raise ValueError(
                f"request of {req.n} frames can never fit "
                f"max_inflight_frames={bound}"
            )
        while True:
            err: Optional[BaseException] = None
            admitted = False
            done = False
            with self._lock:
                # expire due work first: a stale queue must not block or
                # shed live traffic a deadline already freed
                self._expire_locked(time.monotonic())
                queued = self._sched.pending_frames + self._inflight_frames
                if (req.deadline is not None
                        and time.monotonic() >= req.deadline):
                    # the budget elapsed while blocked at admission — the
                    # request expires unqueued, same contract as expiry
                    self._sched.expired += 1
                    req.failed = True
                    req.future._finish(exc=DeadlineExceededError(
                        "deadline exceeded during admission: the queue "
                        "stayed full past the request's budget"
                    ))
                    self._just_finished.append(req.future)
                    done = True
                elif bound is None or queued + req.n <= bound:
                    req.seq = self._sched.next_seq()
                    req.admitted_at = time.monotonic()
                    self._sched.add(req)
                    admitted = True
                elif self.admission == "reject":
                    self._sched.note_rejected()
                    err = QueueFullError(
                        f"queue full: {queued} frames in flight + {req.n} "
                        f"requested > max_inflight_frames={bound}"
                    )
                elif self.admission == "shed":
                    victims = self._sched.shed_victims(
                        queued + req.n - bound,
                        priority=req.priority, deadline=req.deadline,
                    )
                    if victims is None:
                        # nothing queued ranks below the newcomer — IT is
                        # the least-urgent work, so it takes the rejection
                        self._sched.note_rejected()
                        err = QueueFullError(
                            f"queue full: {queued} frames in flight + "
                            f"{req.n} requested > max_inflight_frames="
                            f"{bound}, and no queued work ranks below the "
                            "new request"
                        )
                    else:
                        for v in victims:
                            v.failed = True
                            v.future._finish(exc=RequestShedError(
                                f"shed: {v.n} queued frames (priority "
                                f"{v.priority}) evicted for a priority-"
                                f"{req.priority} request at a full queue"
                            ))
                            self._just_finished.append(v.future)
                        req.seq = self._sched.next_seq()
                        req.admitted_at = time.monotonic()
                        self._sched.add(req)
                        admitted = True
                else:
                    # a full queue implies drainable work (checked under
                    # the SAME lock as the fullness read — another thread
                    # may have drained it by the time our step runs,
                    # which is fine)
                    if not (self._sched.has_pending() or self._inflight
                            or self._completing):
                        raise RuntimeError(
                            "queue full but no work to drain — "
                            "inconsistent scheduler state"
                        )
                finished = self._take_finished()
            self._run_finished(finished)
            if err is not None:
                raise err
            if admitted or done:
                return
            # block policy: make space by draining the queue (outside the
            # lock — _step synchronizes itself), then re-check admission
            self._step()

    # ------------------------------------------------------------------
    # The drain loop
    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Drain everything: dispatch all pending frames and complete all
        in-flight dispatches (their futures resolve)."""
        while self._step():
            pass

    def _drain_until(self, fut: SRFuture,
                     deadline: Optional[float] = None) -> None:
        """Drive the drain until ``fut`` resolves — or until ``deadline``
        (absolute monotonic) passes, in which case this returns with the
        request still queued/in flight and the caller's wait raises."""
        while not fut.done():
            if deadline is not None and time.monotonic() >= deadline:
                return
            if not self._step():
                if fut.done():
                    # a concurrent thread finalized the future between our
                    # done() check and _step() taking the lock — fine
                    return
                raise RuntimeError(
                    "future is not done but the server has no pending "
                    "work — was it issued by this server?"
                )

    def _session_ready(self, session: SRSession) -> bool:
        return self._session_inflight.get(id(session), 0) < session.pipeline_depth

    def _step(self) -> bool:
        """One drain turn: launch the next dispatch if a session has
        pipeline-depth slack, else complete the oldest in-flight one.
        Returns False when there is nothing left to do.

        Synchronizes itself: launches (assembly + async dispatch + any
        cache-miss compile) run under the lock; the device wait of a
        completion runs with the lock RELEASED, counted in
        ``_completing`` so other threads know progress is in flight —
        they wait on the condition instead of reporting starvation, and
        their submits are admitted (and coalesce) meanwhile.  Futures
        finished inside a locked region run their done-callbacks here,
        after the lock is released.  (Known trade vs the old in-session
        loop: a depth-1 session no longer stages chunk t+1 during chunk
        t's device wait — the next dispatch assembles only after the
        completion frees depth slack.)
        """
        inf = None
        progress = True
        with self._cv:
            # cancel past-deadline queued work BEFORE forming a dispatch:
            # an expired request must never reach compilation, and its
            # frames must not inflate the bucket choice
            self._expire_locked(time.monotonic())
            bucket_fn = (self._degrade.bucket_cap
                         if self._degrade is not None else None)
            d = self._sched.next_dispatch(self._session_ready, bucket_fn)
            if d is not None:
                self._launch(d)  # a launch FAILURE finishes futures
            elif self._inflight:
                inf = self._inflight.popleft()
                self._completing += 1
            elif self._completing:
                # another thread is waiting on a completion — progress is
                # theirs to make; sleep until its finalize wakes us
                self._cv.wait()
            else:
                # no dispatch, nothing in flight: this turn made progress
                # only if expiry just finished futures
                progress = bool(self._just_finished)
            finished = self._take_finished()
        if inf is None:
            self._run_finished(finished)
            return progress
        self._run_finished(finished)
        error: Optional[BaseException] = None
        try:
            jax.block_until_ready(inf.hr)  # off-lock device wait
        except BaseException as e:  # deferred device-side failure
            error = e
        with self._cv:
            try:
                self._finalize_complete(inf, error)
            finally:
                self._completing -= 1
                self._cv.notify_all()
            finished = self._take_finished()
        self._run_finished(finished)
        return True

    def _take_finished(self) -> list:
        finished, self._just_finished = self._just_finished, []
        return finished

    @staticmethod
    def _run_finished(finished: list) -> None:
        for fut in finished:
            fut._run_callbacks()

    def _launch(self, d: Dispatch) -> None:
        session: SRSession = d.session
        try:
            # executor resolution may compile — on a dummy, before the
            # timed dispatch starts, exactly like the pre-server path
            if d.band_subset is not None:
                entry, _ = session.band_executor_for(
                    d.plan, d.bucket, np.dtype(d.key[2])
                )
            else:
                entry, _ = session.executor_for(
                    d.plan, d.bucket, np.dtype(d.key[2])
                )
            if self._injector is not None:
                # fault-injection point (tests/load harness): a raise here
                # flows through _fail_dispatch below — exactly this
                # dispatch's requests fail, everything else keeps serving
                self._injector.on_dispatch(
                    model=d.key[0], replica=getattr(entry, "replica", None)
                )
            if d.band_subset is not None:
                slab, bounds = self._assemble_bands(d)
                used_staging = False
                t0 = time.perf_counter()
                hr = entry.fn(slab, bounds)  # async dispatch
            else:
                slab, used_staging = self._assemble(d, entry.donates)
                t0 = time.perf_counter()
                hr = entry.fn(slab)  # async dispatch: returns immediately
            session._dispatch_ms.append((time.perf_counter() - t0) * 1e3)
        except BaseException as e:
            self._fail_dispatch(d, e)
            return
        # mesh serving: credit the routing decision — the scheduler's
        # replica counters and the router's live load both key off it
        d.replica = getattr(entry, "replica", None)
        if d.replica is not None:
            self._sched.note_routed(d.replica)
            if session._router is not None:
                session._router.note_launch(d.replica, d.real)
        sid = id(session)
        count = self._session_inflight.get(sid, 0)
        if count == 0:
            self._window_start[sid] = t0
        self._session_inflight[sid] = count + 1
        session._peak_inflight = max(session._peak_inflight, count + 1)
        self._inflight_frames += d.real
        if used_staging:
            self._staging_busy[sid] = self._staging_busy.get(sid, 0) + 1
        self._inflight.append(_Inflight(d, hr, t0, used_staging))

    def _assemble(self, d: Dispatch, donates: bool):
        """Build the bucket-sized device slab from the dispatch's tickets;
        returns ``(slab, used_shared_staging)``.

        All-host tickets go through the session's reused staging buffer
        (zero fresh bucket allocations per ragged dispatch) and one
        ``jax.device_put`` — unless an in-flight dispatch is still using
        that buffer (overlapped host dispatches), in which case a fresh
        buffer keeps the earlier H2D copy safe.  Device tickets use a
        single fused ``jnp.pad`` or ``jnp.concatenate``.  Under donation
        the returned slab is always server-owned: a full-cover slice that
        would hand back a caller's own array object is copied first.
        """
        session: SRSession = d.session
        tickets = d.tickets
        real = d.real
        if all(isinstance(t.request.flat, np.ndarray) for t in tickets):
            first = tickets[0]
            if len(tickets) == 1 and real == d.bucket:
                src = first.request.flat
                return jax.device_put(src[first.start:first.start + first.n]), False
            frame_shape = first.request.flat.shape[1:]
            dtype = first.request.flat.dtype
            shared = not self._staging_busy.get(id(session), 0)
            if shared:
                buf = session._staging_for(d.bucket, frame_shape, dtype)
            else:
                buf = np.zeros((d.bucket, *frame_shape), dtype)
            for t in tickets:
                buf[t.slot:t.slot + t.n] = t.request.flat[t.start:t.start + t.n]
            buf[real:] = 0
            return jax.device_put(buf), shared
        pieces = [t.request.flat[t.start:t.start + t.n] for t in tickets]
        if len(pieces) == 1:
            chunk = pieces[0]
            if isinstance(chunk, np.ndarray):
                chunk = jnp.asarray(chunk)
            if real < d.bucket:
                pad = [(0, d.bucket - real)] + [(0, 0)] * (chunk.ndim - 1)
                return jnp.pad(chunk, pad), False
            if donates and chunk is tickets[0].request.flat:
                # a full-cover slice is the SAME array object in jax;
                # donating it would consume the caller's buffer
                chunk = jnp.array(chunk)
            return chunk, False
        if real < d.bucket:
            pieces.append(jnp.zeros((d.bucket - real, *pieces[0].shape[1:]),
                                    pieces[0].dtype))
        return jnp.concatenate(pieces, axis=0), False

    def _assemble_bands(self, d: Dispatch):
        """Build a band dispatch's ``(slab, bounds)`` device pair.

        Band slabs always stage through a fresh host buffer — never the
        session's shared staging buffer, whose shape bookkeeping is
        per-frame — and the per-slot valid-row bounds are derived
        statically from the dispatched band indices (the same
        ``halo_slabs`` clip formula; ``band_diff.band_bounds`` is its
        host mirror).  Padded slots keep ``(0, 0)``: every row phantom,
        so a padding slab computes zero features and its HR rows are
        never read back.
        """
        from repro.engine.temporal.band_diff import band_bounds

        plan = d.plan
        first = d.tickets[0].request.flat
        buf = np.zeros((d.bucket, *first.shape[1:]), first.dtype)
        for t in d.tickets:
            buf[t.slot:t.slot + t.n] = t.request.flat[t.start:t.start + t.n]
        bounds = band_bounds(
            plan.height, plan.band_rows, plan.num_layers, d.band_subset,
            slots=d.bucket,
        )
        return jax.device_put(buf), jax.device_put(bounds)

    def _finalize_complete(self, inf: _Inflight,
                           error: Optional[BaseException]) -> None:
        """Bookkeeping for a completed (or device-failed) dispatch — runs
        under the lock, after the off-lock ``block_until_ready``."""
        d, session = inf.dispatch, inf.dispatch.session
        sid = id(session)
        now = time.perf_counter()
        # release the replica's in-flight slot FIRST — device failures must
        # not leave a replica looking permanently loaded
        if d.replica is not None and session._router is not None:
            session._router.note_complete(d.replica)
        self._inflight_frames -= d.real
        self._session_inflight[sid] -= 1
        if self._session_inflight[sid] == 0:
            session._span_s += now - self._window_start.pop(sid)
        if inf.used_staging:
            self._staging_busy[sid] -= 1
        if error is not None:
            self._fail_dispatch(d, error)
            return
        session._complete_ms.append((now - inf.t0) * 1e3)
        if d.band_subset is None:
            session._frames += d.real
        else:
            # partial-band traffic: counted in band-rows of compute, not
            # frames — the temporal stats' reuse accounting keys off this
            session._band_rows_served += d.real * d.plan.band_rows
            session._band_dispatches += 1
        for t in d.tickets:
            r = t.request
            if r.failed:
                continue
            # keyed by the ticket's offset: concurrent drains may finalize
            # a long request's dispatches out of order
            r.pieces.append((t.start, inf.hr[t.slot:t.slot + t.n]))
            r.completed += t.n
            if r.completed == r.n:
                self._finish_request(r)

    def _finish_request(self, req: SchedRequest) -> None:
        pieces = [p for _, p in sorted(req.pieces, key=lambda sp: sp[0])]
        out = pieces[0] if len(pieces) == 1 else jnp.concatenate(
            pieces, axis=0)
        req.pieces = []
        if req.ndim == 3:
            out = out[0]
        elif req.ndim == 5:
            out = out.reshape(*req.lead, *req.plan.hr_shape)
        req.future._finish(result=out)
        self._just_finished.append(req.future)
        if self._degrade is not None and req.admitted_at:
            # end-to-end latency (admission -> resolution) is the pressure
            # signal: unlike per-dispatch latency it sees queue delay,
            # which is what overload actually inflates
            self._degrade.observe((time.monotonic() - req.admitted_at) * 1e3)

    def _fail_dispatch(self, d: Dispatch, exc: BaseException) -> None:
        """A dispatch failed (build, launch or device error): fail every
        involved request's future and drop their queued remainders — other
        keys keep serving."""
        for r in d.requests:
            if r.failed:
                continue
            r.failed = True
            self._sched.drop(r)
            r.future._finish(exc=exc)
            self._just_finished.append(r.future)

    # ------------------------------------------------------------------
    # Streaming
    # ------------------------------------------------------------------
    async def stream(self, frames, *, model: Optional[str] = None,
                     priority: int = 0, lookahead: int = 4,
                     delta: bool = False,
                     cache_bytes: Optional[int] = None):
        """Serve an iterable of frames one at a time; yields HR frames in
        order (an async generator — ``async for hr in server.stream(...)``).

        ``lookahead`` frames are submitted ahead of the one being awaited,
        which keeps the micro-batcher's queue non-empty: a single stream
        coalesces its own lookahead window into full buckets, and
        concurrent streams share dispatches with each other.  Waiting
        happens off the event loop (``asyncio.to_thread``), so multiple
        streams interleave.  Under an active :class:`DegradePolicy` at
        level >= 2 the window is halved — re-read each turn, so a
        mid-stream transition takes effect on the next frame.

        ``delta=True`` serves the clip through a
        :class:`~repro.engine.temporal.DeltaSession`: each frame is
        band-diffed against the previous one, only dirty bands dispatch
        (as partial-band dispatches), and clean bands splice from the
        session's output cache — bit-exact vs full re-upscale.  Delta
        streams are sequential by construction (frame k's dirty set
        needs frame k-1's digests), so ``lookahead`` does not apply;
        ``cache_bytes`` bounds the output cache.  Abandoning either kind
        of stream (closing the generator mid-clip) cancels its pending
        requests and releases its cache pins — no carry bucket or
        refcount leaks.
        """
        import asyncio

        if delta:
            from repro.engine.temporal import DeltaSession

            ds = DeltaSession(self.session(model), server=self,
                              priority=priority, cache_bytes=cache_bytes)
            try:
                for frame in frames:
                    yield await asyncio.to_thread(ds.serve, frame)
            finally:
                ds.close()
            return

        base = max(1, int(lookahead))
        pending: Deque[SRFuture] = deque()
        it = iter(frames)
        exhausted = False
        try:
            while pending or not exhausted:
                window = (self._degrade.lookahead(base)
                          if self._degrade is not None else base)
                while not exhausted and len(pending) < window:
                    try:
                        frame = next(it)
                    except StopIteration:
                        exhausted = True
                        break
                    # submit off the loop too: with a full bounded queue
                    # and admission="block" it drains (device waits)
                    # until space
                    pending.append(await asyncio.to_thread(
                        self.submit, frame, model=model, priority=priority))
                if pending:
                    fut = pending.popleft()
                    yield await asyncio.to_thread(fut.result)
        finally:
            # abandoned mid-clip: drop the lookahead window's queued
            # frames so they don't dispatch (or pin a carry bucket) for
            # a consumer that is gone
            while pending:
                self.cancel(pending.popleft())

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    def close(self) -> None:
        """Drain outstanding work, refuse further submits, and release
        the hosted sessions so a successor server may host them (their
        compile caches carry over; the load harness leans on this to
        reuse warm sessions across server configurations)."""
        self.flush()
        self._closed = True
        for s in self._sessions.values():
            if s._server is self:
                s._server = None

    def __enter__(self) -> "SRServer":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()
