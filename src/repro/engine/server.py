"""SRServer — the request/future serving front door over SRSessions.

``SRSession.upscale`` is caller-batched and blocking: every request pays
its own padded bucket and two concurrent half-bucket requests can never
share a dispatch.  ``SRServer`` moves admission and batching into the
engine, the way the block-streaming schedulers of ACNPU/BSRA own their
datapath's work queue:

* ``SRServer.open("abpn_x3", ...)`` hosts one or more named
  :class:`~repro.engine.session.SRSession`\\ s (multi-model traffic routes
  through each session's own ``PlanCache``/``PreparedStack`` machinery).
* ``server.submit(frames, model=..., priority=...)`` validates and queues
  a request and returns an :class:`SRFuture` immediately; requests that
  share a ``(model, plan, dtype)`` key are COALESCED by the
  :class:`~repro.engine.scheduler.MicroBatchScheduler` into bucket-sized
  dispatches — concurrent small requests fill one power-of-two bucket with
  real frames instead of each padding its own.
* ``async for hr in server.stream(frames)`` serves frame-at-a-time live
  video: each frame is submitted (a small lookahead keeps the coalescer
  fed) and HR frames are yielded in order; concurrent streams share
  dispatches.
* ``max_inflight_frames`` bounds the queue (pending + dispatched frames);
  at the bound, ``admission="block"`` drains the queue to make space and
  ``admission="reject"`` raises
  :class:`~repro.engine.scheduler.QueueFullError`.

Execution is the PIPELINED drain loop that previously lived inside
``SRSession``: each dispatch is assembled (host frames through the
session's one reused staging buffer, device frames through a fused pad /
concatenate), launched asynchronously, and completed in order, with up to
``session.pipeline_depth`` dispatches in flight per session.  Latency,
span and peak-inflight numbers are recorded on the owning session —
``session.stats()`` means the same thing whether a batch arrived through
``upscale``, ``submit`` or a stream.  Dispatch formation runs under one
server lock, but device waits release it: ``SRFuture.result()`` from any
thread drives the drain, and while one thread blocks on the device other
threads' submits are admitted — and coalesce into the next dispatch.

``SRSession.upscale`` is now a thin synchronous shim over
``session.submit(frames).result()`` — routed through the server hosting
the session (one scheduler and one lock govern all traffic into it), or
through an embedded single-model server when none does — so the blocking
API and the future API are the same code path.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Deque, Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.scheduler import (
    Dispatch,
    MicroBatchScheduler,
    QueueFullError,
    SchedRequest,
)
from repro.engine.session import SRSession

__all__ = ["SRServer", "SRFuture", "QueueFullError"]

ADMISSION_POLICIES = ("block", "reject")


class SRFuture:
    """The result handle ``SRServer.submit`` returns.

    ``result()`` drives the server's drain loop until this request's
    frames are served (so a single-threaded caller needs no background
    worker), then returns the HR array in the request's original rank —
    or re-raises the error that failed the dispatch.  Thread-safe: any
    number of threads may wait; whoever gets the server lock drains,
    the rest block until notified.
    """

    def __init__(self, server: "SRServer"):
        self._server = server
        self._cond = threading.Condition()
        self._done = False
        self._result = None
        self._exc: Optional[BaseException] = None
        self._callbacks = []

    def done(self) -> bool:
        return self._done

    def _wait_done(self, timeout: Optional[float]) -> None:
        """Drive the drain, then wait (bounded) for completion.

        ``timeout`` bounds only the *wait* for another thread's drain to
        finish the request — a drain this call performs itself runs to
        completion.
        """
        if not self._done:
            self._server._drain_until(self)
        with self._cond:
            if not self._done:
                self._cond.wait(timeout)
            if not self._done:
                raise TimeoutError("request not complete within timeout")

    def result(self, timeout: Optional[float] = None):
        """The request's HR output (blocking; drives the server's drain),
        or re-raises the error that failed the request."""
        self._wait_done(timeout)
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        """The error that failed this request, or ``None`` (blocking; a
        stored failure is RETURNED — even a ``TimeoutError`` raised by the
        dispatch — while an unfinished wait raises ``TimeoutError``)."""
        self._wait_done(timeout)
        return self._exc

    def add_done_callback(self, fn) -> None:
        """Run ``fn(self)`` when the future resolves (immediately if it
        already has).  Callbacks run on the draining thread, OUTSIDE the
        server lock — a callback may submit follow-up work or wait on
        other futures without deadlocking."""
        with self._cond:
            if not self._done:
                self._callbacks.append(fn)
                return
        fn(self)

    def _finish(self, result=None, exc: Optional[BaseException] = None) -> None:
        """Set the outcome and wake waiters.  Callbacks are NOT run here —
        this executes under the server lock; the server runs
        :meth:`_run_callbacks` after releasing it."""
        with self._cond:
            self._result = result
            self._exc = exc
            self._done = True
            self._cond.notify_all()

    def _run_callbacks(self) -> None:
        with self._cond:
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            fn(self)


class _Inflight:
    """One launched dispatch: the async HR handle plus its timing and
    whether it staged through the session's shared host buffer."""

    __slots__ = ("dispatch", "hr", "t0", "used_staging")

    def __init__(self, dispatch: Dispatch, hr, t0: float, used_staging: bool):
        self.dispatch = dispatch
        self.hr = hr
        self.t0 = t0
        self.used_staging = used_staging


class SRServer:
    """One serving endpoint hosting named sessions behind a micro-batcher.

    ``sessions`` maps model names to :class:`SRSession`\\ s (a bare session
    is accepted and hosted under its model name).  ``default_model`` is the
    target when ``submit`` is called without ``model=`` (defaults to the
    first session).  ``max_inflight_frames`` bounds pending + dispatched
    frames; ``admission`` picks the full-queue behavior (``"block"`` drains
    to make space, ``"reject"`` raises :class:`QueueFullError`).
    """

    def __init__(
        self,
        sessions: Union[SRSession, Mapping[str, SRSession]],
        *,
        default_model: Optional[str] = None,
        max_inflight_frames: Optional[int] = None,
        admission: str = "block",
    ):
        if isinstance(sessions, SRSession):
            sessions = {sessions.model or "default": sessions}
        sessions = dict(sessions)
        if not sessions:
            raise ValueError("SRServer needs at least one session")
        for name, s in sessions.items():
            if not isinstance(name, str):
                raise ValueError(f"model name {name!r} must be a string")
            if not isinstance(s, SRSession):
                raise ValueError(
                    f"model {name!r} must map to an SRSession, got {type(s).__name__}"
                )
        if max_inflight_frames is not None and max_inflight_frames < 1:
            raise ValueError(
                f"max_inflight_frames={max_inflight_frames} must be >= 1 "
                "(or None for an unbounded queue)"
            )
        if admission not in ADMISSION_POLICIES:
            raise ValueError(
                f"admission {admission!r} not in {ADMISSION_POLICIES}"
            )
        if default_model is None:
            default_model = next(iter(sessions))
        if default_model not in sessions:
            raise ValueError(
                f"default_model {default_model!r} not among hosted models "
                f"{sorted(sessions)}"
            )
        self._sessions = sessions
        self._default = default_model
        self.max_inflight_frames = max_inflight_frames
        self.admission = admission
        # hosted sessions route their own submit()/upscale() through THIS
        # server, so one lock + one scheduler govern all traffic into the
        # session; a SECOND front door over the same mutable session state
        # (staging buffer, caches, stats) would race it, so hosting an
        # already-served session is an error rather than a silent hazard
        for s in sessions.values():
            if s._server is None:
                s._server = self
            elif s._server is not self:
                raise ValueError(
                    "session is already served by another SRServer (its "
                    "upscale()/submit() traffic routes there); host each "
                    "session in exactly one server — construct the hosting "
                    "server before serving through the session directly"
                )
        self._sched = MicroBatchScheduler()
        # one lock guards scheduler + inflight state; the condition lets a
        # thread RELEASE it while blocking on the device (completions in
        # progress are counted in _completing and waited on via the cv),
        # so concurrent submits are admitted — and coalesce — while a
        # drain is waiting on compute
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._completing = 0  # dispatches being block_until_ready'd off-lock
        self._inflight: Deque[_Inflight] = deque()
        self._inflight_frames = 0  # dispatched, not yet complete (real)
        self._session_inflight: Dict[int, int] = {}
        self._window_start: Dict[int, float] = {}
        # per-session count of in-flight dispatches staged through the
        # session's SHARED host buffer: while one is outstanding, the next
        # host dispatch stages through a fresh buffer instead — the H2D
        # copy of dispatch t may still be reading the buffer when t+1
        # assembles (a hazard only on overlapped host dispatches)
        self._staging_busy: Dict[int, int] = {}
        # futures finished inside a locked region, whose done-callbacks
        # still need to run once the lock is released
        self._just_finished: list = []
        self._closed = False

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------
    @classmethod
    def open(
        cls,
        *models: str,
        default_model: Optional[str] = None,
        max_inflight_frames: Optional[int] = None,
        admission: str = "block",
        seed: int = 0,
        autotune: Union[str, Mapping[str, str], None] = None,
        **session_kwargs,
    ) -> "SRServer":
        """Open a server hosting registered SR models by name.

        Each name resolves through ``repro.models.registry``
        (``list_sr_models()`` enumerates them); ``session_kwargs``
        (backend, precision, pipeline_depth, max_bucket, ...) apply to
        every hosted session.  ``autotune`` sets each session's schedule
        policy (``"off"`` | ``"cached"`` | ``"full"`` — see
        ``session.AUTOTUNE_MODES``): a single string applies to every
        hosted model, a mapping sets it per model name (unnamed models
        keep the session default).  With no names, hosts the paper's
        ``abpn_x3``.
        """
        names = models or ("abpn_x3",)

        def _kwargs_for(name: str) -> dict:
            kw = dict(session_kwargs)
            if isinstance(autotune, Mapping):
                if name in autotune:
                    kw["autotune"] = autotune[name]
            elif autotune is not None:
                kw["autotune"] = autotune
            return kw

        sessions = {
            name: SRSession.open(name, seed=seed, **_kwargs_for(name))
            for name in names
        }
        return cls(
            sessions,
            default_model=default_model,
            max_inflight_frames=max_inflight_frames,
            admission=admission,
        )

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def models(self) -> Tuple[str, ...]:
        return tuple(self._sessions)

    def session(self, model: Optional[str] = None) -> SRSession:
        """The hosted session serving ``model`` (default model if None)."""
        return self._sessions[self._resolve_model(model)]

    def scheduler_stats(self) -> dict:
        """The micro-batcher's coalescing/queue counters plus the server's
        in-flight state (see ``MicroBatchScheduler.stats``)."""
        with self._lock:
            stats = self._sched.stats()
            stats["inflight_dispatches"] = len(self._inflight)
            stats["inflight_frames"] = self._inflight_frames
            stats["recent_dispatches"] = list(self._sched.recent_dispatches)
        return stats

    def stats(self) -> dict:
        """Scheduler counters plus each hosted session's serving stats."""
        return {
            "scheduler": self.scheduler_stats(),
            "models": {
                name: dict(s.stats()) for name, s in self._sessions.items()
            },
        }

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    def _resolve_model(self, model: Optional[str]) -> str:
        name = self._default if model is None else model
        if name not in self._sessions:
            raise ValueError(
                f"unknown model {name!r}; this server hosts {sorted(self._sessions)}"
            )
        return name

    def submit_for(self, session: SRSession, frames, *, priority: int = 0) -> SRFuture:
        """Submit addressed by hosted session identity rather than name —
        what ``SRSession.submit`` calls on its hosting server."""
        for name, s in self._sessions.items():
            if s is session:
                return self.submit(frames, model=name, priority=priority)
        raise ValueError("session is not hosted by this server")

    def submit(self, frames, *, model: Optional[str] = None, priority: int = 0) -> SRFuture:
        """Queue a request; returns its :class:`SRFuture` immediately.

        ``frames`` is any rank ``upscale`` accepts (``(H, W, C)``,
        ``(T, H, W, C)``, ``(B, T, H, W, C)``); validation (array-ness,
        numeric dtype, rank, channel count) happens HERE, synchronously,
        so malformed input fails with a clear ``ValueError`` instead of
        surfacing from plan derivation or compilation.  Higher
        ``priority`` keys dispatch first.  The actual dispatch runs when
        the drain loop next turns over (``result()``/``flush()``/a
        concurrent waiter), coalescing whatever compatible requests are
        queued by then.
        """
        if self._closed:
            raise RuntimeError("server is closed")
        name = self._resolve_model(model)
        session = self._sessions[name]
        flat, ndim, lead = session.flatten_request(frames)
        shape = tuple(int(x) for x in flat.shape[1:])
        n = int(flat.shape[0])
        # the request's frame count keys the tuning-DB lookup on a new
        # shape (bucket rounding policy is tuned per batch size)
        plan = session.plan_for(shape, batch_hint=n or None)
        dtype = session.serving_dtype(flat.dtype)
        fut = SRFuture(self)
        if n == 0:
            out = jnp.zeros((0, *plan.hr_shape), session.output_dtype(plan, dtype))
            if ndim == 5:
                out = out.reshape(*lead, *plan.hr_shape)
            with self._lock:
                self._sched.note_empty_request()
            fut._finish(result=out)
            return fut
        req = SchedRequest(
            seq=0,  # assigned under the lock below
            key=(name, plan, dtype.name),
            session=session,
            plan=plan,
            flat=flat,
            n=n,
            priority=int(priority),
            future=fut,
            ndim=ndim,
            lead=lead,
        )
        self._admit(req)
        return fut

    def _admit(self, req: SchedRequest) -> None:
        bound = self.max_inflight_frames
        if bound is not None and req.n > bound:
            raise ValueError(
                f"request of {req.n} frames can never fit "
                f"max_inflight_frames={bound}"
            )
        while True:
            with self._lock:
                queued = self._sched.pending_frames + self._inflight_frames
                if bound is None or queued + req.n <= bound:
                    req.seq = self._sched.next_seq()
                    self._sched.add(req)
                    return
                if self.admission == "reject":
                    self._sched.note_rejected()
                    raise QueueFullError(
                        f"queue full: {queued} frames in flight + {req.n} "
                        f"requested > max_inflight_frames={bound}"
                    )
                # a full queue implies drainable work (checked under the
                # SAME lock as the fullness read — another thread may have
                # drained it by the time our step runs, which is fine)
                if not (self._sched.has_pending() or self._inflight
                        or self._completing):
                    raise RuntimeError(
                        "queue full but no work to drain — inconsistent "
                        "scheduler state"
                    )
            # block policy: make space by draining the queue (outside the
            # lock — _step synchronizes itself), then re-check admission
            self._step()

    # ------------------------------------------------------------------
    # The drain loop
    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Drain everything: dispatch all pending frames and complete all
        in-flight dispatches (their futures resolve)."""
        while self._step():
            pass

    def _drain_until(self, fut: SRFuture) -> None:
        while not fut.done():
            if not self._step():
                if fut.done():
                    # a concurrent thread finalized the future between our
                    # done() check and _step() taking the lock — fine
                    return
                raise RuntimeError(
                    "future is not done but the server has no pending "
                    "work — was it issued by this server?"
                )

    def _session_ready(self, session: SRSession) -> bool:
        return self._session_inflight.get(id(session), 0) < session.pipeline_depth

    def _step(self) -> bool:
        """One drain turn: launch the next dispatch if a session has
        pipeline-depth slack, else complete the oldest in-flight one.
        Returns False when there is nothing left to do.

        Synchronizes itself: launches (assembly + async dispatch + any
        cache-miss compile) run under the lock; the device wait of a
        completion runs with the lock RELEASED, counted in
        ``_completing`` so other threads know progress is in flight —
        they wait on the condition instead of reporting starvation, and
        their submits are admitted (and coalesce) meanwhile.  Futures
        finished inside a locked region run their done-callbacks here,
        after the lock is released.  (Known trade vs the old in-session
        loop: a depth-1 session no longer stages chunk t+1 during chunk
        t's device wait — the next dispatch assembles only after the
        completion frees depth slack.)
        """
        inf = None
        with self._cv:
            d = self._sched.next_dispatch(self._session_ready)
            if d is not None:
                self._launch(d)  # a launch FAILURE finishes futures
                finished = self._take_finished()
            elif self._inflight:
                inf = self._inflight.popleft()
                self._completing += 1
                finished = []
            elif self._completing:
                # another thread is waiting on a completion — progress is
                # theirs to make; sleep until its finalize wakes us
                self._cv.wait()
                return True
            else:
                return False
        if inf is None:
            self._run_finished(finished)
            return True
        error: Optional[BaseException] = None
        try:
            jax.block_until_ready(inf.hr)  # off-lock device wait
        except BaseException as e:  # deferred device-side failure
            error = e
        with self._cv:
            try:
                self._finalize_complete(inf, error)
            finally:
                self._completing -= 1
                self._cv.notify_all()
            finished = self._take_finished()
        self._run_finished(finished)
        return True

    def _take_finished(self) -> list:
        finished, self._just_finished = self._just_finished, []
        return finished

    @staticmethod
    def _run_finished(finished: list) -> None:
        for fut in finished:
            fut._run_callbacks()

    def _launch(self, d: Dispatch) -> None:
        session: SRSession = d.session
        try:
            # executor resolution may compile — on a dummy, before the
            # timed dispatch starts, exactly like the pre-server path
            entry, _ = session.executor_for(d.plan, d.bucket, np.dtype(d.key[2]))
            slab, used_staging = self._assemble(d, entry.donates)
            t0 = time.perf_counter()
            hr = entry.fn(slab)  # async dispatch: returns immediately
            session._dispatch_ms.append((time.perf_counter() - t0) * 1e3)
        except BaseException as e:
            self._fail_dispatch(d, e)
            return
        # mesh serving: credit the routing decision — the scheduler's
        # replica counters and the router's live load both key off it
        d.replica = getattr(entry, "replica", None)
        if d.replica is not None:
            self._sched.note_routed(d.replica)
            if session._router is not None:
                session._router.note_launch(d.replica, d.real)
        sid = id(session)
        count = self._session_inflight.get(sid, 0)
        if count == 0:
            self._window_start[sid] = t0
        self._session_inflight[sid] = count + 1
        session._peak_inflight = max(session._peak_inflight, count + 1)
        self._inflight_frames += d.real
        if used_staging:
            self._staging_busy[sid] = self._staging_busy.get(sid, 0) + 1
        self._inflight.append(_Inflight(d, hr, t0, used_staging))

    def _assemble(self, d: Dispatch, donates: bool):
        """Build the bucket-sized device slab from the dispatch's tickets;
        returns ``(slab, used_shared_staging)``.

        All-host tickets go through the session's reused staging buffer
        (zero fresh bucket allocations per ragged dispatch) and one
        ``jax.device_put`` — unless an in-flight dispatch is still using
        that buffer (overlapped host dispatches), in which case a fresh
        buffer keeps the earlier H2D copy safe.  Device tickets use a
        single fused ``jnp.pad`` or ``jnp.concatenate``.  Under donation
        the returned slab is always server-owned: a full-cover slice that
        would hand back a caller's own array object is copied first.
        """
        session: SRSession = d.session
        tickets = d.tickets
        real = d.real
        if all(isinstance(t.request.flat, np.ndarray) for t in tickets):
            first = tickets[0]
            if len(tickets) == 1 and real == d.bucket:
                src = first.request.flat
                return jax.device_put(src[first.start:first.start + first.n]), False
            frame_shape = first.request.flat.shape[1:]
            dtype = first.request.flat.dtype
            shared = not self._staging_busy.get(id(session), 0)
            if shared:
                buf = session._staging_for(d.bucket, frame_shape, dtype)
            else:
                buf = np.zeros((d.bucket, *frame_shape), dtype)
            for t in tickets:
                buf[t.slot:t.slot + t.n] = t.request.flat[t.start:t.start + t.n]
            buf[real:] = 0
            return jax.device_put(buf), shared
        pieces = [t.request.flat[t.start:t.start + t.n] for t in tickets]
        if len(pieces) == 1:
            chunk = pieces[0]
            if isinstance(chunk, np.ndarray):
                chunk = jnp.asarray(chunk)
            if real < d.bucket:
                pad = [(0, d.bucket - real)] + [(0, 0)] * (chunk.ndim - 1)
                return jnp.pad(chunk, pad), False
            if donates and chunk is tickets[0].request.flat:
                # a full-cover slice is the SAME array object in jax;
                # donating it would consume the caller's buffer
                chunk = jnp.array(chunk)
            return chunk, False
        if real < d.bucket:
            pieces.append(jnp.zeros((d.bucket - real, *pieces[0].shape[1:]),
                                    pieces[0].dtype))
        return jnp.concatenate(pieces, axis=0), False

    def _finalize_complete(self, inf: _Inflight,
                           error: Optional[BaseException]) -> None:
        """Bookkeeping for a completed (or device-failed) dispatch — runs
        under the lock, after the off-lock ``block_until_ready``."""
        d, session = inf.dispatch, inf.dispatch.session
        sid = id(session)
        now = time.perf_counter()
        # release the replica's in-flight slot FIRST — device failures must
        # not leave a replica looking permanently loaded
        if d.replica is not None and session._router is not None:
            session._router.note_complete(d.replica)
        self._inflight_frames -= d.real
        self._session_inflight[sid] -= 1
        if self._session_inflight[sid] == 0:
            session._span_s += now - self._window_start.pop(sid)
        if inf.used_staging:
            self._staging_busy[sid] -= 1
        if error is not None:
            self._fail_dispatch(d, error)
            return
        session._complete_ms.append((now - inf.t0) * 1e3)
        session._frames += d.real
        for t in d.tickets:
            r = t.request
            if r.failed:
                continue
            # keyed by the ticket's offset: concurrent drains may finalize
            # a long request's dispatches out of order
            r.pieces.append((t.start, inf.hr[t.slot:t.slot + t.n]))
            r.completed += t.n
            if r.completed == r.n:
                self._finish_request(r)

    def _finish_request(self, req: SchedRequest) -> None:
        pieces = [p for _, p in sorted(req.pieces, key=lambda sp: sp[0])]
        out = pieces[0] if len(pieces) == 1 else jnp.concatenate(
            pieces, axis=0)
        req.pieces = []
        if req.ndim == 3:
            out = out[0]
        elif req.ndim == 5:
            out = out.reshape(*req.lead, *req.plan.hr_shape)
        req.future._finish(result=out)
        self._just_finished.append(req.future)

    def _fail_dispatch(self, d: Dispatch, exc: BaseException) -> None:
        """A dispatch failed (build, launch or device error): fail every
        involved request's future and drop their queued remainders — other
        keys keep serving."""
        for r in d.requests:
            if r.failed:
                continue
            r.failed = True
            self._sched.drop(r)
            r.future._finish(exc=exc)
            self._just_finished.append(r.future)

    # ------------------------------------------------------------------
    # Streaming
    # ------------------------------------------------------------------
    async def stream(self, frames, *, model: Optional[str] = None,
                     priority: int = 0, lookahead: int = 4):
        """Serve an iterable of frames one at a time; yields HR frames in
        order (an async generator — ``async for hr in server.stream(...)``).

        ``lookahead`` frames are submitted ahead of the one being awaited,
        which keeps the micro-batcher's queue non-empty: a single stream
        coalesces its own lookahead window into full buckets, and
        concurrent streams share dispatches with each other.  Waiting
        happens off the event loop (``asyncio.to_thread``), so multiple
        streams interleave.
        """
        import asyncio

        pending: Deque[SRFuture] = deque()
        it = iter(frames)
        exhausted = False
        while pending or not exhausted:
            while not exhausted and len(pending) < max(1, int(lookahead)):
                try:
                    frame = next(it)
                except StopIteration:
                    exhausted = True
                    break
                # submit off the loop too: with a full bounded queue and
                # admission="block" it drains (device waits) until space
                pending.append(await asyncio.to_thread(
                    self.submit, frame, model=model, priority=priority))
            if pending:
                fut = pending.popleft()
                yield await asyncio.to_thread(fut.result)

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    def close(self) -> None:
        """Drain outstanding work and refuse further submits."""
        self.flush()
        self._closed = True

    def __enter__(self) -> "SRServer":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()
