"""SRSession — shape/batch/model-agnostic serving over a compile cache.

The paper's accelerator serves ONE fixed pipeline (1080p x3 at 60 fps);
production traffic is heterogeneous: mixed resolutions, clip lengths,
batch sizes and dtypes.  ``SRSession`` is the serving front door that
absorbs that heterogeneity:

* ``SRSession.open("abpn_x3", backend=..., precision=...)`` resolves the
  model's config + weights through ``repro.models.registry``.
* ``session.upscale(frames)`` accepts ``(H, W, C)``, ``(T, H, W, C)`` or
  ``(B, T, H, W, C)`` input.  Per new resolution it derives the
  :class:`~repro.engine.plan.SRPlan` (including a legal ``band_rows`` for
  the incoming height — ``SRPlan.from_request``), buckets the flattened
  batch up to a power of two, and compiles one executor per
  ``(plan, bucket, dtype)`` on demand.
* Compiled executors live in an LRU :class:`PlanCache`; hit/miss/evict
  counters and per-entry compile times are exposed via
  :meth:`SRSession.cache_stats`.

Serving is PIPELINED — the software analogue of the paper's ping-pong
line buffers:

* Weights are prepared (quantised / cast / kernel-packed) ONCE per session
  into a device-resident :class:`~repro.engine.executor.PreparedStack`
  (refcounted across cache entries, released when the last entry using it
  is evicted), so no per-batch jitted call re-runs weight prep.
* Multi-bucket requests dispatch up to ``pipeline_depth`` chunks
  asynchronously (depth 2 by default — double buffering): while the device
  computes chunk *t*, chunk *t+1* is staged (``jax.device_put`` for host
  frames, one reused tail-padding buffer) and enqueued; blocking happens
  only when the pipeline is full and at the tail.
* ``donate_frames`` compiles executors with the frame batch donated, so
  XLA can recycle the bucket-sized slab for same-sized intermediates and
  release it at its last use instead of pinning it for the whole call —
  the HR output is ``scale^2`` x larger, so it never aliases the input
  (auto: on for accelerator backends, off on CPU where XLA does not
  implement donation).
  Donated inputs are CONSUMED — ``upscale`` only ever donates slabs the
  session itself staged; arrays passed straight to :meth:`serve_batch` are
  consumed when donation is on.

Stats split DISPATCH latency (time to enqueue a chunk) from COMPLETE
latency (dispatch -> result ready); throughput is computed over the
serving wall-clock span, so steady-state fps reflects the overlap.  A
synchronous caller (:meth:`serve_batch`) records identical dispatch and
complete values.

Compilation always happens on a zero dummy **in the dtype being served**,
inside the cache-miss path — so steady-state latency stats
(:meth:`SRSession.stats`) never include compile time, and a first batch in
a new dtype never pays a silent mid-serving compile.

``VideoStream`` (stream.py) is now a deprecated shim over a session pinned
to one plan, one bucket and ``pipeline_depth=1`` (the legacy blocking
behavior).

Since the :class:`~repro.engine.server.SRServer` redesign, the session no
longer owns a serving loop of its own: :meth:`SRSession.submit` queues a
request on an embedded single-model server (which runs the pipelined
dispatch/coalescing drain), and :meth:`SRSession.upscale` is a thin
synchronous shim over ``submit(frames).result()``.  The session keeps what
is per-model state: the plan/executor caches, the prepared weight stacks,
the staging buffer and the latency/throughput stats (recorded identically
whether a batch arrived through ``upscale``, ``submit`` or a stream).
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.executor import (
    PreparedStack,
    build_band_executor,
    build_stack_executor,
    output_spec,
    prepare_stack,
)
from repro.engine.plan import (
    PREFERRED_BAND_ROWS,
    SRPlan,
    check_layer_channels,
)

__all__ = [
    "SRSession",
    "PlanCache",
    "StreamStats",
    "bucket_batch",
    "AUTOTUNE_MODES",
]

# Cold-start schedule policy (SRSession.open(..., autotune=...)):
#   "off"    — hard-coded defaults only; the tuning DB is never read.
#   "cached" — consult the DB per new (shape, batch); a hit applies the
#              measured-best schedule, a miss falls back to the defaults.
#              NEVER measures in the serving path (the safe default).
#   "full"   — like "cached", but a miss runs a small tuning sweep NOW
#              (blocking, on the serving thread) and persists the winner —
#              first-request latency pays for every later cold start.
AUTOTUNE_MODES = ("off", "cached", "full")


class StreamStats(dict):
    """Latency/throughput summary: frames, batches, fps, dispatch/complete
    p50/p95/p99/mean ms."""


def latency_stats(
    lat_ms: Sequence[float],
    frames: int,
    *,
    dispatch_ms: Optional[Sequence[float]] = None,
    total_s: Optional[float] = None,
    **extra,
) -> StreamStats:
    """Summarise recorded per-call latencies (compile time never included).

    ``lat_ms`` are COMPLETE latencies (dispatch -> result ready); the
    headline percentiles (``p50_ms``/``p95_ms``/``p99_ms``/``mean_ms``)
    come from them.  ``dispatch_ms`` (enqueue time only) populates the
    ``dispatch_*`` keys — for a synchronous caller both series are the
    same list, so the values are identical.  ``total_s`` is the serving
    wall-clock span: with pipelining, completes overlap, so fps is frames
    over the SPAN, not over the sum of latencies.  A clock too coarse to
    resolve any call reports ``fps=0.0``, not inf.
    """
    lat = np.asarray(lat_ms, dtype=np.float64)
    disp = lat if dispatch_ms is None else np.asarray(dispatch_ms, np.float64)
    if lat.size == 0:
        return StreamStats(
            frames=0, batches=0, fps=0.0,
            p50_ms=0.0, p95_ms=0.0, p99_ms=0.0, mean_ms=0.0,
            dispatch_p50_ms=0.0, dispatch_p99_ms=0.0, dispatch_mean_ms=0.0,
            **extra,
        )
    total = lat.sum() / 1e3 if total_s is None else float(total_s)
    if disp.size == 0:
        d50 = d99 = dmean = 0.0
    else:
        d50 = float(np.percentile(disp, 50))
        d99 = float(np.percentile(disp, 99))
        dmean = float(disp.mean())
    return StreamStats(
        frames=frames,
        batches=int(lat.size),
        fps=frames / total if total > 0 else 0.0,
        p50_ms=float(np.percentile(lat, 50)),
        p95_ms=float(np.percentile(lat, 95)),
        p99_ms=float(np.percentile(lat, 99)),
        mean_ms=float(lat.mean()),
        dispatch_p50_ms=d50,
        dispatch_p99_ms=d99,
        dispatch_mean_ms=dmean,
        **extra,
    )


def bucket_batch(n: int) -> int:
    """Round a batch size up to the next power of two.

    Bucketing bounds the number of compiled programs per plan at
    ``log2(max batch)`` while wasting at most 2x padding compute on a
    worst-case batch — the standard serving trade for heterogeneous
    request sizes.
    """
    if n < 1:
        raise ValueError(f"batch size {n} must be >= 1")
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class _CacheEntry:
    """A compiled executor plus the key facts ``cache_stats`` reports."""

    fn: Callable[[jax.Array], jax.Array]
    plan: SRPlan
    bucket: int
    dtype: str
    compile_s: float
    stack_key: tuple = ()
    donates: bool = False
    # replica index when the entry was compiled by a ReplicaRouter (mesh
    # serving); None for ordinary single-device executors
    replica: Optional[int] = None

    @property
    def jitted(self):
        """The executor's own jit wrapper (trace-count introspection)."""
        return getattr(self.fn, "jitted", None)


class PlanCache:
    """LRU cache of compiled executors keyed by ``(plan, bucket, dtype)``.

    ``get`` counts a hit (and refreshes recency) or a miss; ``put`` evicts
    the least-recently-used entry past ``capacity`` and counts the
    eviction.  Counters are cumulative over the cache's lifetime.
    ``on_evict(key, entry)`` fires for every evicted entry (including
    :meth:`clear`), so the owner can release per-entry resources — the
    session uses it to drop the evicted executor's reference on the
    device-resident :class:`~repro.engine.executor.PreparedStack`.
    """

    def __init__(self, capacity: int = 8, on_evict: Optional[Callable] = None):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be >= 1")
        self.capacity = capacity
        self.on_evict = on_evict
        self._entries: "OrderedDict[tuple, _CacheEntry]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key) -> Optional[_CacheEntry]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return entry

    def _evict_oldest(self) -> None:
        k, e = self._entries.popitem(last=False)
        self.evictions += 1
        if self.on_evict is not None:
            self.on_evict(k, e)

    def put(self, key, entry: _CacheEntry) -> None:
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._evict_oldest()

    def clear(self) -> None:
        """Evict every entry (counted, ``on_evict`` fired per entry)."""
        while self._entries:
            self._evict_oldest()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:  # does not touch the counters
        return key in self._entries

    def keys(self) -> List[tuple]:
        """Keys in LRU -> MRU order (eviction order)."""
        return list(self._entries)

    def entries(self) -> List[_CacheEntry]:
        """Entries in LRU -> MRU order."""
        return list(self._entries.values())

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "size": len(self._entries),
            "capacity": self.capacity,
            "hit_rate": self.hits / total if total else 0.0,
        }


@dataclasses.dataclass
class _StackRecord:
    """A refcounted device-resident PreparedStack shared by cache entries."""

    stack: PreparedStack
    refs: int
    prepare_s: float


class SRSession:
    """One serving endpoint: fixed weights + policy, any request shape.

    Construct directly from a layer stack, via :meth:`open` (model name ->
    weights through the registry), or via :meth:`from_plan` (pin an
    existing plan — the ``VideoStream`` compatibility path).
    """

    def __init__(
        self,
        layers,
        *,
        backend: str = "tilted",
        precision: str = "fp32",
        vertical_policy: str = "zero",
        tile_cols: int = 8,
        band_rows: Optional[int] = None,
        preferred_band_rows: int = PREFERRED_BAND_ROWS,
        scale: int = 3,
        clip: bool = True,
        cache_capacity: int = 8,
        max_bucket: Optional[int] = None,
        model: Optional[str] = None,
        pipeline_depth: Optional[int] = None,
        donate_frames: Optional[bool] = None,
        autotune: str = "cached",
        tuner=None,
        tuning_db: Optional[str] = None,
        strict: bool = False,
        mesh=None,
        route: str = "least_loaded",
    ):
        layers = tuple(layers)
        if not layers:
            raise ValueError("layer stack is empty")
        if max_bucket is not None and max_bucket < 1:
            raise ValueError(f"max_bucket={max_bucket} must be >= 1")
        if pipeline_depth is not None and pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth={pipeline_depth} must be >= 1 "
                "(1 = blocking, 2 = double-buffered dispatch)"
            )
        if autotune not in AUTOTUNE_MODES:
            raise ValueError(
                f"autotune {autotune!r} not in {AUTOTUNE_MODES}"
            )
        if cache_capacity < 1:
            raise ValueError(
                f"cache_capacity={cache_capacity} must be >= 1 "
                "(the session needs at least one live compiled executor)"
            )
        # mesh serving: resolve the topology FIRST — it gates autotune
        # modes and stamps the tuner with the topology descriptor
        self.mesh_spec = None
        self._router = None
        if mesh is not None:
            from repro.engine.sharding import MeshSpec  # lazy: no cycle

            spec = MeshSpec.coerce(mesh)
            if not spec.is_trivial:
                if autotune == "full":
                    raise ValueError(
                        'autotune="full" measures single-device schedules '
                        "and cannot run on a sharded session; tune offline "
                        'per topology and use "cached" or "off"'
                    )
                self.mesh_spec = spec
        self.layers = layers
        self.model = model
        self.backend = backend
        self.precision = precision
        self.vertical_policy = vertical_policy
        self.tile_cols = tile_cols
        self.band_rows = band_rows
        self.preferred_band_rows = preferred_band_rows
        self.scale = scale
        self.clip = clip
        self.max_bucket = max_bucket
        # pipeline_depth bounds in-flight chunks per request: 1 = blocking
        # (complete t before dispatching t+1), 2 = double buffering (the
        # paper's ping-pong line buffers), deeper = more latency hiding at
        # the cost of holding more bucket-sized slabs live.  None = the
        # tunable default (2) — the autotuner may override it from a
        # measured DB entry; an EXPLICIT depth is the caller's decision
        # and is never overridden.
        self._depth_explicit = pipeline_depth is not None
        self.pipeline_depth = 2 if pipeline_depth is None else pipeline_depth
        # schedule autotuning: mode + the DB-backed PlanTuner ("off" keeps
        # no tuner at all, so the DB file is never even opened)
        self.autotune = autotune
        self._tuner = None
        if autotune != "off":
            from repro.engine.autotune import PlanTuner  # lazy: no cycle

            self._tuner = tuner if tuner is not None else PlanTuner(
                path=tuning_db,
                mesh_shape=(
                    self.mesh_spec.descriptor if self.mesh_spec else "1x1"
                ),
            )
        self._tuning_counts = {"hits": 0, "misses": 0, "fallbacks": 0,
                               "applied": 0, "tuned_now": 0}
        # strict=True statically verifies every derived plan
        # (repro.analysis.plan_check) and refuses error-level findings
        # BEFORE anything compiles; degenerate one-giant-band fallbacks
        # are counted either way and surface in tuning_stats()
        self.strict = bool(strict)
        self._degenerate_plans = 0
        # per-cache-key compile counter: an entry evicted and re-missed
        # compiles again — the recompile detector (repro.analysis
        # .program_audit) flags keys whose count exceeds one
        self._compile_counts: Dict[tuple, int] = {}
        # request batch sizes whose measured-best bucket policy is "exact"
        # (compile the true batch instead of rounding up to a power of two)
        self._exact_buckets: set = set()
        # donate_frames=None resolves per-backend at first executor build:
        # XLA implements input-output aliasing on accelerators but not CPU
        # (donating there just warns and copies).
        self.donate_frames = donate_frames
        self._cache = PlanCache(cache_capacity, on_evict=self._on_evict)
        # device-resident prepared weights, refcounted by live cache
        # entries — prepared ONCE per (precision, backend), dropped when
        # the last entry using them is evicted (no weight leak)
        self._stacks: Dict[tuple, _StackRecord] = {}
        # derived-plan / output-dtype memos; bounded like the executor
        # cache so a long-lived endpoint under arbitrarily diverse
        # resolutions cannot grow memory monotonically
        self._memo_cap = 8 * cache_capacity
        self._plans: Dict[Tuple[int, int, int], SRPlan] = {}
        self._out_dtypes: Dict[tuple, np.dtype] = {}
        self._pinned: Optional[SRPlan] = None
        self._pinned_bucket: Optional[int] = None
        # one host-side staging buffer, reused across ragged tails (keyed
        # by (bucket, frame shape, dtype) — replaced when the shape moves)
        self._staging: Optional[Tuple[tuple, np.ndarray]] = None
        self._dispatch_ms: List[float] = []
        self._complete_ms: List[float] = []
        self._span_s = 0.0
        self._frames = 0
        self._peak_inflight = 0
        # temporal delta serving (engine.temporal): partial-band dispatch
        # counters (bumped by the server at completion) plus the per-frame
        # reuse accounting DeltaSession maintains; the output cache is
        # created on first delta use
        self._band_rows_served = 0
        self._band_dispatches = 0
        self._temporal_counts: Dict[str, int] = {
            "frames": 0,
            "bands_total": 0,
            "bands_skipped": 0,
            "band_rows_total": 0,
            "band_rows_served": 0,
            "hbm_bytes_full": 0,
            "hbm_bytes_served": 0,
            "cover_violations": 0,
        }
        self._output_cache = None
        # the SRServer submit()/upscale() serve through: set by the first
        # server that hosts this session, else an embedded single-model
        # server created lazily on first submit
        self._server = None
        # mesh serving: the router owns per-replica compile caches + band-
        # sharded executors; built EAGERLY so a too-small device pool fails
        # at construction, not on the first request
        if self.mesh_spec is not None:
            from repro.engine.sharding import ReplicaRouter  # lazy: no cycle

            self._router = ReplicaRouter(
                self, self.mesh_spec, policy=route,
                cache_capacity=cache_capacity,
            )

    # ------------------------------------------------------------------
    # Constructors
    # ------------------------------------------------------------------
    @classmethod
    def open(
        cls,
        model: str = "abpn_x3",
        *,
        seed: int = 0,
        layers=None,
        scale: Optional[int] = None,
        clip: Optional[bool] = None,
        **kwargs,
    ) -> "SRSession":
        """Open a session on a registered SR model.

        Weights resolve through ``repro.models.registry.get_sr_model``:
        the spec's initialiser (seeded by ``seed``) unless an explicit
        trained ``layers`` stack is passed.  ``scale``/``clip`` default to
        the model config's values; everything else (backend, precision,
        vertical_policy, cache_capacity, pipeline_depth, ...) passes
        through to :class:`SRSession`.
        """
        from repro.models.registry import get_sr_model

        spec = get_sr_model(model)
        cfg = spec.config
        if layers is None:
            layers = spec.init(jax.random.PRNGKey(seed))
        return cls(
            layers,
            scale=cfg.scale if scale is None else scale,
            clip=cfg.clip if clip is None else clip,
            model=spec.name,
            **kwargs,
        )

    @classmethod
    def from_plan(
        cls,
        plan: SRPlan,
        layers,
        *,
        bucket: Optional[int] = None,
        cache_capacity: int = 8,
        **kwargs,
    ) -> "SRSession":
        """A session pinned to one plan (and optionally one batch bucket).

        This is what the deprecated ``VideoStream`` wraps: the plan's
        geometry/numerics are fixed, requests for any other LR shape are
        rejected, and ``bucket`` (when given) replaces power-of-two
        bucketing so the stream's exact batch size is the one compiled
        program.  ``kwargs`` (``pipeline_depth``, ``donate_frames``, ...)
        pass through to :class:`SRSession`.
        """
        session = cls(
            layers,
            backend=plan.backend,
            precision=plan.precision,
            vertical_policy=plan.vertical_policy,
            tile_cols=plan.tile_cols,
            band_rows=plan.band_rows,
            scale=plan.scale,
            clip=plan.clip,
            cache_capacity=cache_capacity,
            **kwargs,
        )
        check_layer_channels(session.layers, plan.in_channels, plan.scale)
        session._pinned = plan
        session._pinned_bucket = bucket
        session._plans[plan.lr_shape] = plan
        return session

    # ------------------------------------------------------------------
    # Plan + executor resolution
    # ------------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.layers)

    def plan_for(
        self,
        lr_shape: Tuple[int, int, int],
        batch_hint: Optional[int] = None,
    ) -> SRPlan:
        """The session's plan for one LR frame shape (derived once, memoised).

        ``batch_hint`` (the request's flattened frame count, passed by the
        server's submit path) keys the tuning-DB lookup: a warm entry for
        this (shape, batch) applies the measured-best schedule — band
        decomposition via ``SRPlan.from_request(tuner=...)``, pipeline
        depth and bucket rounding policy via :meth:`_apply_tuning` — before
        anything compiles.  With ``autotune="off"`` (or an explicit
        ``band_rows``) the derivation is exactly the untuned default.
        """
        lr_shape = tuple(int(x) for x in lr_shape)
        plan = self._plans.get(lr_shape)
        if plan is not None:
            return plan
        if self._pinned is not None:
            raise ValueError(
                f"session is pinned to LR shape {self._pinned.lr_shape}, "
                f"got {lr_shape}"
            )
        check_layer_channels(self.layers, lr_shape[2], self.scale)
        tuner = self._tuner if self.band_rows is None else None
        if tuner is not None:
            self._consult_tuning(lr_shape, batch_hint)
        plan = SRPlan.from_request(
            lr_shape,
            num_layers=self.num_layers,
            band_rows=self.band_rows,
            tile_cols=self.tile_cols,
            vertical_policy=self.vertical_policy,
            backend=self.backend,
            precision=self.precision,
            scale=self.scale,
            clip=self.clip,
            preferred_band_rows=self.preferred_band_rows,
            tuner=tuner,
            bucket=batch_hint,
        )
        if self.mesh_spec is not None:
            plan = self._shardable_plan(plan)
        if plan.degenerate_bands:
            self._degenerate_plans += 1
        if self.strict:
            self._verify_plan(plan)
        self._memo_put(self._plans, lr_shape, plan)
        return plan

    def _shardable_plan(self, plan: SRPlan) -> SRPlan:
        """Make a derived plan legal for the session's mesh: re-band when
        the default decomposition does not split across the band shards;
        an EXPLICIT ``band_rows`` is the caller's decision and is rejected
        (never silently re-banded) when it cannot shard."""
        from repro.engine.sharding import check_shardable, ensure_shardable

        if self.band_rows is not None:
            err = check_shardable(plan, self.mesh_spec.band_shards)
            if err is not None:
                raise ValueError(
                    f"explicit band_rows={self.band_rows} cannot serve on "
                    f"mesh {self.mesh_spec.descriptor}: {err}"
                )
            return plan
        return ensure_shardable(
            plan, self.mesh_spec, self.preferred_band_rows
        )

    def _verify_plan(self, plan: SRPlan) -> None:
        """Strict-mode gate: statically verify the derived plan and raise
        :class:`~repro.analysis.findings.PlanVerificationError` on any
        error-level finding — BEFORE weight prep or compilation."""
        from repro.analysis import findings as _findings  # lazy: no cycle
        from repro.analysis import plan_check  # lazy: no cycle

        kwargs = {}
        if self.mesh_spec is not None:
            kwargs["band_shards"] = self.mesh_spec.band_shards
        errs = _findings.errors(plan_check.verify_plan(plan, **kwargs))
        if errs:
            raise _findings.PlanVerificationError(errs)

    # ------------------------------------------------------------------
    # Schedule autotuning (engine.autotune)
    # ------------------------------------------------------------------
    def _tuning_key(self, lr_shape: tuple, batch: Optional[int]):
        from repro.engine.autotune import TuningKey

        H, W, C = lr_shape
        return TuningKey(
            backend=self.backend, precision=self.precision,
            vertical_policy=self.vertical_policy,
            height=H, width=W, channels=C,
            num_layers=self.num_layers, tile_cols=self.tile_cols,
            scale=self.scale, clip=self.clip,
            batch=int(batch) if batch else 1,
        )

    def _consult_tuning(self, lr_shape: tuple, batch: Optional[int]) -> None:
        """DB lookup for a new shape: count the outcome, apply a hit's
        depth/bucket policy, and — ``autotune="full"`` only — tune NOW on
        a miss (blocking; the winner persists for every later cold
        start)."""
        key = self._tuning_key(lr_shape, batch)
        entry, kind = self._tuner.lookup(key)
        self._tuning_counts[
            {"hit": "hits", "fallback": "fallbacks", "miss": "misses"}[kind]
        ] += 1
        if entry is None and self.autotune == "full":
            entry = self._tune_now(lr_shape, batch)
        if entry is not None:
            self._apply_tuning(entry)

    def _apply_tuning(self, entry) -> None:
        """Adopt a measured-best schedule's session-level knobs.  Band
        decomposition is applied where plans are built (``from_request``'s
        tuner hook); depth applies unless the caller pinned one
        explicitly; an "exact" bucket policy registers the tuned batch so
        ``_bucket_for`` stops rounding it up."""
        self._tuning_counts["applied"] += 1
        if not self._depth_explicit:
            self.pipeline_depth = int(entry.pipeline_depth)
        if entry.bucket_policy == "exact":
            self._exact_buckets.add(int(entry.bucket))

    def _tune_now(self, lr_shape: tuple, batch: Optional[int]):
        """The ``autotune="full"`` miss path: run a small measured sweep
        for this (shape, batch) and persist the winner (shallow depth grid
        + few reps — first-request latency, paid once per DB)."""
        from repro.engine.autotune import tune

        default_plan = SRPlan.from_request(
            lr_shape,
            num_layers=self.num_layers,
            tile_cols=self.tile_cols,
            vertical_policy=self.vertical_policy,
            backend=self.backend,
            precision=self.precision,
            scale=self.scale,
            clip=self.clip,
            preferred_band_rows=self.preferred_band_rows,
        )
        entry = tune(
            self.layers, default_plan, batch or 1,
            db=self._tuner.db, depths=(1, 2), chunks=2, reps=1,
        )
        self._tuning_counts["tuned_now"] += 1
        return entry

    def tuning_stats(self) -> dict:
        """Autotune outcome counters: ``hits`` (exact DB entry),
        ``fallbacks`` (nearest tuned batch), ``misses``, ``applied``
        (schedules adopted), ``tuned_now`` (blocking sweeps run by
        ``autotune="full"``), plus the mode, DB path and the live
        session-level knobs the tuner controls."""
        return {
            "mode": self.autotune,
            "db_path": self._tuner.db.path if self._tuner else None,
            **self._tuning_counts,
            "degenerate_plans": self._degenerate_plans,
            "pipeline_depth": self.pipeline_depth,
            "exact_buckets": sorted(self._exact_buckets),
        }

    def _memo_put(self, memo: dict, key, value) -> None:
        """Insert into a memo dict, evicting oldest entries past the cap
        (a pinned session never accumulates shapes, so pins are safe)."""
        memo[key] = value
        while len(memo) > self._memo_cap:
            try:
                memo.pop(next(iter(memo)))
            except (KeyError, StopIteration, RuntimeError):
                # concurrent server submits resolve plans outside the
                # server lock; losing the race for the oldest key just
                # means another thread evicted it — re-check the cap
                continue

    @staticmethod
    def serving_dtype(dtype) -> np.dtype:
        """The dtype a request ACTUALLY serves in: jax canonicalizes
        (float64 -> float32 without x64), so keying/compiling on the raw
        host dtype would duplicate programs and mislabel cache entries."""
        return np.dtype(jax.dtypes.canonicalize_dtype(np.dtype(dtype)))

    @classmethod
    def cache_key(cls, plan: SRPlan, bucket: int, dtype) -> tuple:
        return (plan, int(bucket), cls.serving_dtype(dtype).name)

    def _resolve_donate(self) -> bool:
        if self.donate_frames is not None:
            return bool(self.donate_frames)
        return jax.default_backend() != "cpu"

    def _acquire_stack(self, plan: SRPlan) -> Tuple[PreparedStack, tuple]:
        """The session's PreparedStack for this plan's numerics/backend,
        prepared on first use (blocking — NEVER inside serving latency)
        and refcounted per cache entry."""
        skey = plan.stack_key
        rec = self._stacks.get(skey)
        if rec is None:
            t0 = time.perf_counter()
            stack = prepare_stack(plan, self.layers)
            jax.block_until_ready(stack)
            rec = _StackRecord(
                stack=stack, refs=0, prepare_s=time.perf_counter() - t0
            )
            self._stacks[skey] = rec
        rec.refs += 1
        return rec.stack, skey

    def _release_stack(self, skey: tuple) -> None:
        rec = self._stacks.get(skey)
        if rec is None:
            return
        rec.refs -= 1
        if rec.refs <= 0:
            # last executor using these device buffers is gone — drop them
            del self._stacks[skey]

    def _on_evict(self, key, entry: _CacheEntry) -> None:
        self._release_stack(entry.stack_key)

    def clear_cache(self) -> None:
        """Evict every compiled executor AND release the device-resident
        prepared weights they pinned (frees accelerator memory; the next
        request re-prepares and recompiles)."""
        self._cache.clear()
        if self._router is not None:
            self._router.clear()

    def executor_for(
        self, plan: SRPlan, bucket: int, dtype
    ) -> Tuple[_CacheEntry, bool]:
        """The compiled executor for ``(plan, bucket, dtype)``.

        Cache miss prepares the weight stack (once per session numerics —
        shared and refcounted across entries) and compiles NOW, warmed on a
        zero dummy in the dtype that will actually be served, recording the
        compile seconds on the entry — so no later ``fn`` call on this key
        pays compilation or weight prep.  Returns ``(entry, compiled_now)``.

        On a mesh session the call routes to a replica's band-sharded
        executor instead (``entry.replica`` records which one).
        """
        if self._router is not None:
            return self._router.executor_for(plan, bucket, dtype)
        dtype = self.serving_dtype(dtype)
        key = self.cache_key(plan, bucket, dtype)
        entry = self._cache.get(key)
        if entry is not None:
            return entry, False
        stack, skey = self._acquire_stack(plan)
        try:
            donate = self._resolve_donate()
            # own jit per entry: evicting the entry drops the only
            # reference this layer holds to the compiled program (the
            # module-level shared jit would pin it for the process); a
            # re-miss re-acquires and re-times — fast when jax's internal
            # caches still hold the program
            fn = build_stack_executor(plan, stack, donate_frames=donate)
            dummy = jnp.zeros((bucket, *plan.lr_shape), dtype)
            t0 = time.perf_counter()
            jax.block_until_ready(fn(dummy))
            compile_s = time.perf_counter() - t0
        except BaseException:
            # a failed build/compile must not strand the stack refcount —
            # otherwise the device-resident weights could never be freed
            self._release_stack(skey)
            raise
        entry = _CacheEntry(
            fn=fn,
            plan=plan,
            bucket=int(bucket),
            dtype=dtype.name,
            compile_s=compile_s,
            stack_key=skey,
            donates=donate,
        )
        self._compile_counts[key] = self._compile_counts.get(key, 0) + 1
        self._cache.put(key, entry)
        return entry, True

    def band_executor_for(
        self, plan: SRPlan, bucket: int, dtype
    ) -> Tuple[_CacheEntry, bool]:
        """The compiled partial-band executor for ``(plan, bucket, dtype)``
        — the temporal delta path's program:
        ``(bucket, rows, W, C) slabs + (bucket, 2) bounds -> HR bands``.

        Lives in the same :class:`PlanCache` under a ``"bands"``-suffixed
        key with the same refcounted weight-stack sharing, warmed on zero
        dummies like the frame path.  Never donates (band slabs are small
        and the splice reads the result immediately).  On a mesh session
        the program compiles locally, unsharded: a partial-band dispatch
        is below the granularity band sharding pays off at, and single-
        device vs sharded full-frame outputs are already bit-exact, so
        the splice guarantee holds transitively.
        """
        if plan.backend == "reference":
            raise ValueError(
                "partial-band serving needs a banded backend (tilted or "
                "kernel); the reference backend computes whole frames"
            )
        dtype = self.serving_dtype(dtype)
        key = (plan, int(bucket), dtype.name, "bands")
        entry = self._cache.get(key)
        if entry is not None:
            return entry, False
        from repro.engine.temporal.band_diff import band_input_rows

        stack, skey = self._acquire_stack(plan)
        try:
            fn = build_band_executor(plan, stack)
            rows = band_input_rows(
                plan.band_rows, plan.num_layers, plan.vertical_policy
            )
            dummy = jnp.zeros(
                (bucket, rows, plan.width, plan.in_channels), dtype
            )
            dbounds = jnp.zeros((bucket, 2), jnp.int32)
            t0 = time.perf_counter()
            jax.block_until_ready(fn(dummy, dbounds))
            compile_s = time.perf_counter() - t0
        except BaseException:
            self._release_stack(skey)
            raise
        entry = _CacheEntry(
            fn=fn,
            plan=plan,
            bucket=int(bucket),
            dtype=dtype.name,
            compile_s=compile_s,
            stack_key=skey,
            donates=False,
        )
        self._compile_counts[key] = self._compile_counts.get(key, 0) + 1
        self._cache.put(key, entry)
        return entry, True

    def output_dtype(self, plan: SRPlan, dtype) -> np.dtype:
        """The dtype the compiled executor emits for ``dtype`` input
        (abstract eval — no compile, memoised), so degenerate paths —
        empty clips — return exactly what a real batch would."""
        dtype = self.serving_dtype(dtype)
        key = (plan, dtype.name)
        out = self._out_dtypes.get(key)
        if out is None:
            out = output_spec(plan, self.layers, 1, dtype).dtype
            self._memo_put(self._out_dtypes, key, out)
        return out

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        if self._pinned_bucket is not None:
            return self._pinned_bucket
        if n in self._exact_buckets and (
            self.max_bucket is None or n <= self.max_bucket
        ):
            # the tuner measured this batch faster compiled exactly than
            # rounded up (padding waste beats the extra program)
            return n
        bucket = bucket_batch(n)
        if self.max_bucket is not None:
            # clamp DOWN to the largest power of two within the cap — the
            # cap is a ceiling (e.g. device memory), never exceeded
            cap = 1 << (self.max_bucket.bit_length() - 1)
            bucket = min(bucket, cap)
        return bucket

    def flatten_request(self, frames) -> Tuple[object, int, Optional[tuple]]:
        """Validate a request and flatten it to ``(N, H, W, C)``.

        Returns ``(flat, ndim, lead)`` — the flat frame batch (host numpy
        stays host, already cast to the serving dtype; device arrays pass
        through), the caller's original rank, and the ``(B, T)`` leading
        shape for rank-5 input.  Malformed input fails HERE with a clear
        ``ValueError`` naming the expected ``(..., H, W, C)`` layout —
        non-array objects, non-numeric dtypes, bad ranks and channel
        counts never reach plan derivation or the compiler.
        """
        if isinstance(frames, (np.ndarray, jax.Array)):
            arr = frames
        else:
            try:
                arr = np.asarray(frames)
            except Exception as e:
                raise ValueError(
                    "expected an array of frames with shape (..., H, W, C); "
                    f"got {type(frames).__name__}"
                ) from e
        dtype = arr.dtype
        if not (jnp.issubdtype(dtype, jnp.floating)
                or jnp.issubdtype(dtype, jnp.integer)
                or dtype == np.bool_):
            raise ValueError(
                "expected numeric frames with shape (..., H, W, C); "
                f"got dtype {dtype} (from {type(frames).__name__})"
            )
        if isinstance(arr, np.ndarray):
            # cast to the dtype jax will actually serve in (float64 ->
            # float32 without x64) BEFORE keying/staging, so one program
            # serves both spellings and chunks match the compiled dtype
            arr = arr.astype(self.serving_dtype(dtype), copy=False)
        lead: Optional[tuple] = None
        if arr.ndim == 3:
            flat = arr[None]
        elif arr.ndim == 4:
            flat = arr
        elif arr.ndim == 5:
            lead = arr.shape[:2]
            flat = arr.reshape(arr.shape[0] * arr.shape[1], *arr.shape[2:])
        else:
            raise ValueError(
                "expected (H, W, C), (T, H, W, C) or (B, T, H, W, C) frames, "
                f"got shape {tuple(arr.shape)}"
            )
        ci = getattr(self.layers[0], "ci", None)
        if ci is not None and flat.shape[-1] != ci:
            raise ValueError(
                f"frames have {flat.shape[-1]} channels in the trailing "
                f"(..., H, W, C) axis; this session's layer stack expects "
                f"C={ci}"
            )
        return flat, arr.ndim, lead

    def submit(self, frames, *, priority: int = 0,
               deadline: Optional[float] = None,
               timeout: Optional[float] = None):
        """Queue a request on the session's embedded server; returns an
        :class:`~repro.engine.server.SRFuture` immediately.

        The request dispatches when the server's drain loop next turns
        over (``future.result()`` drives it), coalescing with any other
        queued requests that share this session's ``(plan, dtype)`` key.
        If an :class:`~repro.engine.server.SRServer` hosts this session,
        the request goes through THAT server (one scheduler + one lock
        govern all traffic into the session); otherwise an embedded
        single-model server is created on first use.  ``deadline``
        (absolute monotonic seconds) / ``timeout`` (relative) bound the
        request's QUEUED lifetime — see ``SRServer.submit``.
        """
        return self._host_server().submit_for(
            self, frames, priority=priority,
            deadline=deadline, timeout=timeout)

    def _host_server(self):
        """The server this session serves through — the hosting
        :class:`~repro.engine.server.SRServer` if one registered itself,
        else an embedded single-model server created on first use."""
        if self._server is None:
            from repro.engine.server import SRServer  # lazy: avoids a cycle

            # (SRServer.__init__ also registers itself on the session —
            # the assignment is the same object, stated explicitly)
            self._server = SRServer({self.model or "session": self})
        return self._server

    def upscale(self, frames) -> jax.Array:
        """Super-resolve frames of any supported rank (blocking).

        ``(H, W, C)`` -> ``(sH, sW, C)``; ``(T, H, W, C)`` ->
        ``(T, sH, sW, C)``; ``(B, T, H, W, C)`` -> ``(B, T, sH, sW, C)``.
        A thin synchronous shim over ``submit(frames).result()``: the
        flattened batch is served in bucket-sized dispatches through the
        server's pipelined drain (up to ``pipeline_depth`` in flight;
        host numpy input staged per chunk via the one reused staging
        buffer + ``jax.device_put``), padded outputs are trimmed, and only
        real frames count in :meth:`stats`.  The caller's array is never
        donated — only server-staged slabs.
        """
        return self.submit(frames).result()

    def serve_batch(
        self, plan: SRPlan, frames: jax.Array, real_frames: Optional[int] = None
    ) -> jax.Array:
        """Run ONE pre-bucketed batch through the plan's executor
        synchronously, recording its steady-state latency (a cache miss
        compiles on a dummy first, outside the timed region).  Dispatch and
        complete latency are the same recorded value — a synchronous call
        is not "dispatched" until its result is ready.  ``real_frames``
        counts only that many leading frames in :meth:`stats` — the rest
        are padding; the full batch is returned.  When frame donation is
        active, ``frames`` is CONSUMED by the call.
        """
        n_real = frames.shape[0] if real_frames is None else real_frames
        entry, _ = self.executor_for(plan, frames.shape[0], frames.dtype)
        t0 = time.perf_counter()
        hr = entry.fn(frames)
        jax.block_until_ready(hr)
        dt_ms = (time.perf_counter() - t0) * 1e3
        self._dispatch_ms.append(dt_ms)
        self._complete_ms.append(dt_ms)
        self._span_s += dt_ms / 1e3
        self._frames += n_real
        self._peak_inflight = max(self._peak_inflight, 1)
        return hr

    def _staging_for(self, bucket: int, frame_shape, dtype) -> np.ndarray:
        """One reusable host buffer for staging ragged/coalesced host
        dispatches (no fresh bucket-sized allocation per tail); the
        server's assembler fills it and ships it with ``device_put``."""
        key = (bucket, tuple(frame_shape), np.dtype(dtype).str)
        if self._staging is None or self._staging[0] != key:
            self._staging = (key, np.zeros((bucket, *frame_shape), dtype))
        return self._staging[1]

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def _lat_ms(self) -> List[float]:
        """Back-compat alias: the complete-latency series."""
        return self._complete_ms

    def cache_stats(self) -> dict:
        """Compile-cache counters plus per-entry compile metadata.

        ``hits``/``misses``/``evictions`` are cumulative; ``entries`` lists
        live entries in LRU -> MRU order, each with its plan shape, batch
        bucket, serving dtype and measured compile seconds.  ``stacks``
        lists the device-resident prepared weight stacks with their entry
        refcounts, one-time prepare seconds and resident bytes.
        """
        stats = self._cache.stats()
        stats["recompiles"] = sum(
            c - 1 for c in self._compile_counts.values() if c > 1
        )
        stats["entries"] = [
            {
                "lr_shape": list(e.plan.lr_shape),
                "backend": e.plan.backend,
                "precision": e.plan.precision,
                "band_rows": e.plan.band_rows,
                "bucket": e.bucket,
                "dtype": e.dtype,
                "compile_s": e.compile_s,
                "donates": e.donates,
            }
            for e in self._cache.entries()
        ]
        stats["stacks"] = [
            {
                "precision": k[0],
                "backend": k[1],
                "refs": rec.refs,
                "prepare_s": rec.prepare_s,
                "resident_bytes": rec.stack.nbytes(),
            }
            for k, rec in self._stacks.items()
        ]
        return stats

    def stats(self, **extra) -> StreamStats:
        """Steady-state serving stats — compile and weight-prep time are
        never included (both happen inside the cache-miss path, outside
        the timed span).  Percentiles split dispatch (enqueue) from
        complete (result ready); ``fps`` is real frames over the serving
        wall-clock span, so pipelined overlap shows up as throughput."""
        if self._temporal_counts["frames"] and "temporal" not in extra:
            extra["temporal"] = self.temporal_stats()
        return latency_stats(
            self._complete_ms,
            self._frames,
            dispatch_ms=self._dispatch_ms,
            total_s=self._span_s,
            peak_inflight=self._peak_inflight,
            **extra,
        )

    def output_cache(self, max_bytes: Optional[int] = None):
        """The session's HR output-band cache (temporal delta serving),
        created on first use.  ``max_bytes`` only applies at creation —
        later callers share whatever bound the first one set."""
        if self._output_cache is None:
            from repro.engine.temporal.output_cache import (  # lazy: no cycle
                DEFAULT_CACHE_BYTES,
                OutputBandCache,
            )

            self._output_cache = OutputBandCache(
                max_bytes=DEFAULT_CACHE_BYTES if max_bytes is None
                else max_bytes
            )
        return self._output_cache

    def temporal_stats(self) -> dict:
        """Delta-serving counters (the ``temporal`` section of
        :meth:`stats`).

        ``reuse_ratio`` is spliced-from-cache bands over all bands of
        delta-served frames; ``band_rows_*`` count LR rows of conv-stack
        compute (``served / total`` is the compute fraction the delta
        path actually ran).  ``effective_hbm_bytes_per_frame`` models
        the paper's DRAM-traffic metric for the delta path: the LR slab
        bytes dispatched plus the HR band bytes written, per frame —
        weights excluded (they are resident either way) — next to
        ``full_hbm_bytes_per_frame``, the same model for full
        re-upscale.
        """
        t = self._temporal_counts
        frames = t["frames"]
        total = t["bands_total"]
        out = {
            "frames": frames,
            "bands_total": total,
            "bands_skipped": t["bands_skipped"],
            "reuse_ratio": t["bands_skipped"] / total if total else 0.0,
            "band_rows_total": t["band_rows_total"],
            "band_rows_served": t["band_rows_served"],
            "band_dispatches": self._band_dispatches,
            # server-side truth: band-rows across ALL partial dispatches
            # (any submit_bands caller), vs the delta accounting above
            "band_rows_dispatched": self._band_rows_served,
            "effective_hbm_bytes_per_frame":
                t["hbm_bytes_served"] / frames if frames else 0.0,
            "full_hbm_bytes_per_frame":
                t["hbm_bytes_full"] / frames if frames else 0.0,
            "cover_violations": t["cover_violations"],
        }
        if self._output_cache is not None:
            out["cache"] = self._output_cache.stats()
        return out

    def sharding_stats(self) -> Optional[dict]:
        """Mesh routing stats (replica dispatch balance, per-replica
        caches, halo bytes per frame); ``None`` on an unsharded session."""
        if self._router is None:
            return None
        return self._router.stats()

    def reset_stats(self) -> None:
        self._dispatch_ms.clear()
        self._complete_ms.clear()
        self._span_s = 0.0
        self._frames = 0
        self._peak_inflight = 0
        self._band_rows_served = 0
        self._band_dispatches = 0
        for k in self._temporal_counts:
            self._temporal_counts[k] = 0
