"""SRSession — shape/batch/model-agnostic serving over a compile cache.

The paper's accelerator serves ONE fixed pipeline (1080p x3 at 60 fps);
production traffic is heterogeneous: mixed resolutions, clip lengths,
batch sizes and dtypes.  ``SRSession`` is the serving front door that
absorbs that heterogeneity:

* ``SRSession.open("abpn_x3", backend=..., precision=...)`` resolves the
  model's config + weights through ``repro.models.registry``.
* ``session.upscale(frames)`` accepts ``(H, W, C)``, ``(T, H, W, C)`` or
  ``(B, T, H, W, C)`` input.  Per new resolution it derives the
  :class:`~repro.engine.plan.SRPlan` (including a legal ``band_rows`` for
  the incoming height — ``SRPlan.from_request``), buckets the flattened
  batch up to a power of two, and compiles one executor per
  ``(plan, bucket, dtype)`` on demand.
* Compiled executors live in an LRU :class:`PlanCache`; hit/miss/evict
  counters and per-entry compile times are exposed via
  :meth:`SRSession.cache_stats`.

Compilation always happens on a zero dummy **in the dtype being served**,
inside the cache-miss path — so steady-state latency stats
(:meth:`SRSession.stats`) never include compile time, and a first batch in
a new dtype never pays a silent mid-serving compile.

``VideoStream`` (stream.py) is now a deprecated shim over a session pinned
to one plan and one bucket.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.executor import build_executor, output_spec
from repro.engine.plan import (
    PREFERRED_BAND_ROWS,
    SRPlan,
    check_layer_channels,
)

__all__ = [
    "SRSession",
    "PlanCache",
    "StreamStats",
    "bucket_batch",
]


class StreamStats(dict):
    """Latency/throughput summary: frames, batches, fps, p50/p95/mean ms."""


def latency_stats(lat_ms: Sequence[float], frames: int, **extra) -> StreamStats:
    """Summarise recorded per-call latencies (compile time never included).

    A clock too coarse to resolve any call reports ``fps=0.0``, not inf.
    """
    lat = np.asarray(lat_ms, dtype=np.float64)
    if lat.size == 0:
        return StreamStats(frames=0, batches=0, fps=0.0,
                           p50_ms=0.0, p95_ms=0.0, mean_ms=0.0, **extra)
    total_s = lat.sum() / 1e3
    return StreamStats(
        frames=frames,
        batches=int(lat.size),
        fps=frames / total_s if total_s > 0 else 0.0,
        p50_ms=float(np.percentile(lat, 50)),
        p95_ms=float(np.percentile(lat, 95)),
        mean_ms=float(lat.mean()),
        **extra,
    )


def bucket_batch(n: int) -> int:
    """Round a batch size up to the next power of two.

    Bucketing bounds the number of compiled programs per plan at
    ``log2(max batch)`` while wasting at most 2x padding compute on a
    worst-case batch — the standard serving trade for heterogeneous
    request sizes.
    """
    if n < 1:
        raise ValueError(f"batch size {n} must be >= 1")
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class _CacheEntry:
    """A compiled executor plus the key facts ``cache_stats`` reports."""

    fn: Callable[[jax.Array], jax.Array]
    plan: SRPlan
    bucket: int
    dtype: str
    compile_s: float


class PlanCache:
    """LRU cache of compiled executors keyed by ``(plan, bucket, dtype)``.

    ``get`` counts a hit (and refreshes recency) or a miss; ``put`` evicts
    the least-recently-used entry past ``capacity`` and counts the
    eviction.  Counters are cumulative over the cache's lifetime.
    """

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be >= 1")
        self.capacity = capacity
        self._entries: "OrderedDict[tuple, _CacheEntry]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key) -> Optional[_CacheEntry]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return entry

    def put(self, key, entry: _CacheEntry) -> None:
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:  # does not touch the counters
        return key in self._entries

    def keys(self) -> List[tuple]:
        """Keys in LRU -> MRU order (eviction order)."""
        return list(self._entries)

    def entries(self) -> List[_CacheEntry]:
        """Entries in LRU -> MRU order."""
        return list(self._entries.values())

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "size": len(self._entries),
            "capacity": self.capacity,
            "hit_rate": self.hits / total if total else 0.0,
        }


class SRSession:
    """One serving endpoint: fixed weights + policy, any request shape.

    Construct directly from a layer stack, via :meth:`open` (model name ->
    weights through the registry), or via :meth:`from_plan` (pin an
    existing plan — the ``VideoStream`` compatibility path).
    """

    def __init__(
        self,
        layers,
        *,
        backend: str = "tilted",
        precision: str = "fp32",
        vertical_policy: str = "zero",
        tile_cols: int = 8,
        band_rows: Optional[int] = None,
        preferred_band_rows: int = PREFERRED_BAND_ROWS,
        scale: int = 3,
        clip: bool = True,
        cache_capacity: int = 8,
        max_bucket: Optional[int] = None,
        model: Optional[str] = None,
    ):
        layers = tuple(layers)
        if not layers:
            raise ValueError("layer stack is empty")
        if max_bucket is not None and max_bucket < 1:
            raise ValueError(f"max_bucket={max_bucket} must be >= 1")
        self.layers = layers
        self.model = model
        self.backend = backend
        self.precision = precision
        self.vertical_policy = vertical_policy
        self.tile_cols = tile_cols
        self.band_rows = band_rows
        self.preferred_band_rows = preferred_band_rows
        self.scale = scale
        self.clip = clip
        self.max_bucket = max_bucket
        self._cache = PlanCache(cache_capacity)
        # derived-plan / output-dtype memos; bounded like the executor
        # cache so a long-lived endpoint under arbitrarily diverse
        # resolutions cannot grow memory monotonically
        self._memo_cap = 8 * cache_capacity
        self._plans: Dict[Tuple[int, int, int], SRPlan] = {}
        self._out_dtypes: Dict[tuple, np.dtype] = {}
        self._pinned: Optional[SRPlan] = None
        self._pinned_bucket: Optional[int] = None
        self._lat_ms: List[float] = []
        self._frames = 0

    # ------------------------------------------------------------------
    # Constructors
    # ------------------------------------------------------------------
    @classmethod
    def open(
        cls,
        model: str = "abpn_x3",
        *,
        seed: int = 0,
        layers=None,
        scale: Optional[int] = None,
        clip: Optional[bool] = None,
        **kwargs,
    ) -> "SRSession":
        """Open a session on a registered SR model.

        Weights resolve through ``repro.models.registry.get_sr_model``:
        the spec's initialiser (seeded by ``seed``) unless an explicit
        trained ``layers`` stack is passed.  ``scale``/``clip`` default to
        the model config's values; everything else (backend, precision,
        vertical_policy, cache_capacity, ...) passes through to
        :class:`SRSession`.
        """
        from repro.models.registry import get_sr_model

        spec = get_sr_model(model)
        cfg = spec.config
        if layers is None:
            layers = spec.init(jax.random.PRNGKey(seed))
        return cls(
            layers,
            scale=cfg.scale if scale is None else scale,
            clip=cfg.clip if clip is None else clip,
            model=spec.name,
            **kwargs,
        )

    @classmethod
    def from_plan(
        cls,
        plan: SRPlan,
        layers,
        *,
        bucket: Optional[int] = None,
        cache_capacity: int = 8,
    ) -> "SRSession":
        """A session pinned to one plan (and optionally one batch bucket).

        This is what the deprecated ``VideoStream`` wraps: the plan's
        geometry/numerics are fixed, requests for any other LR shape are
        rejected, and ``bucket`` (when given) replaces power-of-two
        bucketing so the stream's exact batch size is the one compiled
        program.
        """
        session = cls(
            layers,
            backend=plan.backend,
            precision=plan.precision,
            vertical_policy=plan.vertical_policy,
            tile_cols=plan.tile_cols,
            band_rows=plan.band_rows,
            scale=plan.scale,
            clip=plan.clip,
            cache_capacity=cache_capacity,
        )
        check_layer_channels(session.layers, plan.in_channels, plan.scale)
        session._pinned = plan
        session._pinned_bucket = bucket
        session._plans[plan.lr_shape] = plan
        return session

    # ------------------------------------------------------------------
    # Plan + executor resolution
    # ------------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.layers)

    def plan_for(self, lr_shape: Tuple[int, int, int]) -> SRPlan:
        """The session's plan for one LR frame shape (derived once, memoised)."""
        lr_shape = tuple(int(x) for x in lr_shape)
        plan = self._plans.get(lr_shape)
        if plan is not None:
            return plan
        if self._pinned is not None:
            raise ValueError(
                f"session is pinned to LR shape {self._pinned.lr_shape}, "
                f"got {lr_shape}"
            )
        check_layer_channels(self.layers, lr_shape[2], self.scale)
        plan = SRPlan.from_request(
            lr_shape,
            num_layers=self.num_layers,
            band_rows=self.band_rows,
            tile_cols=self.tile_cols,
            vertical_policy=self.vertical_policy,
            backend=self.backend,
            precision=self.precision,
            scale=self.scale,
            clip=self.clip,
            preferred_band_rows=self.preferred_band_rows,
        )
        self._memo_put(self._plans, lr_shape, plan)
        return plan

    def _memo_put(self, memo: dict, key, value) -> None:
        """Insert into a memo dict, evicting oldest entries past the cap
        (a pinned session never accumulates shapes, so pins are safe)."""
        memo[key] = value
        while len(memo) > self._memo_cap:
            memo.pop(next(iter(memo)))

    @staticmethod
    def cache_key(plan: SRPlan, bucket: int, dtype) -> tuple:
        return (plan, int(bucket), np.dtype(dtype).name)

    def executor_for(
        self, plan: SRPlan, bucket: int, dtype
    ) -> Tuple[_CacheEntry, bool]:
        """The compiled executor for ``(plan, bucket, dtype)``.

        Cache miss compiles NOW, warmed on a zero dummy in the dtype that
        will actually be served, and records the compile seconds on the
        entry — so no later ``fn`` call on this key pays compilation.
        Returns ``(entry, compiled_now)``.
        """
        key = self.cache_key(plan, bucket, dtype)
        entry = self._cache.get(key)
        if entry is not None:
            return entry, False
        # own jit per entry: evicting the entry drops the only reference
        # this layer holds to the compiled program (the module-level shared
        # jit would pin it for the process); a re-miss re-acquires and
        # re-times — fast when jax's internal caches still hold the program
        fn = build_executor(plan, self.layers, shared_jit=False)
        dummy = jnp.zeros((bucket, *plan.lr_shape), np.dtype(dtype))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(dummy))
        compile_s = time.perf_counter() - t0
        entry = _CacheEntry(
            fn=fn,
            plan=plan,
            bucket=int(bucket),
            dtype=np.dtype(dtype).name,
            compile_s=compile_s,
        )
        self._cache.put(key, entry)
        return entry, True

    def output_dtype(self, plan: SRPlan, dtype) -> np.dtype:
        """The dtype the compiled executor emits for ``dtype`` input
        (abstract eval — no compile, memoised), so degenerate paths —
        empty clips — return exactly what a real batch would."""
        key = (plan, np.dtype(dtype).name)
        out = self._out_dtypes.get(key)
        if out is None:
            out = output_spec(plan, self.layers, 1, np.dtype(dtype)).dtype
            self._memo_put(self._out_dtypes, key, out)
        return out

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        if self._pinned_bucket is not None:
            return self._pinned_bucket
        bucket = bucket_batch(n)
        if self.max_bucket is not None:
            # clamp DOWN to the largest power of two within the cap — the
            # cap is a ceiling (e.g. device memory), never exceeded
            cap = 1 << (self.max_bucket.bit_length() - 1)
            bucket = min(bucket, cap)
        return bucket

    def upscale(self, frames) -> jax.Array:
        """Super-resolve frames of any supported rank.

        ``(H, W, C)`` -> ``(sH, sW, C)``; ``(T, H, W, C)`` ->
        ``(T, sH, sW, C)``; ``(B, T, H, W, C)`` -> ``(B, T, sH, sW, C)``.
        The flattened frame batch is padded up to its bucket and served in
        one compiled call per bucket-sized chunk; padded outputs are
        trimmed and only real frames count in :meth:`stats`.
        """
        arr = jnp.asarray(frames)
        if arr.ndim == 3:
            flat = arr[None]
        elif arr.ndim == 4:
            flat = arr
        elif arr.ndim == 5:
            flat = arr.reshape(arr.shape[0] * arr.shape[1], *arr.shape[2:])
        else:
            raise ValueError(
                "expected (H, W, C), (T, H, W, C) or (B, T, H, W, C) frames, "
                f"got shape {arr.shape}"
            )
        H, W, C = flat.shape[1:]
        plan = self.plan_for((H, W, C))
        hr = self._serve_flat(plan, flat)
        if arr.ndim == 3:
            return hr[0]
        if arr.ndim == 5:
            return hr.reshape(arr.shape[0], arr.shape[1], *plan.hr_shape)
        return hr

    def serve_batch(
        self, plan: SRPlan, frames: jax.Array, real_frames: Optional[int] = None
    ) -> jax.Array:
        """Run ONE pre-bucketed batch through the plan's executor,
        recording its steady-state latency (a cache miss compiles on a
        dummy first, outside the timed region).  ``real_frames`` counts
        only that many leading frames in :meth:`stats` — the rest are
        padding; the full batch is returned.
        """
        n_real = frames.shape[0] if real_frames is None else real_frames
        entry, _ = self.executor_for(plan, frames.shape[0], frames.dtype)
        t0 = time.perf_counter()
        hr = entry.fn(frames)
        jax.block_until_ready(hr)
        self._lat_ms.append((time.perf_counter() - t0) * 1e3)
        self._frames += n_real
        return hr

    def _serve_flat(self, plan: SRPlan, flat: jax.Array) -> jax.Array:
        N = flat.shape[0]
        if N == 0:
            return jnp.zeros(
                (0, *plan.hr_shape), self.output_dtype(plan, flat.dtype)
            )
        bucket = self._bucket_for(N)
        outs = []
        for i in range(0, N, bucket):
            chunk = flat[i : i + bucket]
            n = chunk.shape[0]
            if n < bucket:  # pad up to the compiled bucket, trim after
                pad = jnp.zeros((bucket - n, *chunk.shape[1:]), chunk.dtype)
                chunk = jnp.concatenate([chunk, pad], axis=0)
            outs.append(self.serve_batch(plan, chunk, real_frames=n)[:n])
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    def cache_stats(self) -> dict:
        """Compile-cache counters plus per-entry compile metadata.

        ``hits``/``misses``/``evictions`` are cumulative; ``entries`` lists
        live entries in LRU -> MRU order, each with its plan shape, batch
        bucket, serving dtype and measured compile seconds.
        """
        stats = self._cache.stats()
        stats["entries"] = [
            {
                "lr_shape": list(e.plan.lr_shape),
                "backend": e.plan.backend,
                "precision": e.plan.precision,
                "band_rows": e.plan.band_rows,
                "bucket": e.bucket,
                "dtype": e.dtype,
                "compile_s": e.compile_s,
            }
            for e in self._cache.entries()
        ]
        return stats

    def stats(self, **extra) -> StreamStats:
        """Steady-state serving stats — compile time is never included
        (compilation happens on a dummy inside the cache-miss path)."""
        return latency_stats(self._lat_ms, self._frames, **extra)

    def reset_stats(self) -> None:
        self._lat_ms.clear()
        self._frames = 0
