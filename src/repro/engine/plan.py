"""SRPlan — the single description of a super-resolution execution.

The repo used to express the tilted-fusion schedule three separate times
(full-image reference, pure-JAX band loop, Pallas kernel), glued together by
string dispatch in ``models.abpn.apply_abpn``.  An :class:`SRPlan` captures
everything those paths need — geometry (bands, tile columns, the
:class:`~repro.core.tiling.TileSchedule`), numerics (fp32 / bf16 /
int8-dequant), vertical boundary policy and backend — in one validated,
hashable object that is built once and reused across frames.  The executor
layer (``engine.executor``) compiles a plan + weight stack into a single
jitted callable over a batch of frames.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional, Sequence, Tuple

from repro.core.tiling import TileSchedule, make_schedule

__all__ = [
    "SRPlan",
    "make_plan",
    "check_layer_channels",
    "derive_band_rows",
    "legal_band_rows",
    "shardable_band_rows",
    "BACKENDS",
    "PRECISIONS",
    "VERTICAL_POLICIES",
]

BACKENDS = ("reference", "tilted", "kernel")
PRECISIONS = ("fp32", "bf16", "int8")
VERTICAL_POLICIES = ("zero", "halo", "replicate")

# The paper's design point: 60-row bands for 360-row frames.  Requests for
# other heights derive a legal band height near this (derive_band_rows).
PREFERRED_BAND_ROWS = 60

# Below this band height the per-band recompute/boundary overhead dominates
# (the 3x3 stack's receptive field spans 2L+1 rows); rather than slice a
# frame into slivers, fall back to a single full-height band.
MIN_BAND_ROWS = 8


def legal_band_rows(
    height: int,
    preferred: int = PREFERRED_BAND_ROWS,
    min_rows: int = MIN_BAND_ROWS,
) -> List[int]:
    """ALL legal ``band_rows`` for a frame height, best-default first.

    Banded backends need ``height % band_rows == 0``, so the legal space
    is the divisors of ``height`` that are not degenerate slivers
    (``>= min_rows``), plus the always-legal full-height single band.
    Sorted by distance from ``preferred`` (the paper's 60-row design
    point), ties preferring the divisor ``<= preferred`` — so element 0
    is a sensible default and the whole list is the autotuner's
    ``band_rows`` candidate axis.
    """
    if height <= 0:
        raise ValueError(f"height={height} must be positive")
    divisors = [d for d in range(min_rows, height + 1) if height % d == 0]
    if height not in divisors:
        divisors.append(height)  # one full-height band is always legal
    return sorted(divisors, key=lambda d: (abs(d - preferred), d > preferred))


def derive_band_rows(
    height: int,
    preferred: int = PREFERRED_BAND_ROWS,
    min_rows: int = MIN_BAND_ROWS,
) -> int:
    """The DEFAULT legal ``band_rows`` for an arbitrary frame height.

    Pick the largest divisor of ``height`` that is ``<= preferred`` (the
    paper's 60-row design point); if the only such divisors are degenerate
    slivers (``< min_rows``, e.g. a prime height), serve the frame as one
    full-height band — always legal for any positive height.  The full
    candidate space this default is drawn from is :func:`legal_band_rows`.
    """
    if height <= 0:
        raise ValueError(f"height={height} must be positive")
    if height <= preferred:
        return height
    candidates = [d for d in legal_band_rows(height, preferred, min_rows)
                  if d <= preferred]
    return max(candidates) if candidates else height


def shardable_band_rows(
    height: int,
    band_shards: int,
    preferred: int = PREFERRED_BAND_ROWS,
    min_rows: int = MIN_BAND_ROWS,
) -> Optional[int]:
    """Best legal ``band_rows`` whose band count splits across shards.

    Band-sharded execution places ``num_bands // band_shards`` whole bands
    on each device along the ``bands`` mesh axis, so it needs
    ``(height // band_rows) % band_shards == 0`` on top of the usual
    divisibility.  Returns the highest-preference such divisor from
    :func:`legal_band_rows`, or ``None`` when no legal decomposition
    exists (e.g. more shards than bands at every legal ``band_rows``).
    """
    if band_shards <= 0:
        raise ValueError(f"band_shards={band_shards} must be positive")
    for d in legal_band_rows(height, preferred, min_rows):
        if (height // d) % band_shards == 0:
            return d
    return None


def _is_degenerate_fallback(height: int, band_rows: int, preferred: int) -> bool:
    """True when a derived ``band_rows`` is the one-giant-band fallback —
    the frame is TALLER than the preferred band yet serves as a single
    band (e.g. a prime height with no legal divisor)."""
    return band_rows == height and height > preferred


@dataclasses.dataclass(frozen=True)
class SRPlan:
    """Static plan for running an SR conv stack over LR frames.

    Geometry:
      height/width/in_channels: LR frame shape (H, W, C0).
      num_layers: L, depth of the fused conv stack.
      band_rows: R, rows per band (paper: 60 for 360-row frames).
      tile_cols: C, parallelepiped width of the tilted sweep (paper: 8).
    Numerics:
      precision: ``fp32`` | ``bf16`` | ``int8`` (int8 = symmetric
        weight quantisation with dequant-on-read, ``core.quant``).
    Policy:
      vertical_policy: ``zero`` | ``halo`` | ``replicate`` band boundaries.
      backend: ``reference`` | ``tilted`` | ``kernel`` datapath.
    Output:
      scale: pixel-shuffle upscale factor (anchor residual is added).
      clip: clip HR output to [0, 1].
    Diagnostics:
      degenerate_bands: the derived ``band_rows`` was the one-giant-band
        fallback (a taller-than-preferred frame with no legal divisor,
        e.g. a prime height).  Metadata only — excluded from equality and
        hashing so plan/cache keys are unaffected.
    """

    height: int
    width: int
    in_channels: int = 3
    num_layers: int = 7
    band_rows: int = 60
    tile_cols: int = 8
    vertical_policy: str = "zero"
    backend: str = "tilted"
    precision: str = "fp32"
    scale: int = 3
    clip: bool = True
    degenerate_bands: bool = dataclasses.field(default=False, compare=False)

    def __post_init__(self):
        if self.height <= 0 or self.width <= 0 or self.in_channels <= 0:
            raise ValueError(
                f"frame shape ({self.height}, {self.width}, {self.in_channels}) "
                "must be positive"
            )
        if self.num_layers <= 0:
            raise ValueError(f"num_layers={self.num_layers} must be positive")
        if self.scale < 1:
            raise ValueError(f"scale={self.scale} must be >= 1")
        if self.band_rows <= 0:
            raise ValueError(f"band_rows={self.band_rows} must be positive")
        if self.backend != "reference" and self.height % self.band_rows != 0:
            # the reference backend has no bands; only banded datapaths
            # need the height to partition evenly
            raise ValueError(
                f"height {self.height} must be a multiple of "
                f"band_rows {self.band_rows} for backend {self.backend!r}"
            )
        if self.tile_cols < 2:
            raise ValueError(
                f"tile_cols={self.tile_cols} must be >= 2 "
                "(overlap hand-off is 2 columns)"
            )
        if self.vertical_policy not in VERTICAL_POLICIES:
            raise ValueError(
                f"vertical_policy {self.vertical_policy!r} not in {VERTICAL_POLICIES}"
            )
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        if self.precision not in PRECISIONS:
            raise ValueError(f"precision {self.precision!r} not in {PRECISIONS}")

    # ------------------------------------------------------------------
    # Derived geometry
    # ------------------------------------------------------------------
    @property
    def num_bands(self) -> int:
        return self.height // self.band_rows

    @property
    def schedule(self) -> TileSchedule:
        """The tilted sweep geometry shared by every backend."""
        return make_schedule(
            width=self.width, tile_cols=self.tile_cols, num_layers=self.num_layers
        )

    @property
    def lr_shape(self) -> Tuple[int, int, int]:
        return (self.height, self.width, self.in_channels)

    @property
    def hr_shape(self) -> Tuple[int, int, int]:
        return (self.height * self.scale, self.width * self.scale, self.in_channels)

    @property
    def stack_key(self) -> Tuple[str, str]:
        """Key of the device-resident prepared weight stack this plan's
        executor consumes.  Weight preparation (numerics policy + kernel
        packing) depends only on ``(precision, backend)`` — NOT on frame
        geometry, bucket or serving dtype — so every resolution/bucket a
        session serves shares one ``PreparedStack`` under this key."""
        return (self.precision, self.backend)

    def check_invariants(self) -> None:
        """Validate the full plan: field constraints ran in ``__post_init__``;
        this additionally asserts the tilted schedule's hand-off invariants
        for every (tile, layer)."""
        self.schedule.check_invariants()

    def verify(self, **kwargs):
        """Statically verify this plan (band coverage, halo sufficiency,
        Table II on-chip budget) and return the list of
        :class:`~repro.analysis.findings.Finding` diagnostics — empty when
        clean.  Keyword overrides (``channels``, ``budget_kb``,
        ``halo_margin``) pass through to
        :func:`repro.analysis.plan_check.verify_plan`."""
        from repro.analysis.plan_check import verify_plan  # lazy: no cycle

        return verify_plan(self, **kwargs)

    # ------------------------------------------------------------------
    # Construction from a serving request
    # ------------------------------------------------------------------
    @classmethod
    def from_request(
        cls,
        lr_shape: Tuple[int, int, int],
        *,
        num_layers: int,
        band_rows: int | None = None,
        tile_cols: int = 8,
        vertical_policy: str = "zero",
        backend: str = "tilted",
        precision: str = "fp32",
        scale: int = 3,
        clip: bool = True,
        preferred_band_rows: int = PREFERRED_BAND_ROWS,
        validate: bool = True,
        tuner: Optional[object] = None,
        bucket: Optional[int] = None,
    ) -> "SRPlan":
        """Build a plan for an arbitrary request shape — the ONE owner of
        the shape -> geometry derivation.

        ``band_rows=None`` derives a legal band height for the incoming
        frame (:func:`derive_band_rows`), so any positive ``(H, W, C)`` is
        servable without the caller knowing the banding rules.  This is
        what :class:`~repro.engine.session.SRSession` calls per new
        resolution; ``make_plan`` routes through it with an explicit
        ``band_rows``.

        ``tuner`` (a :class:`~repro.engine.autotune.PlanTuner`) is
        consulted BEFORE the default derivation: if its tuning database
        holds a measured-best ``band_rows`` for this exact configuration
        (optionally at batch ``bucket``), that schedule wins; a miss falls
        back to the unchanged defaults.  The tuner only ever returns
        numerics-safe overrides (see ``PlanTuner.band_rows_for``).

        A derived one-giant-band fallback (a taller-than-preferred frame
        with no legal divisor, e.g. a prime height) is no longer silent:
        it warns and the plan records ``degenerate_bands=True``.
        """
        if len(lr_shape) != 3:
            raise ValueError(f"lr_shape {lr_shape!r} must be (H, W, C)")
        H, W, C = (int(x) for x in lr_shape)
        degenerate = False
        if band_rows is None:
            if tuner is not None:
                band_rows = tuner.band_rows_for(
                    lr_shape=(H, W, C),
                    num_layers=num_layers,
                    tile_cols=tile_cols,
                    vertical_policy=vertical_policy,
                    backend=backend,
                    precision=precision,
                    scale=scale,
                    clip=clip,
                    bucket=bucket,
                )
            if band_rows is None:
                band_rows = derive_band_rows(H, preferred_band_rows)
                # a tuner override is a MEASURED choice, never degenerate;
                # only the silent default fallback warrants the signal
                degenerate = _is_degenerate_fallback(H, band_rows,
                                                     preferred_band_rows)
            if degenerate:
                warnings.warn(
                    f"height {H} has no band decomposition with bands in "
                    f"[{MIN_BAND_ROWS}, {preferred_band_rows}] rows; serving "
                    f"as ONE {H}-row band (degenerate_bands=True on the "
                    "plan) — banded backends lose their streaming locality "
                    "at this height",
                    RuntimeWarning,
                    stacklevel=2,
                )
        plan = cls(
            height=H,
            width=W,
            in_channels=C,
            num_layers=num_layers,
            band_rows=band_rows,
            tile_cols=tile_cols,
            vertical_policy=vertical_policy,
            backend=backend,
            precision=precision,
            scale=scale,
            clip=clip,
            degenerate_bands=degenerate,
        )
        if validate:
            plan.check_invariants()
        return plan


def make_plan(
    layers: Sequence,
    lr_shape: Tuple[int, int, int],
    *,
    band_rows: int = 60,
    tile_cols: int = 8,
    vertical_policy: str = "zero",
    backend: str = "tilted",
    precision: str = "fp32",
    scale: int = 3,
    clip: bool = True,
    validate: bool = True,
) -> SRPlan:
    """Build (and optionally fully validate) an :class:`SRPlan` from a conv
    stack and an LR frame shape.

    ``layers`` is a ``Sequence[ConvLayer]`` — only its length and input
    channel count are read, so quantised stacks work too.
    """
    if len(layers) == 0:
        raise ValueError("layer stack is empty")
    H, W, C0 = lr_shape
    plan = SRPlan.from_request(
        (H, W, C0),
        num_layers=len(layers),
        band_rows=band_rows,
        tile_cols=tile_cols,
        vertical_policy=vertical_policy,
        backend=backend,
        precision=precision,
        scale=scale,
        clip=clip,
        validate=False,
    )
    check_layer_channels(layers, C0, scale)
    if validate:
        plan.check_invariants()
    return plan


def check_layer_channels(layers: Sequence, in_channels: int, scale: int) -> None:
    """Assert a conv stack fits ``in_channels`` frames and the anchor +
    pixel-shuffle epilogue at ``scale`` (shared by ``make_plan`` and
    ``SRSession``)."""
    lc = getattr(layers[0], "ci", None)
    if lc is not None and lc != in_channels:
        raise ValueError(
            f"layer stack expects {lc} input channels, frames have {in_channels}"
        )
    co = getattr(layers[-1], "co", None)
    if co is not None and co != in_channels * scale * scale:
        raise ValueError(
            f"final layer produces {co} channels; the anchor + pixel-shuffle "
            f"epilogue needs in_channels * scale^2 = {in_channels * scale * scale}"
        )
