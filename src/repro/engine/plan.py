"""SRPlan — the single description of a super-resolution execution.

The repo used to express the tilted-fusion schedule three separate times
(full-image reference, pure-JAX band loop, Pallas kernel), glued together by
string dispatch in ``models.abpn.apply_abpn``.  An :class:`SRPlan` captures
everything those paths need — geometry (bands, tile columns, the
:class:`~repro.core.tiling.TileSchedule`), numerics (fp32 / bf16 /
int8-dequant), vertical boundary policy and backend — in one validated,
hashable object that is built once and reused across frames.  The executor
layer (``engine.executor``) compiles a plan + weight stack into a single
jitted callable over a batch of frames.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from repro.core.tiling import TileSchedule, make_schedule

__all__ = ["SRPlan", "make_plan", "BACKENDS", "PRECISIONS", "VERTICAL_POLICIES"]

BACKENDS = ("reference", "tilted", "kernel")
PRECISIONS = ("fp32", "bf16", "int8")
VERTICAL_POLICIES = ("zero", "halo", "replicate")


@dataclasses.dataclass(frozen=True)
class SRPlan:
    """Static plan for running an SR conv stack over LR frames.

    Geometry:
      height/width/in_channels: LR frame shape (H, W, C0).
      num_layers: L, depth of the fused conv stack.
      band_rows: R, rows per band (paper: 60 for 360-row frames).
      tile_cols: C, parallelepiped width of the tilted sweep (paper: 8).
    Numerics:
      precision: ``fp32`` | ``bf16`` | ``int8`` (int8 = symmetric
        weight quantisation with dequant-on-read, ``core.quant``).
    Policy:
      vertical_policy: ``zero`` | ``halo`` | ``replicate`` band boundaries.
      backend: ``reference`` | ``tilted`` | ``kernel`` datapath.
    Output:
      scale: pixel-shuffle upscale factor (anchor residual is added).
      clip: clip HR output to [0, 1].
    """

    height: int
    width: int
    in_channels: int = 3
    num_layers: int = 7
    band_rows: int = 60
    tile_cols: int = 8
    vertical_policy: str = "zero"
    backend: str = "tilted"
    precision: str = "fp32"
    scale: int = 3
    clip: bool = True

    def __post_init__(self):
        if self.height <= 0 or self.width <= 0 or self.in_channels <= 0:
            raise ValueError(
                f"frame shape ({self.height}, {self.width}, {self.in_channels}) "
                "must be positive"
            )
        if self.num_layers <= 0:
            raise ValueError(f"num_layers={self.num_layers} must be positive")
        if self.scale < 1:
            raise ValueError(f"scale={self.scale} must be >= 1")
        if self.band_rows <= 0:
            raise ValueError(f"band_rows={self.band_rows} must be positive")
        if self.backend != "reference" and self.height % self.band_rows != 0:
            # the reference backend has no bands; only banded datapaths
            # need the height to partition evenly
            raise ValueError(
                f"height {self.height} must be a multiple of "
                f"band_rows {self.band_rows} for backend {self.backend!r}"
            )
        if self.tile_cols < 2:
            raise ValueError(
                f"tile_cols={self.tile_cols} must be >= 2 "
                "(overlap hand-off is 2 columns)"
            )
        if self.vertical_policy not in VERTICAL_POLICIES:
            raise ValueError(
                f"vertical_policy {self.vertical_policy!r} not in {VERTICAL_POLICIES}"
            )
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        if self.precision not in PRECISIONS:
            raise ValueError(f"precision {self.precision!r} not in {PRECISIONS}")

    # ------------------------------------------------------------------
    # Derived geometry
    # ------------------------------------------------------------------
    @property
    def num_bands(self) -> int:
        return self.height // self.band_rows

    @property
    def schedule(self) -> TileSchedule:
        """The tilted sweep geometry shared by every backend."""
        return make_schedule(
            width=self.width, tile_cols=self.tile_cols, num_layers=self.num_layers
        )

    @property
    def lr_shape(self) -> Tuple[int, int, int]:
        return (self.height, self.width, self.in_channels)

    @property
    def hr_shape(self) -> Tuple[int, int, int]:
        return (self.height * self.scale, self.width * self.scale, self.in_channels)

    def check_invariants(self) -> None:
        """Validate the full plan: field constraints ran in ``__post_init__``;
        this additionally asserts the tilted schedule's hand-off invariants
        for every (tile, layer)."""
        self.schedule.check_invariants()


def make_plan(
    layers: Sequence,
    lr_shape: Tuple[int, int, int],
    *,
    band_rows: int = 60,
    tile_cols: int = 8,
    vertical_policy: str = "zero",
    backend: str = "tilted",
    precision: str = "fp32",
    scale: int = 3,
    clip: bool = True,
    validate: bool = True,
) -> SRPlan:
    """Build (and optionally fully validate) an :class:`SRPlan` from a conv
    stack and an LR frame shape.

    ``layers`` is a ``Sequence[ConvLayer]`` — only its length and input
    channel count are read, so quantised stacks work too.
    """
    if len(layers) == 0:
        raise ValueError("layer stack is empty")
    H, W, C0 = lr_shape
    plan = SRPlan(
        height=H,
        width=W,
        in_channels=C0,
        num_layers=len(layers),
        band_rows=band_rows,
        tile_cols=tile_cols,
        vertical_policy=vertical_policy,
        backend=backend,
        precision=precision,
        scale=scale,
        clip=clip,
    )
    lc = getattr(layers[0], "ci", None)
    if lc is not None and lc != C0:
        raise ValueError(
            f"layer stack expects {lc} input channels, frames have {C0}"
        )
    co = getattr(layers[-1], "co", None)
    if co is not None and co != C0 * scale * scale:
        raise ValueError(
            f"final layer produces {co} channels; the anchor + pixel-shuffle "
            f"epilogue needs in_channels * scale^2 = {C0 * scale * scale}"
        )
    if validate:
        plan.check_invariants()
    return plan
