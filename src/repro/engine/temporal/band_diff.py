"""Per-band frame diffing for temporal delta serving.

Video streams change a few bands per frame (a static camera changes
almost none); the band decomposition the engine already serves on makes
that reuse addressable.  This module provides the *content* side of the
delta path:

* digests — a cheap content hash per band.  ``band_digests`` hashes each
  band's OWN input rows (change detection between consecutive frames);
  ``window_digest`` hashes the band's full receptive-field WINDOW — own
  rows plus the halo margin rows its stacked 3x3 convs read — which is
  what the output actually depends on, so it keys the output cache.
* dirty-set dilation — a changed band feeds the receptive field of its
  neighbors under the ``halo`` policy, so the dirty set must be dilated
  by the halo reach (``ceil(L / R)`` bands for an L-deep stack over
  R-row bands; 0 for ``zero``/``replicate``, whose bands are
  independent).  The invariant the splice relies on:

      band not in dilate(changed)  =>  its window rows are unchanged
                                   =>  its cached output is still exact.

* slab/bounds construction — host-side mirrors of the one true
  ``core.fusion.halo_slabs`` geometry, so a partial-band dispatch feeds
  the kernel byte-identical inputs to what the full-frame path would
  have marshalled (tests cross-check them against ``halo_slabs``).

Digests are ``blake2b(digest_size=16)`` over the raw bytes of the
serving-dtype-cast rows, with the dtype folded into the hash (same
bytes under a different dtype must not collide).  blake2b is in the
standard library — no xxhash dependency — and 16 bytes keeps keys
small while making accidental collision probability negligible.
"""

from __future__ import annotations

import hashlib
from typing import Iterable, List, Sequence, Set, Tuple

import numpy as np

__all__ = [
    "BAND_DIGEST_ALGO",
    "band_digest",
    "band_digests",
    "band_input_rows",
    "band_slabs",
    "band_bounds",
    "changed_bands",
    "dilate_dirty",
    "halo_reach",
    "window_digest",
    "window_rows",
]

BAND_DIGEST_ALGO = "blake2b-128"


def _digest_rows(frame: np.ndarray, lo: int, hi: int) -> bytes:
    """Digest of ``frame[lo:hi]`` with the dtype folded in."""
    h = hashlib.blake2b(digest_size=16)
    h.update(frame.dtype.str.encode("ascii"))
    rows = frame[lo:hi]
    if not rows.flags["C_CONTIGUOUS"]:
        rows = np.ascontiguousarray(rows)
    h.update(rows)
    return h.digest()


def band_digest(frame: np.ndarray, band_rows: int, band: int) -> bytes:
    """Digest of band ``band``'s own input rows."""
    return _digest_rows(frame, band * band_rows, (band + 1) * band_rows)


def band_digests(frame: np.ndarray, band_rows: int) -> Tuple[bytes, ...]:
    """Own-rows digest of every band of a (H, W, C) frame."""
    height = frame.shape[0]
    if height % band_rows != 0:
        raise ValueError(
            f"height {height} is not a multiple of band_rows {band_rows}"
        )
    return tuple(
        band_digest(frame, band_rows, b) for b in range(height // band_rows)
    )


def changed_bands(
    digests: Sequence[bytes], prev: Sequence[bytes]
) -> Set[int]:
    """Bands whose own-rows digest differs from the previous frame's."""
    if len(digests) != len(prev):
        raise ValueError(
            f"digest count changed between frames: {len(prev)} -> "
            f"{len(digests)} (same plan implies same band count)"
        )
    return {b for b, (d, p) in enumerate(zip(digests, prev)) if d != p}


def halo_reach(band_rows: int, num_layers: int, vertical_policy: str) -> int:
    """How many neighbor bands a changed band invalidates, per side.

    Under ``halo`` a band's receptive field reaches L real rows past its
    own, so a change in band b touches every band whose window overlaps
    rows [b*R, b*R + R): reach = ceil(L / R) bands (1 at the paper's
    design point, L=7 over R=60).  ``zero``/``replicate`` bands never
    read neighbor rows: reach 0.
    """
    if vertical_policy != "halo":
        return 0
    return -(-num_layers // band_rows)


def dilate_dirty(
    changed: Iterable[int],
    num_bands: int,
    band_rows: int,
    num_layers: int,
    vertical_policy: str,
) -> Set[int]:
    """Dilate the changed-band set by the halo reach (clipped to range)."""
    reach = halo_reach(band_rows, num_layers, vertical_policy)
    dirty: Set[int] = set()
    for b in changed:
        b = int(b)
        if not 0 <= b < num_bands:
            raise ValueError(f"changed band {b} out of range [0, {num_bands})")
        lo = max(0, b - reach)
        hi = min(num_bands, b + reach + 1)
        dirty.update(range(lo, hi))
    return dirty


def window_rows(
    height: int,
    band_rows: int,
    num_layers: int,
    band: int,
    vertical_policy: str,
) -> Tuple[int, int]:
    """Real-row interval [lo, hi) a band's output depends on.

    ``halo``: own rows widened by L per side, clipped to the frame (the
    out-of-frame part of the margin is constant zero padding, identical
    for every frame at the same band index, so it carries no content and
    stays out of the digest).  ``zero``/``replicate``: own rows only.
    """
    lo = band * band_rows
    hi = lo + band_rows
    if vertical_policy == "halo":
        lo = max(0, lo - num_layers)
        hi = min(height, hi + num_layers)
    return lo, hi


def window_digest(
    frame: np.ndarray,
    band_rows: int,
    num_layers: int,
    band: int,
    vertical_policy: str,
) -> bytes:
    """Digest of the receptive-field window — the output-cache key digest."""
    lo, hi = window_rows(
        frame.shape[0], band_rows, num_layers, band, vertical_policy
    )
    return _digest_rows(frame, lo, hi)


def band_input_rows(
    band_rows: int, num_layers: int, vertical_policy: str
) -> int:
    """Input rows per dispatched band slab (R + 2L under ``halo``)."""
    if vertical_policy == "halo":
        return band_rows + 2 * num_layers
    return band_rows


def band_slabs(
    frame: np.ndarray,
    band_rows: int,
    num_layers: int,
    bands: Sequence[int],
    vertical_policy: str,
) -> np.ndarray:
    """Host-side input slabs for a band subset of one (H, W, C) frame.

    Mirrors ``core.fusion.halo_slabs`` exactly (L rows of zero padding
    above and below the frame; slab b = padded rows [b*R, b*R + R + 2L))
    so a partial dispatch is byte-identical to the corresponding rows of
    a full-frame dispatch — the bit-exact splice guarantee starts here.
    """
    height, width, chans = frame.shape
    rows = band_input_rows(band_rows, num_layers, vertical_policy)
    out = np.zeros((len(bands), rows, width, chans), frame.dtype)
    if vertical_policy == "halo":
        padded = np.zeros((height + 2 * num_layers, width, chans), frame.dtype)
        padded[num_layers : num_layers + height] = frame
        for i, b in enumerate(bands):
            out[i] = padded[b * band_rows : b * band_rows + rows]
    else:
        for i, b in enumerate(bands):
            out[i] = frame[b * band_rows : (b + 1) * band_rows]
    return out


def band_bounds(
    height: int,
    band_rows: int,
    num_layers: int,
    bands: Sequence[int],
    *,
    slots: int = 0,
) -> np.ndarray:
    """Per-slab valid-row bounds, the ``halo_slabs`` formula verbatim.

    Row r of slab b is a real frame row iff ``lo <= r < hi`` with
    ``lo = clip(L - b*R, 0, rows)`` and ``hi = clip(L + H - b*R, 0,
    rows)``; rows outside are phantom padding the kernel re-zeroes.
    ``slots`` pads the array to a bucket size; padded slots get (0, 0)
    (all rows phantom), so a padded slab computes zero features and its
    output rows are never read.
    """
    rows = band_rows + 2 * num_layers
    n = max(len(bands), slots)
    out = np.zeros((n, 2), np.int32)
    for i, b in enumerate(bands):
        lo = min(max(num_layers - b * band_rows, 0), rows)
        hi = min(max(num_layers + height - b * band_rows, 0), rows)
        out[i] = (lo, hi)
    return out
