"""Temporal delta serving: band-level frame diffing and output reuse.

Consecutive video frames usually change a few bands (a static camera
changes almost none); the band decomposition the engine already serves
on makes that reuse addressable.  This package turns it into a serving
mode:

* :mod:`~repro.engine.temporal.band_diff` — per-band content digests,
  halo-reach dirty-set dilation, and host-side slab/bounds marshalling
  in the one true ``core.fusion.halo_slabs`` geometry;
* :mod:`~repro.engine.temporal.output_cache` — a bounded, refcounted
  LRU of upscaled HR output bands keyed by (plan, band, window digest);
* :mod:`~repro.engine.temporal.delta_stream` — :class:`DeltaSession`,
  which dispatches only dirty bands (``SRServer.submit_bands`` ->
  partial-band dispatches through the micro-batch scheduler) and
  splices clean bands from cache, bit-exact vs full re-upscale.

Entry points: ``SRServer.stream(delta=True)`` for the async streaming
path, or a :class:`DeltaSession` directly for synchronous per-frame
control.  Stats land in ``session.stats()['temporal']``.
"""

from repro.engine.temporal.band_diff import (
    BAND_DIGEST_ALGO,
    band_bounds,
    band_digest,
    band_digests,
    band_input_rows,
    band_slabs,
    changed_bands,
    dilate_dirty,
    halo_reach,
    window_digest,
    window_rows,
)
from repro.engine.temporal.delta_stream import DeltaSession
from repro.engine.temporal.output_cache import (
    DEFAULT_CACHE_BYTES,
    OutputBandCache,
)

__all__ = [
    "BAND_DIGEST_ALGO",
    "DEFAULT_CACHE_BYTES",
    "DeltaSession",
    "OutputBandCache",
    "band_bounds",
    "band_digest",
    "band_digests",
    "band_input_rows",
    "band_slabs",
    "changed_bands",
    "dilate_dirty",
    "halo_reach",
    "window_digest",
    "window_rows",
]
