"""Delta-aware video serving: diff, dispatch dirty bands, splice.

:class:`DeltaSession` is the temporal subsystem's driver.  Per frame:

1. cast to the session's serving dtype and digest every band's own rows
   (``band_diff.band_digests``);
2. diff against the previous frame's digests and dilate the changed set
   by the halo reach (``band_diff.dilate_dirty``) — a changed band
   invalidates every neighbor whose receptive field it feeds;
3. verify the splice partition (``plan_check.verify_delta_cover``): the
   dirty set plus the cached clean bands must cover every output row
   exactly once and dominate the dilation — a violation raises before
   anything dispatches (splice correctness is the subsystem's contract,
   so the rule is always strict);
4. dispatch ONLY the dirty bands as one partial-band request
   (``SRServer.submit_bands`` -> ``Dispatch.band_subset`` through the
   micro-batch scheduler) with input slabs marshalled host-side in the
   exact ``core.fusion.halo_slabs`` geometry;
5. splice the HR frame: fresh rows from the dispatch, clean rows from
   the :class:`~repro.engine.temporal.output_cache.OutputBandCache`,
   keyed by ``(plan, band, window_digest)`` — the digest of the band's
   full receptive-field window, so a hit PROVES the cached rows were
   computed from byte-identical input.

That proof is the bit-exactness argument end to end: identical window
bytes -> identical executor input (band slabs mirror ``halo_slabs``
byte-for-byte) -> identical per-band program (the band executor runs
the same per-slab computation the full-frame path vmaps/grids over,
and band outputs are independent of batch composition) -> identical HR
rows.  The parity tests assert equality with full re-upscale per
backend x boundary policy, including against a band-sharded mesh
session's full path.

Delta streams are sequential by construction — frame k's dirty set
needs frame k-1's digests — so there is no cross-frame lookahead.  They
also bypass the server's degrade dtype ladder (a mid-clip downcast
would poison the cache and break the contract) and, on mesh sessions,
band sharding: partial dispatches run on the local device, and the
guarantee vs the sharded full path holds transitively because sharded
vs single-device full re-upscale is already bit-exact.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.engine.temporal.band_diff import (
    band_digests,
    band_input_rows,
    band_slabs,
    changed_bands,
    dilate_dirty,
    window_digest,
)
from repro.engine.temporal.output_cache import OutputBandCache

__all__ = ["DeltaSession"]


class DeltaSession:
    """Serve a video stream delta-aware against one hosted session.

    ``session`` must use a banded backend (``tilted`` | ``kernel``) —
    the reference backend has no band decomposition to reuse.
    ``server`` defaults to the session's hosting/embedded server;
    ``cache_bytes`` bounds the shared output cache (only applied when
    this call creates it).  Not thread-safe per instance (the cache it
    shares is); run one ``DeltaSession`` per stream.
    """

    def __init__(self, session, *, server=None, priority: int = 0,
                 cache_bytes: Optional[int] = None):
        if session.backend == "reference":
            raise ValueError(
                "delta serving needs a banded backend (tilted or kernel); "
                "the reference backend computes whole frames"
            )
        self.session = session
        self._server = server if server is not None else session._host_server()
        self._model = self._server._name_for(session)
        self._priority = int(priority)
        self._cache: OutputBandCache = session.output_cache(cache_bytes)
        self._plan = None
        self._prev_own: Optional[Tuple[bytes, ...]] = None
        self._prev_window: List[Optional[bytes]] = []
        self._pinned: List[tuple] = []
        self._inflight = None
        self._closed = False
        self.frames = 0

    # ------------------------------------------------------------------
    def _reset_plan(self, plan) -> None:
        """A resolution/plan switch resets temporal state (digests keyed
        to the old geometry are meaningless); cache pins carry the OLD
        plan in their keys and are released."""
        for key in self._pinned:
            self._cache.unpin(key)
        self._pinned = []
        self._plan = plan
        self._prev_own = None
        self._prev_window = [None] * plan.num_bands

    def _key(self, plan, band: int, digest: bytes) -> tuple:
        return (plan, int(band), digest)

    def serve(self, frame) -> np.ndarray:
        """Upscale one ``(H, W, C)`` frame, reusing cached output bands
        (blocking; returns the HR frame as host numpy)."""
        if self._closed:
            raise RuntimeError("DeltaSession is closed")
        session = self.session
        arr = np.asarray(frame)
        if arr.ndim != 3:
            raise ValueError(
                f"DeltaSession serves single (H, W, C) frames, got rank "
                f"{arr.ndim}"
            )
        dtype = session.serving_dtype(arr.dtype)
        arr = np.ascontiguousarray(arr.astype(dtype, copy=False))
        plan = session.plan_for(tuple(int(x) for x in arr.shape))
        if plan is not self._plan:
            self._reset_plan(plan)
        num_bands = plan.num_bands
        own = band_digests(arr, plan.band_rows)
        if self._prev_own is None:
            changed = set(range(num_bands))
        else:
            changed = changed_bands(own, self._prev_own)
        dirty = dilate_dirty(
            changed, num_bands, plan.band_rows, plan.num_layers,
            plan.vertical_policy,
        )
        # window digests: recompute for dirty bands; a clean band's window
        # is unchanged by the dilation invariant, so its digest carries over
        window = list(self._prev_window)
        for b in dirty:
            window[b] = window_digest(
                arr, plan.band_rows, plan.num_layers, b, plan.vertical_policy
            )
        # a clean band must be resident to splice — normally guaranteed by
        # the pins on the previous frame's entries, but re-serve it if the
        # cache was cleared/evicted externally (its window is unchanged,
        # so recomputing it is pure cost, never a correctness issue)
        clean = []
        for b in range(num_bands):
            if b in dirty:
                continue
            if self._cache.peek(self._key(plan, b, window[b])) is None:
                dirty.add(b)
            else:
                clean.append(b)
        self._verify_cover(plan, dirty, changed)
        dirty_list = sorted(dirty)
        hr_bands = None
        if dirty_list:
            slabs = band_slabs(
                arr, plan.band_rows, plan.num_layers, dirty_list,
                plan.vertical_policy,
            )
            fut = self._server.submit_bands(
                slabs, dirty_list, plan=plan, model=self._model,
                priority=self._priority,
            )
            self._inflight = fut
            try:
                hr_bands = np.asarray(fut.result())
            finally:
                self._inflight = None
        # --- splice ----------------------------------------------------
        # Pin-on-access (put/get with pin=True): this frame's bands are
        # the next frame's splice sources, and the pin must be atomic
        # with the insert/lookup — with a tiny or contended cache a
        # separate pin() after the loop could find its entry already
        # evicted.  On any failure mid-splice the partial pin set is
        # released before re-raising.
        out_dtype = (hr_bands.dtype if hr_bands is not None
                     else session.output_dtype(plan, dtype))
        out = np.empty(plan.hr_shape, out_dtype)
        hr_rows = plan.band_rows * plan.scale
        keys: List[tuple] = []
        try:
            for i, b in enumerate(dirty_list):
                out[b * hr_rows:(b + 1) * hr_rows] = hr_bands[i]
                key = self._key(plan, b, window[b])
                self._cache.put(key, hr_bands[i], pin=True)
                keys.append(key)
            for b in clean:
                key = self._key(plan, b, window[b])
                rows = self._cache.get(key, pin=True)
                if rows is None:  # pragma: no cover - pinned on entry
                    raise RuntimeError(
                        f"clean band {b} vanished from the output cache "
                        "mid-splice (its previous-frame pin was released "
                        "externally)"
                    )
                keys.append(key)
                out[b * hr_rows:(b + 1) * hr_rows] = rows
        except BaseException:
            for key in keys:
                self._cache.unpin(key)
            raise
        for key in self._pinned:
            self._cache.unpin(key)
        self._pinned = keys
        self._account(plan, num_bands, len(dirty_list), arr, out)
        self._prev_own = own
        self._prev_window = window
        self.frames += 1
        return out

    def _verify_cover(self, plan, dirty, changed) -> None:
        """The plan_check splice rule, enforced before anything dispatches."""
        # deferred: engine.temporal must stay importable without pulling
        # the analysis package in at module-import time
        from repro.analysis.plan_check import verify_delta_cover

        errors = [
            f for f in verify_delta_cover(
                plan, sorted(dirty), changed_bands=sorted(changed)
            )
            if f.severity == "error"
        ]
        if errors:
            self.session._temporal_counts["cover_violations"] += len(errors)
            raise RuntimeError(
                "delta splice invariant violated:\n"
                + "\n".join(f.format() for f in errors)
            )

    def _account(self, plan, num_bands: int, served: int, arr, out) -> None:
        """Per-frame reuse accounting (the ``temporal`` stats section).

        The HBM-traffic model matches the paper's metric shape: LR slab
        bytes read plus HR band bytes written, per frame — weights are
        resident either way and excluded.
        """
        t = self.session._temporal_counts
        slab_rows = band_input_rows(
            plan.band_rows, plan.num_layers, plan.vertical_policy
        )
        lr_band_bytes = slab_rows * plan.width * plan.in_channels * arr.itemsize
        hr_band_bytes = (
            plan.band_rows * plan.scale * plan.width * plan.scale
            * plan.in_channels * out.itemsize
        )
        t["frames"] += 1
        t["bands_total"] += num_bands
        t["bands_skipped"] += num_bands - served
        t["band_rows_total"] += num_bands * plan.band_rows
        t["band_rows_served"] += served * plan.band_rows
        t["hbm_bytes_full"] += num_bands * (lr_band_bytes + hr_band_bytes)
        t["hbm_bytes_served"] += served * (lr_band_bytes + hr_band_bytes)

    def stats(self) -> dict:
        """The owning session's ``temporal`` stats section."""
        return self.session.temporal_stats()

    def close(self) -> None:
        """Release every cache pin (and cancel an in-flight dispatch, if
        the stream was abandoned mid-serve).  Idempotent."""
        if self._closed:
            return
        self._closed = True
        fut = self._inflight
        if fut is not None:
            self._server.cancel(fut)
            self._inflight = None
        for key in self._pinned:
            self._cache.unpin(key)
        self._pinned = []

    def __enter__(self) -> "DeltaSession":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()
