"""Bounded, refcounted cache of upscaled HR output bands.

The value side of delta serving: once a band's receptive-field window
has been upscaled, the HR rows are kept keyed by
``(plan, band_index, window_digest)`` so the next frame that presents
the same window bytes splices them back instead of recomputing.

Semantics:

* LRU bounded by ``max_bytes`` of stored HR band payload.  Eviction
  walks from the least recently used entry and skips pinned ones.
* Pins are refcounts: a :class:`~repro.engine.temporal.delta_stream.
  DeltaSession` pins every band of its current frame (they are the
  splice sources for the next frame) and releases the previous frame's
  pins after each step, so an abandoned stream that calls ``close()``
  leaves ``pinned == 0`` — the leak test asserts exactly that.  If
  every entry is pinned the cache may transiently exceed ``max_bytes``
  (``bytes > max_bytes`` in :meth:`stats` makes that visible) rather
  than evict a row another frame is about to splice.
* Counters — hits/misses/evictions/puts/``bytes_saved`` (HR bytes
  served from cache instead of recomputed) — feed the session's
  ``temporal`` stats section and the bench record.

Thread safety: a single lock guards the map and counters.  Values are
copied to contiguous arrays *before* taking the lock (no array
marshalling under the lock — concurrency_lint's blocking-under-lock
rule applies to this module) and handed out as stored; callers copy
out of them and must not mutate.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Hashable, Optional, Tuple

import numpy as np

__all__ = ["DEFAULT_CACHE_BYTES", "OutputBandCache"]

# Generous for the design-point stream (360x640 -> x3: a 60-row HR band
# is ~2.5 MB fp32, one 1080-row HR frame ~44 MB) while still bounding a
# long multi-plan session.  Override per stream via ``cache_bytes``.
DEFAULT_CACHE_BYTES = 256 * 1024 * 1024


@dataclass
class _Entry:
    value: np.ndarray
    nbytes: int
    pins: int = 0


class OutputBandCache:
    """LRU + refcount cache of HR output bands (see module docstring)."""

    def __init__(self, max_bytes: int = DEFAULT_CACHE_BYTES):
        if max_bytes < 1:
            raise ValueError(f"max_bytes={max_bytes} must be positive")
        self.max_bytes = int(max_bytes)
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Hashable, _Entry]" = OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.puts = 0
        self.bytes_saved = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def peek(self, key: Hashable) -> Optional[np.ndarray]:
        """Presence probe: no counters, no recency touch."""
        with self._lock:
            e = self._entries.get(key)
            return None if e is None else e.value

    def get(self, key: Hashable, *, pin: bool = False
            ) -> Optional[np.ndarray]:
        """Counted lookup; a hit refreshes recency and adds bytes_saved.
        ``pin=True`` takes a reference atomically with the hit (a
        separate ``pin()`` call could race an eviction between the two);
        a miss pins nothing."""
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            self.bytes_saved += e.nbytes
            if pin:
                e.pins += 1
            return e.value

    def put(self, key: Hashable, value: np.ndarray, *,
            pin: bool = False) -> None:
        """Insert an HR band (no-op if present: same key => same bytes).
        ``pin=True`` takes a reference atomically with the insert — the
        entry survives the eviction pass its own insert may trigger,
        which a separate ``pin()`` call could not guarantee."""
        # Copy to an owned contiguous array OUTSIDE the lock — the value
        # is usually a slice view of a larger dispatch result, and
        # storing the view would retain the whole parent buffer (note
        # ascontiguousarray alone would NOT copy a contiguous view).
        owned = np.array(value, order="C", copy=True)
        with self._lock:
            e = self._entries.get(key)
            if e is not None:
                self._entries.move_to_end(key)
                if pin:
                    e.pins += 1
                return
            e = _Entry(owned, owned.nbytes, pins=1 if pin else 0)
            self._entries[key] = e
            self._bytes += owned.nbytes
            self.puts += 1
            self._evict_over_budget()

    def pin(self, key: Hashable) -> None:
        """Take a reference on an entry (it becomes non-evictable)."""
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                raise KeyError(f"cannot pin missing cache entry {key!r}")
            e.pins += 1

    def unpin(self, key: Hashable) -> None:
        """Drop a reference; the entry becomes evictable at zero pins."""
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                raise KeyError(f"cannot unpin missing cache entry {key!r}")
            if e.pins <= 0:
                raise ValueError(f"unbalanced unpin for cache entry {key!r}")
            e.pins -= 1
            if e.pins == 0:
                self._evict_over_budget()

    def _evict_over_budget(self) -> None:
        # caller holds self._lock
        if self._bytes <= self.max_bytes:
            return
        for key in list(self._entries):
            if self._bytes <= self.max_bytes:
                return
            e = self._entries[key]
            if e.pins > 0:
                continue
            del self._entries[key]
            self._bytes -= e.nbytes
            self.evictions += 1

    @property
    def pinned(self) -> int:
        """Number of entries currently holding at least one pin."""
        with self._lock:
            return sum(1 for e in self._entries.values() if e.pins > 0)

    def stats(self) -> Dict[str, float]:
        with self._lock:
            lookups = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "bytes": self._bytes,
                "max_bytes": self.max_bytes,
                "pinned": sum(
                    1 for e in self._entries.values() if e.pins > 0
                ),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / lookups if lookups else 0.0,
                "evictions": self.evictions,
                "puts": self.puts,
                "bytes_saved": self.bytes_saved,
            }
