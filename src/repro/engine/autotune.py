"""Roofline-guided plan autotuner — sweep the legal schedule space, keep
the winners.

The paper's accelerator hits 1080p@60fps on ONE hand-tuned schedule
(60-row tilted bands, double-buffered line memories).  The software
engine inherited those constants for every backend, resolution, precision
and batch — and the benchmark record shows it leaves throughput on the
table (bucket choice alone swings CPU frames/s by ~1.6x, and depth-2
pipelining *hurts* p50 latency on CPU).  This module makes the schedule a
measured decision instead of a constant, the measured-cost-model-driven
kernel search the embedded-GPU SR accelerators (Zhao et al., PAPERS.md)
use to beat hand-tuned schedules:

1. **Enumerate** the legal candidate space for a (backend, lr_shape,
   precision, request batch) configuration:

   * ``band_rows`` — every legal divisor near the preferred height
     (:func:`~repro.engine.plan.legal_band_rows`)... but ONLY for the
     ``halo`` vertical policy, where band decomposition is bit-exact
     invariant (each band recomputes its true receptive field).  Under
     ``zero``/``replicate`` the band boundary is an approximation, so
     retuning ``band_rows`` would change numerics — those plans keep the
     default, and the tuner says so.
   * ``pipeline_depth`` in ``{1..4}`` — in-flight dispatches per request.
   * bucket rounding policy — round the batch up to a power of two
     (bounded program count) vs compile the exact batch (zero padding
     waste).  Both are numerics-safe: padded frames are computed
     independently and trimmed.

2. **Score analytically first.**  :func:`predict_cost` is a pure-math
   roofline (per-frame FLOPs + HBM bytes from plan geometry — the halo
   recompute factor ``(R+2L)/R``, the cache-residency of the per-band
   working set, the padding waste of the bucket) — no compilation.
   Candidates whose predicted frame time exceeds ``prune_ratio`` (1.5x)
   of the roofline-best are pruned before ever being compiled.  The
   default schedule always survives, so the measured baseline — and the
   tuned >= default guarantee — is never lost to the model being wrong.

3. **Compile + measure the survivors.**  Each surviving (band_rows,
   bucket) compiles ONE executor over a shared
   :class:`~repro.engine.executor.PreparedStack` — never touching any
   session's ``PlanCache`` — and each depth is measured with the same
   bounded in-flight dispatch loop the server runs.  The measured pass is
   the arbiter: the analytic model proposes, wall-clock disposes (ties
   within ``tie_tol`` prefer the shallower pipeline and the default
   schedule — simpler wins when measurement can't separate them).

4. **Persist.**  Winners land in a JSON :class:`TuningDB`
   (``~/.cache/repro-sr/tuning.json``, ``REPRO_SR_TUNING_DB`` overrides)
   keyed like the ``PlanCache`` — the full plan configuration plus the
   batch bucket — and stamped with schema version, jax backend and device
   kind so entries from another schema/machine are ignored rather than
   misapplied.  Writes are atomic (temp file + ``os.replace``) and the DB
   is bounded (oldest entries evicted past ``capacity``).

Serving consults the DB through :class:`PlanTuner`:
``SRPlan.from_request(..., tuner=)`` asks it for a measured ``band_rows``;
``SRSession.open(model, autotune="off"|"cached"|"full")`` controls the
cold-start policy (``"cached"`` = lookup only, never measure in the
serving path; ``"full"`` = tune-and-persist on a miss);
``session.tuning_stats()`` reports hits/misses/fallbacks.

Pre-warm the DB offline::

    PYTHONPATH=src python -m repro.engine.autotune --sweep
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import tempfile
import time
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.engine.plan import SRPlan, derive_band_rows, legal_band_rows

__all__ = [
    "SCHEMA_VERSION",
    "DB_ENV_VAR",
    "default_db_path",
    "TuningKey",
    "TuningEntry",
    "TuningDB",
    "RooflinePeaks",
    "predict_cost",
    "Candidate",
    "enumerate_candidates",
    "measure_schedule",
    "tune",
    "PlanTuner",
]

# Bump when the entry layout or the meaning of a tuned knob changes —
# loaders ignore any DB written under a different schema (stale entries
# must never be misapplied to a new engine).
# v2: entries carry a topology stamp (device_count + mesh_shape) so a
# schedule tuned on one device layout is rejected on another.
SCHEMA_VERSION = 2

DB_ENV_VAR = "REPRO_SR_TUNING_DB"

# Tunable pipeline depths: 1 = blocking, 2 = the paper's ping-pong double
# buffering, 3-4 = deeper latency hiding (more live slabs).
DEPTHS = (1, 2, 3, 4)

# A candidate within this fraction of the measured best is a TIE — the
# simpler schedule (shallower pipeline, default band/bucket) wins it.
TIE_TOL = 0.03


def default_db_path() -> str:
    """``$REPRO_SR_TUNING_DB`` if set, else ``~/.cache/repro-sr/tuning.json``."""
    env = os.environ.get(DB_ENV_VAR)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro-sr", "tuning.json"
    )


def device_kind() -> str:
    """The kind string of device 0 — part of every entry's validity stamp
    (a schedule tuned on one device class must not steer another)."""
    import jax

    try:
        return str(jax.devices()[0].device_kind)
    except Exception:
        return "unknown"


# ----------------------------------------------------------------------
# Keys + entries + the persistent DB
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TuningKey:
    """What a tuning decision is FOR: every plan field that is not a
    tunable knob, plus the request batch the bucket policy was tuned at —
    exactly the ``PlanCache`` key shape minus the knobs themselves."""

    backend: str
    precision: str
    vertical_policy: str
    height: int
    width: int
    channels: int
    num_layers: int
    tile_cols: int
    scale: int
    clip: bool
    batch: int  # the request batch size the sweep was run for

    @classmethod
    def from_plan(cls, plan: SRPlan, batch: int) -> "TuningKey":
        return cls(
            backend=plan.backend,
            precision=plan.precision,
            vertical_policy=plan.vertical_policy,
            height=plan.height,
            width=plan.width,
            channels=plan.in_channels,
            num_layers=plan.num_layers,
            tile_cols=plan.tile_cols,
            scale=plan.scale,
            clip=plan.clip,
            batch=int(batch),
        )

    def encode(self) -> str:
        return (
            f"{self.backend}|{self.precision}|{self.vertical_policy}"
            f"|{self.height}x{self.width}x{self.channels}"
            f"|L{self.num_layers}|T{self.tile_cols}|s{self.scale}"
            f"|clip{int(self.clip)}|b{self.batch}"
        )

    def config_encode(self) -> str:
        """The key minus the batch — the fallback grouping (a nearby
        batch's tuned schedule beats the untuned default)."""
        return self.encode().rsplit("|b", 1)[0]


@dataclasses.dataclass
class TuningEntry:
    """One tuned schedule: the winning knobs plus the evidence and the
    validity stamp."""

    band_rows: int
    pipeline_depth: int
    bucket: int
    bucket_policy: str  # "pow2" | "exact"
    predicted_ms: float  # analytic roofline ms per real frame (winner)
    measured_ms: float  # measured ms per real frame (winner)
    default_ms: float  # measured ms per real frame (default schedule)
    speedup: float  # default_ms / measured_ms (>= 1 by construction)
    jax_backend: str
    device_kind: str
    created: float  # unix seconds
    # topology stamp: schedules are measured on ONE device layout and are
    # invalid on any other (a 1-device winner says nothing about halo
    # exchange cost on a 2x4 mesh).  mesh_shape is "RxS" (replicas x band
    # shards); unsharded sessions are "1x1".
    device_count: int = 1
    mesh_shape: str = "1x1"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> Optional["TuningEntry"]:
        try:
            return cls(**{f.name: d[f.name]
                          for f in dataclasses.fields(cls)})
        except (KeyError, TypeError):
            return None  # malformed entry — treat as absent


class TuningDB:
    """The persistent winner store: one JSON file, atomic writes, bounded
    size, schema/backend/device validity filtering on read.

    Layout::

        {"schema": 2, "entries": {"<key.encode()>": {<TuningEntry>}, ...}}

    A file written under a different ``SCHEMA_VERSION`` is ignored
    wholesale (``stale_schema`` records that it happened); an entry
    stamped with a different jax backend, device kind, device count or
    mesh shape is ignored per-lookup.  ``put`` keeps insertion order and evicts the oldest
    entries past ``capacity``; ``save`` writes a temp file in the target
    directory and ``os.replace``\\ s it — readers never see a torn file.
    """

    def __init__(self, path: Optional[str] = None, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be >= 1")
        self.path = path or default_db_path()
        self.capacity = capacity
        self.stale_schema = False
        self._entries: "OrderedDict[str, dict]" = OrderedDict()
        self._load()

    def _load(self) -> None:
        try:
            with open(self.path) as f:
                raw = json.load(f)
        except (OSError, json.JSONDecodeError):
            return  # missing or torn file — start empty
        if not isinstance(raw, dict) or raw.get("schema") != SCHEMA_VERSION:
            self.stale_schema = True
            return  # another engine's DB — never misapply its schedules
        entries = raw.get("entries")
        if isinstance(entries, dict):
            for k, v in entries.items():
                if isinstance(v, dict):
                    self._entries[k] = v

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self) -> List[str]:
        return list(self._entries)

    def get(
        self,
        key: TuningKey,
        *,
        device_count: Optional[int] = None,
        mesh_shape: str = "1x1",
    ) -> Optional[TuningEntry]:
        """The valid entry for ``key``, or None (wrong backend/device/
        topology or malformed entries are invalid, not errors).

        ``device_count`` defaults to the live ``jax.device_count()``;
        ``mesh_shape`` is the consumer's serving topology ("RxS") — an
        entry stamped with any other layout is rejected, never silently
        reused.
        """
        raw = self._entries.get(key.encode())
        if raw is None:
            return None
        entry = TuningEntry.from_dict(raw)
        if entry is None:
            return None
        import jax

        if (entry.jax_backend != jax.default_backend()
                or entry.device_kind != device_kind()):
            return None
        if device_count is None:
            device_count = jax.device_count()
        if (entry.device_count != int(device_count)
                or entry.mesh_shape != mesh_shape):
            return None
        return entry

    def get_nearest_batch(
        self,
        key: TuningKey,
        *,
        device_count: Optional[int] = None,
        mesh_shape: str = "1x1",
    ) -> Optional[Tuple[TuningEntry, int]]:
        """The valid entry matching ``key``'s configuration at the NEAREST
        tuned batch (the fallback when the exact batch was never swept);
        returns ``(entry, tuned_batch)`` or None."""
        prefix = key.config_encode() + "|b"
        best: Optional[Tuple[int, int, str]] = None
        for k in self._entries:
            if not k.startswith(prefix):
                continue
            try:
                b = int(k[len(prefix):])
            except ValueError:
                continue
            rank = (abs(b - key.batch), b)
            if best is None or rank < best[:2]:
                best = (*rank, k)
        if best is None:
            return None
        entry = self.get(
            dataclasses.replace(key, batch=best[1]),
            device_count=device_count, mesh_shape=mesh_shape,
        )
        return (entry, best[1]) if entry is not None else None

    def put(self, key: TuningKey, entry: TuningEntry) -> None:
        enc = key.encode()
        self._entries.pop(enc, None)
        self._entries[enc] = entry.to_dict()
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def save(self) -> None:
        """Atomic write: temp file next to the target + ``os.replace``."""
        payload = {"schema": SCHEMA_VERSION, "entries": dict(self._entries)}
        directory = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(directory, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


# ----------------------------------------------------------------------
# The analytic roofline (scoring WITHOUT compiling)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RooflinePeaks:
    """Peak compute/bandwidth + cache budget the predictor ranks against.

    Absolute values barely matter (candidates are compared to EACH OTHER
    and the measured pass arbitrates); the ratios set where the model
    places the compute/memory knee and when a band's working set spills.
    """

    flops_per_s: float
    hbm_bytes_per_s: float
    cache_bytes: float

    @classmethod
    def detect(cls) -> "RooflinePeaks":
        import jax

        if jax.default_backend() == "cpu":
            # a few-core SIMD CPU: tens of GFLOP/s, tens of GB/s, ~1 MiB
            # effective per-core L2 for the band working set
            return cls(5e10, 2e10, 1 << 20)
        # accelerator class: MXU-ish compute, HBM-ish bandwidth, ~16 MiB
        # on-chip buffer (the paper's SRAM analogue)
        return cls(1e13, 8e11, 16 << 20)


def _layer_channels(layers: Sequence) -> List[Tuple[int, int]]:
    chans = []
    for l in layers:
        ci = getattr(l, "ci", None)
        co = getattr(l, "co", None)
        if ci is None or co is None:  # duck-typed stacks: fall back to w
            ci, co = int(l.w.shape[2]), int(l.w.shape[3])
        chans.append((int(ci), int(co)))
    return chans


def predict_cost(
    plan: SRPlan,
    layers: Sequence,
    bucket: int,
    real_frames: int,
    peaks: Optional[RooflinePeaks] = None,
) -> dict:
    """Analytic roofline prediction for serving ``real_frames`` frames in
    one ``bucket``-sized dispatch of ``plan`` — pure geometry, NO
    compilation (this is what prunes the candidate space).

    Per band, every fused layer computes ``rows_c`` rows (``R`` for
    zero/replicate, ``R + 2L`` for halo — the recompute margin the paper
    trades DRAM traffic against).  FLOPs are the 3x3 MACs over those
    rows.  HBM bytes charge the frame in/out and the weights always, and
    the inter-layer feature maps only when the band working set exceeds
    the cache budget (cache-resident bands stream through on-chip, the
    whole point of banding).  Padded bucket slots compute like real
    frames, so the per-real-frame time scales by ``bucket/real_frames`` —
    the waste the exact-bucket policy removes.
    """
    if peaks is None:
        peaks = RooflinePeaks.detect()
    chans = _layer_channels(layers)
    H, W = plan.height, plan.width
    R, L, B = plan.band_rows, plan.num_layers, plan.num_bands
    rows_c = R + 2 * L if plan.vertical_policy == "halo" else R
    dsize = 2 if plan.precision == "bf16" else 4
    max_ch = max(max(ci, co) for ci, co in chans)

    flops = B * sum(2 * 9 * rows_c * W * ci * co for ci, co in chans)
    # epilogue: anchor add + pixel shuffle over the HR frame
    flops += 4 * H * W * plan.in_channels * plan.scale ** 2

    weight_bytes = sum(9 * ci * co * dsize for ci, co in chans)
    io_bytes = (H * W * plan.in_channels * 4
                + H * W * plan.in_channels * plan.scale ** 2 * 4)
    hbm = io_bytes + weight_bytes
    working_set = rows_c * W * max_ch * dsize
    if working_set > peaks.cache_bytes:
        # the band no longer fits on-chip: every fused layer's feature
        # map round-trips memory
        hbm += B * sum(2 * rows_c * W * co * dsize for _, co in chans)

    frame_s = max(flops / peaks.flops_per_s, hbm / peaks.hbm_bytes_per_s)
    ms_per_frame = frame_s * 1e3 * bucket / max(real_frames, 1)
    return {
        "flops_per_frame": int(flops),
        "hbm_bytes_per_frame": int(hbm),
        "working_set_bytes": int(working_set),
        "ms_per_frame": float(ms_per_frame),
    }


# ----------------------------------------------------------------------
# Candidate space + measurement
# ----------------------------------------------------------------------
@dataclasses.dataclass
class Candidate:
    """One point of the schedule space, carrying its scores through the
    sweep."""

    band_rows: int
    bucket: int
    pipeline_depth: int
    is_default: bool = False
    predicted_ms: float = math.nan
    measured_ms: float = math.nan
    pruned: bool = False


def band_rows_is_tunable(plan: SRPlan) -> bool:
    """Whether ``band_rows`` may differ from the default WITHOUT changing
    numerics: only the ``halo`` policy recomputes each band's true
    receptive field (bit-exact for any legal decomposition — asserted in
    tests/test_autotune.py); zero/replicate band boundaries are
    approximations, so their band height is part of the numerics, not the
    schedule."""
    return plan.vertical_policy == "halo"


def _pow2_bucket(n: int) -> int:
    return 1 << (max(int(n), 1) - 1).bit_length()


def enumerate_candidates(
    plan: SRPlan,
    batch: int,
    *,
    depths: Sequence[int] = DEPTHS,
    max_band_candidates: int = 4,
) -> List[Candidate]:
    """The legal candidate grid for one configuration.

    ``band_rows`` spans the nearest ``max_band_candidates`` legal
    decompositions (halo plans only — see :func:`band_rows_is_tunable`);
    the bucket axis is the two rounding policies (power-of-two vs exact);
    depth spans ``depths``.  Exactly one candidate ``is_default`` — the
    schedule today's hard-coded constants would run (default band, pow2
    bucket, depth 2) — and it is never pruned.
    """
    default_band = derive_band_rows(plan.height)
    if band_rows_is_tunable(plan):
        bands = legal_band_rows(plan.height)[:max_band_candidates]
        if default_band not in bands:
            bands.append(default_band)
    else:
        bands = [plan.band_rows]  # pinned: numerics, not schedule
    pow2 = _pow2_bucket(batch)
    buckets = sorted({pow2, int(batch)})
    default_depth = 2  # SRSession's constructor default
    depths = sorted(set(int(d) for d in depths))
    if default_depth not in depths:
        depths.append(default_depth)
    out = []
    for band in bands:
        for bucket in buckets:
            for depth in depths:
                out.append(Candidate(
                    band_rows=band,
                    bucket=bucket,
                    pipeline_depth=depth,
                    is_default=(band == (default_band
                                         if band_rows_is_tunable(plan)
                                         else plan.band_rows)
                                and bucket == pow2
                                and depth == default_depth),
                ))
    return out


def measure_schedule(fn, chunks: Sequence, depth: int, reps: int = 2) -> float:
    """Wall-clock seconds to serve ``chunks`` through executor ``fn`` with
    at most ``depth`` dispatches in flight — the same bounded-pipeline
    dispatch loop the server's drain runs, minus the locking.  Minimum
    over ``reps`` (noise floor, not noise mean)."""
    import jax

    jax.block_until_ready(fn(chunks[0]))  # warm (compile outside timing)
    best = math.inf
    for _ in range(max(int(reps), 1)):
        inflight = deque()
        t0 = time.perf_counter()
        for chunk in chunks:
            if len(inflight) >= depth:
                jax.block_until_ready(inflight.popleft())
            inflight.append(fn(chunk))
        while inflight:
            jax.block_until_ready(inflight.popleft())
        best = min(best, time.perf_counter() - t0)
    return best


def _preference(c: Candidate, plan: SRPlan, batch: int) -> tuple:
    """Tie-break rank among measured near-equals: shallower pipeline,
    then the default band, then the pow2 bucket — simplest schedule wins
    what measurement cannot separate."""
    return (
        c.pipeline_depth,
        0 if c.band_rows == derive_band_rows(plan.height) else 1,
        0 if c.bucket == _pow2_bucket(batch) else 1,
    )


def tune(
    layers: Sequence,
    plan: SRPlan,
    batch: int,
    dtype=np.float32,
    *,
    db: Optional[TuningDB] = None,
    depths: Sequence[int] = DEPTHS,
    max_band_candidates: int = 4,
    prune_ratio: float = 1.5,
    chunks: int = 3,
    reps: int = 2,
    peaks: Optional[RooflinePeaks] = None,
    measure_all: bool = False,
    tie_tol: float = TIE_TOL,
    seed: int = 0,
) -> TuningEntry:
    """Sweep the legal schedule space for ``(plan, batch)``; return — and
    persist, when ``db`` is given — the measured-best schedule.

    ``plan`` is the DEFAULT-derived plan for the configuration (what
    ``SRPlan.from_request`` builds with no tuner).  The sweep enumerates
    candidates, prunes on the analytic roofline at ``prune_ratio`` (the
    default candidate is exempt — the baseline must always be measured),
    compiles each surviving (band_rows, bucket) ONCE over a shared
    :class:`~repro.engine.executor.PreparedStack`, measures every
    surviving depth with :func:`measure_schedule` on a ``chunks``-dispatch
    synthetic clip, and picks the minimum (ties within ``tie_tol`` go to
    the simpler schedule).  ``measure_all=True`` skips pruning — the
    pruning-safety test uses it to check the roofline never discards the
    measured best.
    """
    import jax
    import jax.numpy as jnp

    from repro.engine.executor import build_stack_executor, prepare_stack

    batch = int(batch)
    if batch < 1:
        raise ValueError(f"batch={batch} must be >= 1")
    cands = enumerate_candidates(
        plan, batch, depths=depths, max_band_candidates=max_band_candidates
    )

    # --- analytic pass: score every candidate, prune the hopeless -------
    pred_cache: Dict[Tuple[int, int], float] = {}
    for c in cands:
        pk = (c.band_rows, c.bucket)
        if pk not in pred_cache:
            p = dataclasses.replace(plan, band_rows=c.band_rows)
            pred_cache[pk] = predict_cost(p, layers, c.bucket, batch,
                                          peaks)["ms_per_frame"]
        c.predicted_ms = pred_cache[pk]
    best_pred = min(c.predicted_ms for c in cands)
    if not measure_all:
        for c in cands:
            if not c.is_default and c.predicted_ms > prune_ratio * best_pred:
                c.pruned = True
    survivors = [c for c in cands if not c.pruned]

    # --- measured pass: one compile per (band, bucket), one stack total -
    stack = prepare_stack(plan, layers)  # numerics/packing: band-invariant
    jax.block_until_ready(stack)
    rng = np.random.default_rng(seed)
    dtype = np.dtype(dtype)
    frames_cache: Dict[int, list] = {}
    fn_cache: Dict[Tuple[int, int], object] = {}
    for c in survivors:
        fk = (c.band_rows, c.bucket)
        if fk not in fn_cache:
            p = dataclasses.replace(plan, band_rows=c.band_rows)
            # own executor, never donated (chunks are reused across reps),
            # never entered into any PlanCache
            fn_cache[fk] = build_stack_executor(p, stack,
                                                donate_frames=False)
        if c.bucket not in frames_cache:
            frames_cache[c.bucket] = [
                jnp.asarray(rng.random(
                    (c.bucket, *plan.lr_shape), np.float32).astype(dtype))
                for _ in range(max(int(chunks), 1))
            ]
        t = measure_schedule(fn_cache[fk], frames_cache[c.bucket],
                             c.pipeline_depth, reps=reps)
        c.measured_ms = t * 1e3 / (len(frames_cache[c.bucket]) * batch)

    best_ms = min(c.measured_ms for c in survivors)
    default = next(c for c in survivors if c.is_default)
    # ties within tie_tol of the best go to the simpler schedule — but a
    # tie-broken winner must never measure WORSE than the default (the
    # tuned >= default guarantee is exact, not within-noise)
    contenders = [c for c in survivors
                  if c.measured_ms <= best_ms * (1 + tie_tol)
                  and c.measured_ms <= default.measured_ms] or [default]
    winner = min(contenders, key=lambda c: _preference(c, plan, batch))

    entry = TuningEntry(
        band_rows=winner.band_rows,
        pipeline_depth=winner.pipeline_depth,
        bucket=winner.bucket,
        bucket_policy="exact" if winner.bucket == batch != _pow2_bucket(batch)
                      else "pow2",
        predicted_ms=round(winner.predicted_ms, 6),
        measured_ms=round(winner.measured_ms, 6),
        default_ms=round(default.measured_ms, 6),
        speedup=round(default.measured_ms / max(winner.measured_ms, 1e-12), 4),
        jax_backend=jax.default_backend(),
        device_kind=device_kind(),
        created=time.time(),
        # tune() measures the single-device executor: entries are only
        # valid for an unsharded consumer on this exact device count
        device_count=jax.device_count(),
        mesh_shape="1x1",
    )
    if db is not None:
        db.put(TuningKey.from_plan(plan, batch), entry)
        db.save()
    # expose the sweep for reporting/tests without widening the return
    entry.candidates = cands  # type: ignore[attr-defined]
    return entry


# ----------------------------------------------------------------------
# The serving-side consumer
# ----------------------------------------------------------------------
class PlanTuner:
    """The serving stack's view of the tuning DB.

    ``SRPlan.from_request(..., tuner=)`` and ``SRSession`` consult it;
    it answers from the DB only (never measures — measurement is
    :func:`tune`, invoked by ``autotune="full"`` sessions or the offline
    ``--sweep``).  Every answer is vetted for numerics safety and
    legality: a ``band_rows`` override must divide the height and must
    only move on a ``halo`` plan; anything else is ignored as stale.
    """

    def __init__(self, db: Optional[TuningDB] = None,
                 path: Optional[str] = None, *,
                 device_count: Optional[int] = None,
                 mesh_shape: str = "1x1"):
        self.db = db if db is not None else TuningDB(path)
        # the consumer's topology: lookups only accept entries stamped
        # with it (a sharded session never adopts 1-device winners)
        self.device_count = device_count
        self.mesh_shape = mesh_shape

    def lookup(
        self, key: TuningKey
    ) -> Tuple[Optional[TuningEntry], str]:
        """``(entry, kind)`` where kind is ``"hit"`` (exact batch),
        ``"fallback"`` (same config, nearest tuned batch) or ``"miss"``."""
        topo = {"device_count": self.device_count,
                "mesh_shape": self.mesh_shape}
        entry = self.db.get(key, **topo)
        if entry is not None and self._safe(key, entry):
            return entry, "hit"
        near = self.db.get_nearest_batch(key, **topo)
        if near is not None and self._safe(key, near[0]):
            return near[0], "fallback"
        return None, "miss"

    def _safe(self, key: TuningKey, entry: TuningEntry) -> bool:
        if key.height % entry.band_rows != 0:
            return False  # stale geometry
        if entry.band_rows != derive_band_rows(key.height):
            # moving band_rows off the default is only numerics-safe
            # under halo (see band_rows_is_tunable)
            return key.vertical_policy == "halo"
        return True

    def band_rows_for(
        self,
        *,
        lr_shape: Tuple[int, int, int],
        num_layers: int,
        tile_cols: int = 8,
        vertical_policy: str = "zero",
        backend: str = "tilted",
        precision: str = "fp32",
        scale: int = 3,
        clip: bool = True,
        bucket: Optional[int] = None,
    ) -> Optional[int]:
        """The measured-best ``band_rows`` for a request configuration, or
        None (fall back to the default derivation).  This is the hook
        ``SRPlan.from_request(..., tuner=)`` calls."""
        H, W, C = (int(x) for x in lr_shape)
        key = TuningKey(
            backend=backend, precision=precision,
            vertical_policy=vertical_policy, height=H, width=W, channels=C,
            num_layers=int(num_layers), tile_cols=int(tile_cols),
            scale=int(scale), clip=bool(clip),
            batch=int(bucket) if bucket else 1,
        )
        entry, _ = self.lookup(key)
        return entry.band_rows if entry is not None else None


# ----------------------------------------------------------------------
# Offline pre-warm CLI
# ----------------------------------------------------------------------
def sweep(
    *,
    db: TuningDB,
    model: str = "abpn_x3",
    backends: Sequence[str] = ("tilted",),
    precisions: Sequence[str] = ("fp32",),
    policies: Sequence[str] = ("zero",),
    heights: Sequence[int] = (120,),
    widths: Sequence[int] = (64,),
    batches: Sequence[int] = (1, 3, 4, 8),
    seed: int = 0,
    **tune_kwargs,
) -> List[Tuple[TuningKey, TuningEntry]]:
    """Tune every configuration in the cross product and persist the
    winners — the offline DB pre-warm behind ``--sweep``."""
    import jax

    from repro.models.registry import get_sr_model

    spec = get_sr_model(model)
    layers = spec.init(jax.random.PRNGKey(seed))
    out = []
    for backend in backends:
        for precision in precisions:
            for policy in policies:
                for h in heights:
                    for w in widths:
                        plan = SRPlan.from_request(
                            (h, w, spec.config.in_channels),
                            num_layers=len(layers),
                            vertical_policy=policy,
                            backend=backend,
                            precision=precision,
                            scale=spec.config.scale,
                        )
                        for b in batches:
                            entry = tune(layers, plan, b, db=db,
                                         **tune_kwargs)
                            key = TuningKey.from_plan(plan, b)
                            out.append((key, entry))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Pre-warm the plan tuning DB offline "
                    "(python -m repro.engine.autotune --sweep)"
    )
    ap.add_argument("--sweep", action="store_true",
                    help="run the tuning sweep and persist winners")
    ap.add_argument("--db", default=None,
                    help=f"tuning DB path (default: ${DB_ENV_VAR} or "
                         "~/.cache/repro-sr/tuning.json)")
    ap.add_argument("--model", default="abpn_x3")
    ap.add_argument("--backends", nargs="+", default=["tilted"],
                    choices=["reference", "tilted", "kernel"])
    ap.add_argument("--precisions", nargs="+", default=["fp32"],
                    choices=["fp32", "bf16", "int8"])
    ap.add_argument("--policies", nargs="+", default=["zero"],
                    choices=["zero", "halo", "replicate"])
    ap.add_argument("--heights", type=int, nargs="+", default=[120])
    ap.add_argument("--widths", type=int, nargs="+", default=[64])
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 3, 4, 8])
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--chunks", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes + shallow grid (CI smoke)")
    args = ap.parse_args(argv)

    if not args.sweep:
        ap.error("nothing to do: pass --sweep to run the tuning sweep")
    db = TuningDB(args.db)
    kw = dict(backends=args.backends, precisions=args.precisions,
              policies=args.policies, heights=args.heights,
              widths=args.widths, batches=args.batches,
              reps=args.reps, chunks=args.chunks)
    if args.quick:
        kw.update(heights=[24], widths=[16], batches=[1, 3],
                  reps=1, chunks=2)
    results = sweep(db=db, model=args.model, **kw)
    for key, e in results:
        print(f"{key.encode()}: band_rows={e.band_rows} "
              f"depth={e.pipeline_depth} bucket={e.bucket} "
              f"({e.bucket_policy}) measured {e.measured_ms:.2f} ms/frame "
              f"(default {e.default_ms:.2f}, x{e.speedup:.3f})")
    print(f"wrote {len(results)} entries -> {db.path}")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
