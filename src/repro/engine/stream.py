"""VideoStream — DEPRECATED fixed-batch driver, now a shim over SRSession.

.. deprecated::
    Use :class:`repro.engine.SRSession`: ``session.upscale(clip)`` replaces
    ``stream.run``, ``session.stats()`` replaces ``stream.stats()``, and
    compilation is handled by the session's plan cache (per serving dtype,
    on a dummy batch — never counted in serving latency).  ``VideoStream``
    remains for callers that hand-build an :class:`~repro.engine.SRPlan`
    and want one pinned (plan, batch size) program; it wraps
    ``SRSession.from_plan(plan, layers, bucket=batch_size)``.

Semantics preserved from the original driver: ``process`` is strict about
the batch size, ``run`` serves arbitrary-length clips by zero-padding the
tail batch (no recompilation) and trimming the output, and only real
frames count in the throughput stats.  One deliberate change rides on the
session: compilation always happens on a warmup dummy in the dtype being
served, so no ``process`` call's recorded latency ever includes a compile
— previously a first batch in a non-fp32 dtype silently recompiled inside
the timed region.

The shim pins ``pipeline_depth=1`` and ``donate_frames=False``: every
batch blocks before the next dispatches and caller arrays are never
consumed — exactly the legacy driver's behavior.  Migrate to
``SRSession`` (``pipeline_depth=2`` default) for the overlapped dispatch
path, or to :class:`~repro.engine.server.SRServer` for the request/future
front door (``submit``/``stream`` + cross-request micro-batching); see the
README "Serving architecture" section.  The pinned session serves through
the same server drain as everyone else — ``run`` is ``upscale`` is
``submit().result()`` — so this shim keeps benefiting from engine fixes
without owning any serving logic.
"""

from __future__ import annotations

import warnings
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import ConvLayer
from repro.engine.plan import SRPlan
from repro.engine.session import SRSession, StreamStats

__all__ = ["VideoStream", "StreamStats"]


class VideoStream:
    def __init__(
        self,
        plan: SRPlan,
        layers: Sequence[ConvLayer],
        batch_size: int = 1,
        dtype=jnp.float32,
    ):
        warnings.warn(
            "VideoStream is deprecated; use repro.engine.SRSession "
            "(session.upscale(clip) replaces stream.run — see the README "
            "migration note)",
            DeprecationWarning,
            stacklevel=2,
        )
        if batch_size < 1:
            raise ValueError(f"batch_size={batch_size} must be >= 1")
        self.plan = plan
        self.batch_size = batch_size
        # the dtype this stream is expected to serve: warmup compiles for
        # it, so the first real batch in it never pays a compile
        self.dtype = np.dtype(dtype)
        # legacy semantics: blocking per-batch serving, no frame donation
        self._session = SRSession.from_plan(
            plan, layers, bucket=batch_size,
            pipeline_depth=1, donate_frames=False,
        )

    @property
    def session(self) -> SRSession:
        """The underlying session (one pinned plan + bucket)."""
        return self._session

    # latency/frame counters live on the session (ONE stats pipeline);
    # these aliases keep pre-session callers that reach into the stream's
    # internals working
    @property
    def _lat_ms(self) -> List[float]:
        return self._session._lat_ms

    @property
    def _frames(self) -> int:
        return self._session._frames

    @_frames.setter
    def _frames(self, value: int) -> None:
        self._session._frames = value

    # ------------------------------------------------------------------
    def warmup(self) -> float:
        """Compile the executor for the serving dtype; returns compile
        seconds (the cached figure if already compiled)."""
        entry, _ = self._session.executor_for(
            self.plan, self.batch_size, self.dtype
        )
        return entry.compile_s

    def process(
        self, frames: jax.Array, real_frames: Optional[int] = None
    ) -> jax.Array:
        """Run one batch (N, H, W, C) -> HR, recording its latency.

        The batch size must match the stream's (one compiled program).
        A batch in a dtype the session has not yet compiled for triggers
        the compile on a dummy first — outside the recorded latency.
        ``real_frames`` counts only that many leading frames in the
        throughput stats (the rest are padding, e.g. a clip's tail
        batch); the full batch is returned.
        """
        if frames.shape[0] != self.batch_size:
            raise ValueError(
                f"stream compiled for batch {self.batch_size}, got {frames.shape[0]}"
            )
        n_real = self.batch_size if real_frames is None else real_frames
        if not 0 <= n_real <= self.batch_size:
            raise ValueError(
                f"real_frames={n_real} outside [0, {self.batch_size}]"
            )
        return self._session.serve_batch(self.plan, frames, real_frames=n_real)

    def run(self, frames: jax.Array) -> jax.Array:
        """Stream a clip (T, H, W, C) through in batch-size chunks.

        T may be any length: a tail shorter than the batch size is
        zero-padded up to the compiled batch (same program — no
        recompilation), the padded outputs are trimmed, and only the T real
        frames count in the latency stats.  Returns the (T, sH, sW, C) HR
        sequence.
        """
        if frames.ndim != 4:
            raise ValueError(
                f"expected a clip (T, H, W, C), got shape {frames.shape}"
            )
        # the pinned session's upscale does exactly this stream's chunk /
        # tail-pad / trim / real-frame accounting (ONE implementation),
        # including the empty-clip compiled-output-dtype path
        return self._session.upscale(frames)

    # ------------------------------------------------------------------
    def cache_stats(self) -> dict:
        """The pinned session's compile-cache counters."""
        return self._session.cache_stats()

    def stats(self) -> StreamStats:
        return self._session.stats(batch_size=self.batch_size)

    def reset_stats(self) -> None:
        self._session.reset_stats()
