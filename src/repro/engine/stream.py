"""VideoStream — the serving driver over a compiled SR plan.

Owns exactly one jitted executor (compiled during :meth:`warmup`, or lazily
on the first batch) and feeds it fixed-size frame batches, recording
wall-clock latency per call.  This is the paper's use case — real-time
video SR — expressed as a service loop: compile once, then stream.
Clips of arbitrary length are served by zero-padding the tail batch up to
the compiled batch size (no recompilation) and trimming the output; only
real frames count in the throughput stats.

Used by ``examples/serve_sr.py`` and ``benchmarks/engine_throughput.py``.
"""

from __future__ import annotations

import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import ConvLayer
from repro.engine.executor import build_executor
from repro.engine.plan import SRPlan

__all__ = ["VideoStream", "StreamStats"]


class StreamStats(dict):
    """Latency/throughput summary: frames, batches, fps, p50/p95/mean ms."""


class VideoStream:
    def __init__(
        self,
        plan: SRPlan,
        layers: Sequence[ConvLayer],
        batch_size: int = 1,
    ):
        if batch_size < 1:
            raise ValueError(f"batch_size={batch_size} must be >= 1")
        self.plan = plan
        self.batch_size = batch_size
        self._fn = build_executor(plan, layers)
        self._lat_ms: List[float] = []
        self._frames = 0
        self._compiled = False

    # ------------------------------------------------------------------
    def warmup(self) -> float:
        """Compile the executor on a zero batch; returns compile seconds."""
        dummy = jnp.zeros((self.batch_size, *self.plan.lr_shape), jnp.float32)
        t0 = time.perf_counter()
        self._fn(dummy).block_until_ready()
        self._compiled = True
        return time.perf_counter() - t0

    def process(
        self, frames: jax.Array, real_frames: Optional[int] = None
    ) -> jax.Array:
        """Run one batch (N, H, W, C) -> HR, recording its latency.

        The batch size must match the stream's (one compiled program); the
        first call compiles if :meth:`warmup` was skipped, and that call's
        latency is excluded from the stats.  ``real_frames`` counts only
        that many leading frames in the throughput stats (the rest are
        padding, e.g. a clip's tail batch); the full batch is returned.
        """
        if frames.shape[0] != self.batch_size:
            raise ValueError(
                f"stream compiled for batch {self.batch_size}, got {frames.shape[0]}"
            )
        n_real = self.batch_size if real_frames is None else real_frames
        if not 0 <= n_real <= self.batch_size:
            raise ValueError(
                f"real_frames={n_real} outside [0, {self.batch_size}]"
            )
        first = not self._compiled
        t0 = time.perf_counter()
        hr = self._fn(frames)
        hr.block_until_ready()
        dt_ms = (time.perf_counter() - t0) * 1e3
        self._compiled = True
        if not first:
            self._lat_ms.append(dt_ms)
            self._frames += n_real
        return hr

    def run(self, frames: jax.Array) -> jax.Array:
        """Stream a clip (T, H, W, C) through in batch-size chunks.

        T may be any length: a tail shorter than the batch size is
        zero-padded up to the compiled batch (same program — no
        recompilation), the padded outputs are trimmed, and only the T real
        frames count in the latency stats.  Returns the (T, sH, sW, C) HR
        sequence.
        """
        T = frames.shape[0]
        if T == 0:
            return jnp.zeros((0, *self.plan.hr_shape), frames.dtype)
        outs = []
        for i in range(0, T, self.batch_size):
            chunk = frames[i : i + self.batch_size]
            n = chunk.shape[0]
            if n < self.batch_size:  # ragged tail: pad to the compiled batch
                pad = jnp.zeros(
                    (self.batch_size - n, *chunk.shape[1:]), chunk.dtype
                )
                chunk = jnp.concatenate([chunk, pad], axis=0)
            outs.append(self.process(chunk, real_frames=n)[:n])
        return jnp.concatenate(outs, axis=0)

    # ------------------------------------------------------------------
    def stats(self) -> StreamStats:
        lat = np.asarray(self._lat_ms, dtype=np.float64)
        if lat.size == 0:
            return StreamStats(frames=0, batches=0, batch_size=self.batch_size,
                               fps=0.0, p50_ms=0.0, p95_ms=0.0, mean_ms=0.0)
        total_s = lat.sum() / 1e3
        return StreamStats(
            frames=self._frames,
            batches=int(lat.size),
            batch_size=self.batch_size,
            # a clock too coarse to resolve the batch reports 0.0, not inf
            fps=self._frames / total_s if total_s > 0 else 0.0,
            p50_ms=float(np.percentile(lat, 50)),
            p95_ms=float(np.percentile(lat, 95)),
            mean_ms=float(lat.mean()),
        )

    def reset_stats(self) -> None:
        self._lat_ms.clear()
        self._frames = 0
