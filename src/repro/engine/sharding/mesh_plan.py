"""Mesh-aware plan wrapping: ``SRPlan`` + device-mesh topology.

The tilted decomposition already splits a frame into independent R-row
bands whose only coupling is the L-row halo ``core.fusion.halo_slabs``
defines.  A :class:`MeshSpec` names the two ways that structure maps onto
devices:

  * ``band_shards`` (mesh axis ``bands``): each device owns
    ``num_bands // band_shards`` whole bands of every frame.  Halo policy
    ``halo`` needs an L-row exchange at shard edges (``shard_exec``);
    ``zero``/``replicate`` shard with no communication at all.
  * ``replicas`` (mesh axis ``replica``): whole micro-batches are routed to
    independent copies of the executor (``router``) — pure data
    parallelism, never visible inside a compiled program.

:class:`ShardedPlan` validates that a plan's band geometry actually splits
across the requested shards and derives the per-shard local plan.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.engine.plan import SRPlan, shardable_band_rows

__all__ = [
    "MeshSpec",
    "ShardedPlan",
    "check_shardable",
    "ensure_shardable",
]


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Serving-mesh topology: ``replicas x band_shards`` devices."""

    replicas: int = 1
    band_shards: int = 1

    def __post_init__(self) -> None:
        if self.replicas <= 0 or self.band_shards <= 0:
            raise ValueError(
                f"mesh axes must be positive, got replicas={self.replicas} "
                f"band_shards={self.band_shards}"
            )

    @classmethod
    def coerce(cls, value: Union["MeshSpec", Tuple[int, int], None]) -> "MeshSpec":
        """Accept a MeshSpec, a ``(replicas, band_shards)`` tuple, or None."""
        if value is None:
            return cls()
        if isinstance(value, MeshSpec):
            return value
        try:
            replicas, band_shards = value
        except (TypeError, ValueError):
            raise ValueError(
                "mesh must be a MeshSpec or a (replicas, band_shards) "
                f"pair, got {value!r}"
            ) from None
        return cls(replicas=int(replicas), band_shards=int(band_shards))

    @property
    def devices_needed(self) -> int:
        return self.replicas * self.band_shards

    @property
    def descriptor(self) -> str:
        """Topology stamp, e.g. ``"2x4"`` — autotune DB validity key."""
        return f"{self.replicas}x{self.band_shards}"

    @property
    def is_trivial(self) -> bool:
        return self.devices_needed == 1


def check_shardable(plan: SRPlan, band_shards: int) -> Optional[str]:
    """Why ``plan`` cannot band-shard ``band_shards`` ways (None = it can)."""
    if band_shards <= 1:
        return None
    if plan.backend == "reference":
        return (
            "reference backend computes over the full frame and cannot "
            "band-shard; use the tilted or kernel backend"
        )
    bands = plan.num_bands
    if bands % band_shards != 0:
        return (
            f"{bands} bands (height {plan.height} / band_rows "
            f"{plan.band_rows}) do not split into {band_shards} equal "
            "shards"
        )
    return None


def ensure_shardable(
    plan: SRPlan, spec: MeshSpec, preferred: Optional[int] = None
) -> SRPlan:
    """Return ``plan`` (or a re-banded copy) legal for ``spec``.

    If the plan's current ``band_rows`` does not split across the shards,
    try the best legal alternative from :func:`shardable_band_rows`;
    raise ``ValueError`` when no decomposition exists.
    """
    err = check_shardable(plan, spec.band_shards)
    if err is None:
        return plan
    if plan.backend == "reference":
        raise ValueError(err)
    kwargs = {} if preferred is None else {"preferred": preferred}
    rows = shardable_band_rows(plan.height, spec.band_shards, **kwargs)
    if rows is None:
        raise ValueError(
            f"no legal band_rows splits height {plan.height} across "
            f"{spec.band_shards} band shards ({err})"
        )
    return dataclasses.replace(plan, band_rows=rows)


@dataclasses.dataclass(frozen=True)
class ShardedPlan:
    """An ``SRPlan`` bound to a mesh topology (validated at construction)."""

    plan: SRPlan
    spec: MeshSpec = MeshSpec()

    def __post_init__(self) -> None:
        err = check_shardable(self.plan, self.spec.band_shards)
        if err is not None:
            raise ValueError(f"plan not shardable over {self.spec}: {err}")

    @property
    def local_plan(self) -> SRPlan:
        """The per-shard plan: same bands/tiles, ``1/S`` of the rows.

        Each shard runs the ordinary band loop over its own contiguous row
        block, so the local plan is just the global one with
        ``height / band_shards`` rows — band_rows, tile_cols and numerics
        are untouched and the schedule is identical per band.
        """
        s = self.spec.band_shards
        if s == 1:
            return self.plan
        return dataclasses.replace(self.plan, height=self.plan.height // s)

    @property
    def bands_per_shard(self) -> int:
        return self.plan.num_bands // self.spec.band_shards

    def verify(self, **kwargs):
        """Static verification including shard-boundary halo checks."""
        from repro.analysis.plan_check import verify_plan

        kwargs.setdefault("band_shards", self.spec.band_shards)
        return verify_plan(self.plan, **kwargs)
