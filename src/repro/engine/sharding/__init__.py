"""Sharded multi-device serving: band-sharded execution + replica routing.

The tilted decomposition's band structure maps directly onto a device
mesh: a ``bands`` axis splits each frame's row bands spatially (with the
L-row halo exchange ``core.fusion.halo_slabs`` geometry implies at shard
edges), and a ``replica`` axis runs independent copies of the executor
for data parallelism.  Three layers:

  * ``mesh_plan``  — :class:`MeshSpec` / :class:`ShardedPlan`: topology +
    plan validation (band counts must split across shards).
  * ``shard_exec`` — :func:`build_sharded_executor`: the band loop under
    ``jax.shard_map`` with ``ppermute`` halo exchange; bit-exact vs the
    single-device executor by construction.
  * ``router``     — :class:`ReplicaRouter`: per-replica compile caches +
    prepared stacks, round-robin / least-loaded dispatch routing.

Everything runs on CPU under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

from repro.engine.sharding.mesh_plan import (
    MeshSpec,
    ShardedPlan,
    check_shardable,
    ensure_shardable,
)
from repro.engine.sharding.router import ROUTE_POLICIES, ReplicaRouter
from repro.engine.sharding.shard_exec import (
    build_sharded_executor,
    frame_spec,
    halo_exchange_bytes_per_frame,
)

__all__ = [
    "MeshSpec",
    "ShardedPlan",
    "check_shardable",
    "ensure_shardable",
    "ReplicaRouter",
    "ROUTE_POLICIES",
    "build_sharded_executor",
    "frame_spec",
    "halo_exchange_bytes_per_frame",
]
