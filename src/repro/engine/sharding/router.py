"""Replica routing: coalesced dispatches over the ``replica`` mesh axis.

Band sharding splits one frame's rows across devices; replication runs
whole micro-batches on independent device groups.  ``ReplicaRouter`` owns
the per-replica state the session would otherwise hold once globally —
a compiled-executor :class:`~repro.engine.session.PlanCache` and the
refcounted device-resident ``PreparedStack`` copies — and picks a replica
per dispatch:

  * ``round_robin`` — strict rotation, ignores load.
  * ``least_loaded`` — fewest in-flight dispatches, ties broken by fewest
    total dispatches then lowest index (the default: keeps replicas full
    under uneven batch sizes).

The replica axis never appears inside a compiled program: each replica's
executor is band-sharded over its own 1-D ``bands`` submesh
(:func:`repro.launch.mesh.band_submesh`), so routing is pure host-side
bookkeeping and the outputs are bit-exact regardless of which replica
served a request.

Thread-safety: the server calls :meth:`executor_for` / :meth:`note_launch`
under its drain lock and :meth:`note_complete` from completion handling —
the router's counters piggyback on that external serialization, same as
the session's own caches.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.engine.executor import prepare_stack
from repro.engine.sharding.mesh_plan import MeshSpec, ShardedPlan
from repro.engine.sharding.shard_exec import (
    build_sharded_executor,
    halo_exchange_bytes_per_frame,
)
from repro.launch.mesh import band_submesh, make_sr_mesh

__all__ = ["ReplicaRouter", "ROUTE_POLICIES"]

ROUTE_POLICIES = ("round_robin", "least_loaded")


@dataclasses.dataclass
class _Replica:
    """One replica's device group + its private serving state."""

    index: int
    mesh: jax.sharding.Mesh
    cache: "PlanCache"  # noqa: F821 - imported lazily (session cycle)
    stacks: dict
    inflight: int = 0
    dispatches: int = 0
    frames: int = 0


class ReplicaRouter:
    """Route ``executor_for`` calls across replicas of a serving mesh."""

    def __init__(
        self,
        session,
        spec: MeshSpec,
        *,
        policy: str = "least_loaded",
        cache_capacity: Optional[int] = None,
    ):
        from repro.engine.session import PlanCache  # lazy: session imports us

        if policy not in ROUTE_POLICIES:
            raise ValueError(f"route policy {policy!r} not in {ROUTE_POLICIES}")
        self.session = session
        self.spec = spec
        self.policy = policy
        self.mesh = make_sr_mesh(spec.replicas, spec.band_shards)
        capacity = cache_capacity or getattr(
            session._cache, "capacity", 8
        )
        self._replicas: List[_Replica] = []
        for r in range(spec.replicas):
            rep = _Replica(
                index=r,
                mesh=band_submesh(self.mesh, r),
                cache=PlanCache(
                    capacity,
                    on_evict=lambda key, entry, _r=r: self._on_evict(_r, entry),
                ),
                stacks={},
            )
            self._replicas.append(rep)
        self._rr = 0
        self._compile_counts: Dict[tuple, int] = {}

    # ------------------------------------------------------------------
    # Replica selection
    # ------------------------------------------------------------------
    def pick(self) -> int:
        """The replica index the next dispatch should run on."""
        if self.policy == "round_robin":
            idx = self._rr % len(self._replicas)
            self._rr += 1
            return idx
        return min(
            self._replicas,
            key=lambda rep: (rep.inflight, rep.dispatches, rep.index),
        ).index

    # ------------------------------------------------------------------
    # Per-replica compile cache (mirrors SRSession.executor_for)
    # ------------------------------------------------------------------
    def _acquire_stack(self, rep: _Replica, plan) -> Tuple[object, tuple]:
        skey = plan.stack_key
        rec = rep.stacks.get(skey)
        if rec is None:
            from repro.engine.session import _StackRecord  # lazy

            t0 = time.perf_counter()
            stack = prepare_stack(plan, self.session.layers)
            # replicate the prepared weights onto this replica's devices —
            # every band shard needs the full stack
            stack = jax.device_put(stack, NamedSharding(rep.mesh, P()))
            jax.block_until_ready(stack)
            rec = _StackRecord(
                stack=stack, refs=0, prepare_s=time.perf_counter() - t0
            )
            rep.stacks[skey] = rec
        rec.refs += 1
        return rec.stack, skey

    def _release_stack(self, rep: _Replica, skey: tuple) -> None:
        rec = rep.stacks.get(skey)
        if rec is None:
            return
        rec.refs -= 1
        if rec.refs <= 0:
            del rep.stacks[skey]

    def _on_evict(self, replica: int, entry) -> None:
        self._release_stack(self._replicas[replica], entry.stack_key)

    def executor_for(self, plan, bucket: int, dtype):
        """A compiled band-sharded executor on the next routed replica.

        Returns ``(entry, compiled_now)`` exactly like
        ``SRSession.executor_for``; ``entry.replica`` records the routing
        decision so the server can credit launch/complete back via
        :meth:`note_launch` / :meth:`note_complete`.
        """
        from repro.engine.session import SRSession, _CacheEntry  # lazy

        rep = self._replicas[self.pick()]
        dtype = SRSession.serving_dtype(dtype)
        key = SRSession.cache_key(plan, bucket, dtype)
        entry = rep.cache.get(key)
        if entry is not None:
            return entry, False
        splan = ShardedPlan(plan=plan, spec=self.spec)
        stack, skey = self._acquire_stack(rep, plan)
        try:
            fn = build_sharded_executor(splan, stack, rep.mesh)
            dummy = jnp.zeros((bucket, *plan.lr_shape), dtype)
            t0 = time.perf_counter()
            jax.block_until_ready(fn(dummy))
            compile_s = time.perf_counter() - t0
        except BaseException:
            self._release_stack(rep, skey)
            raise
        entry = _CacheEntry(
            fn=fn,
            plan=plan,
            bucket=int(bucket),
            dtype=dtype.name,
            compile_s=compile_s,
            stack_key=skey,
            donates=False,
            replica=rep.index,
        )
        ckey = (rep.index, *key)
        self._compile_counts[ckey] = self._compile_counts.get(ckey, 0) + 1
        rep.cache.put(key, entry)
        return entry, True

    # ------------------------------------------------------------------
    # Load accounting (driven by SRServer launch/complete)
    # ------------------------------------------------------------------
    def note_launch(self, replica: int, frames: int = 0) -> None:
        rep = self._replicas[replica]
        rep.inflight += 1
        rep.dispatches += 1
        rep.frames += frames

    def note_complete(self, replica: int) -> None:
        rep = self._replicas[replica]
        rep.inflight = max(0, rep.inflight - 1)

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    def clear(self) -> None:
        """Evict every replica's compiled executors + prepared weights."""
        for rep in self._replicas:
            rep.cache.clear()

    def replica_fill(self) -> float:
        """Dispatch balance across replicas: 1.0 = perfectly even, ->0 as
        one replica takes all the traffic (mean / max dispatches)."""
        counts = [rep.dispatches for rep in self._replicas]
        peak = max(counts, default=0)
        if peak == 0:
            return 0.0
        return (sum(counts) / len(counts)) / peak

    def stats(self) -> dict:
        plan_probe = None
        for rep in self._replicas:
            for entry in rep.cache.entries():
                plan_probe = entry.plan
                break
            if plan_probe is not None:
                break
        return {
            "mesh": self.spec.descriptor,
            "devices": self.spec.devices_needed,
            "policy": self.policy,
            "replica_fill": self.replica_fill(),
            "halo_bytes_per_frame": (
                0 if plan_probe is None else halo_exchange_bytes_per_frame(
                    plan_probe, self.spec.band_shards
                )
            ),
            "replicas": [
                {
                    "index": rep.index,
                    "dispatches": rep.dispatches,
                    "frames": rep.frames,
                    "inflight": rep.inflight,
                    "cache": rep.cache.stats(),
                }
                for rep in self._replicas
            ],
        }
