"""Band-sharded executor: the tilted band loop under ``shard_map``.

Each device on the ``bands`` mesh axis owns a contiguous block of
``num_bands / band_shards`` whole bands (``H / S`` rows) of every frame.
For the ``zero``/``replicate`` vertical policies bands are independent and
the shards run with no communication at all.  For ``halo`` the only
cross-shard coupling is the L-row margin at the two shard edges; an
``lax.ppermute`` pulls the neighbour rows so that each shard can
reconstruct exactly the ``(R + 2L)``-row slabs ``core.fusion.halo_slabs``
would have cut from the zero-padded full frame:

  * a shard's extended rows ``concat([up, local, down])`` equal
    ``padded[s*H_local : s*H_local + H_local + 2L]`` of the L-zero-padded
    frame — ppermute leaves ZEROS on the edge shards that have no
    neighbour, which is exactly the global zero padding;
  * local band ``b``'s slab is ``ext[b*R : b*R + R + 2L]`` and its global
    valid-row bounds are the same clip formulas ``halo_slabs`` uses with
    the global band index ``axis_index('bands') * bands_per_shard + b``.

Bit-exactness vs the single-device executor therefore holds by
construction: identical slab values, identical per-band bounds, identical
band kernel (tilted vmap or Pallas), identical epilogue
(``executor.sr_epilogue``, row-block local).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.fusion import tilted_fused_band
from repro.distributed.partitioning import logical_to_spec, sr_rules
from repro.engine.executor import (
    PreparedStack,
    compute_dtype_for,
    sr_epilogue,
    sr_features,
)
from repro.engine.sharding.mesh_plan import ShardedPlan
from repro.launch.mesh import SR_BAND_AXIS

__all__ = [
    "build_sharded_executor",
    "frame_spec",
    "halo_exchange_bytes_per_frame",
]

# Logical axes of a frame batch (N, H, W, C) — resolved against SR_RULES.
FRAME_AXES = ("sr_batch", "sr_rows", "sr_cols", "sr_chan")


def frame_spec(mesh: jax.sharding.Mesh) -> P:
    """PartitionSpec for a frame batch on ``mesh`` (rows over ``bands``)."""
    return logical_to_spec(FRAME_AXES, mesh, sr_rules())


def halo_exchange_bytes_per_frame(plan, band_shards: int) -> int:
    """Bytes moved across shard edges per frame (both directions).

    ``zero``/``replicate`` shard without communication; ``halo`` exchanges
    the L-row margin at each of the ``S - 1`` internal edges, in both
    directions, in the compute dtype.
    """
    if band_shards <= 1 or plan.vertical_policy != "halo":
        return 0
    itemsize = jnp.dtype(compute_dtype_for(plan.precision)).itemsize
    edge_rows = plan.num_layers * plan.width * plan.in_channels
    return 2 * (band_shards - 1) * edge_rows * itemsize


def _halo_features_local(plan, local, stack: PreparedStack, x: jax.Array):
    """Per-shard halo-policy features: exchange, re-slab, run, crop.

    ``x`` is this shard's ``(N, H/S, W, C0)`` row block in compute dtype;
    returns ``(N, H/S, W, ChL)`` features identical to the matching rows of
    the single-device halo path.
    """
    N, Hl, W, C0 = x.shape
    R, L = plan.band_rows, plan.num_layers
    S = plan.height // Hl
    Bl = local.num_bands
    slab = R + 2 * L

    # Neighbour margins: shard 0 / shard S-1 receive zeros from ppermute on
    # their open edge — identical to the global L-row zero padding.
    fwd = [(i, i + 1) for i in range(S - 1)]
    bwd = [(i + 1, i) for i in range(S - 1)]
    up = jax.lax.ppermute(x[:, -L:], SR_BAND_AXIS, fwd)
    down = jax.lax.ppermute(x[:, :L], SR_BAND_AXIS, bwd)
    ext = jnp.concatenate([up, x, down], axis=1)  # padded[s*Hl : s*Hl+Hl+2L]

    slabs = jnp.stack([ext[:, b * R : b * R + slab] for b in range(Bl)], axis=1)
    slabs = slabs.reshape(N * Bl, slab, W, C0)

    # Global valid-row bounds, same clip formulas as halo_slabs but with the
    # global band index; flat order n*Bl + b matches the reshape above.
    g = jax.lax.axis_index(SR_BAND_AXIS) * Bl + jnp.arange(Bl, dtype=jnp.int32)
    lo = jnp.clip(L - g * R, 0, slab).astype(jnp.int32)
    hi = jnp.clip(L + plan.height - g * R, 0, slab).astype(jnp.int32)
    lo = jnp.tile(lo, N)
    hi = jnp.tile(hi, N)

    if plan.backend == "kernel":
        from repro.kernels import ops  # local import: kernels are optional

        out = ops._tilted_fused_bands(
            slabs,
            stack.packed,
            tile_cols=plan.tile_cols,
            add_anchor=False,
            anchor_repeats=plan.scale * plan.scale,
            interpret=ops.default_interpret(),
            row_policy="zero",
            row_bounds=jnp.stack([lo, hi], axis=1),
            compute_dtype=x.dtype,
        )
    else:
        out = jax.vmap(
            lambda band, l, h: tilted_fused_band(
                band, stack.layers, plan.tile_cols, row_pad="zero",
                row_valid=(l, h),
            )
        )(slabs, lo, hi)
    out = out[:, L : L + R]  # crop the recompute margin
    return out.reshape(N, Hl, W, out.shape[-1])


def _sharded_body(splan: ShardedPlan, stack: PreparedStack, frames: jax.Array):
    """The per-shard program shard_map maps over the ``bands`` axis."""
    plan = splan.plan
    local = splan.local_plan
    in_dtype = frames.dtype
    x = frames.astype(compute_dtype_for(plan.precision))
    if splan.spec.band_shards == 1 or plan.vertical_policy != "halo":
        # Bands are shard-local (or there is only one shard): the ordinary
        # backend over the local row block IS the global computation.
        feats = sr_features(local, stack.layers, x, packed=stack.packed)
    else:
        feats = _halo_features_local(plan, local, stack, x)
    return sr_epilogue(local, x, feats, in_dtype)


def build_sharded_executor(
    splan: ShardedPlan, stack: PreparedStack, mesh: jax.sharding.Mesh
):
    """Compile ``splan`` + ``stack`` into a mesh-sharded frame-batch callable.

    ``mesh`` must carry a ``bands`` axis of size ``spec.band_shards`` (a
    replica's :func:`repro.launch.mesh.band_submesh`, or any 1-D bands
    mesh).  The callable shards input rows over ``bands`` via
    ``device_put``, runs the jitted shard_map program, and returns the HR
    batch with the same row sharding (gather with ``np.asarray`` when a
    host copy is needed).
    """
    spec = splan.spec
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axis_sizes.get(SR_BAND_AXIS) != spec.band_shards:
        raise ValueError(
            f"mesh bands axis {axis_sizes.get(SR_BAND_AXIS)} != plan's "
            f"band_shards {spec.band_shards}"
        )
    fspec = frame_spec(mesh)
    body = functools.partial(_sharded_body, splan)
    mapped = shard_map(
        body, mesh=mesh, in_specs=(P(), fspec), out_specs=fspec,
        check_rep=False,
    )
    jitted = jax.jit(mapped)
    in_sharding = NamedSharding(mesh, fspec)

    def fn(frames):
        frames = jax.device_put(frames, in_sharding)
        return jitted(stack, frames)

    fn.jitted = jitted
    fn.donates_frames = False
    fn.mesh = mesh
    fn.sharded_plan = splan
    return fn
