"""Batched plan executor — one jitted call per frame batch, any backend.

This replaces both the string dispatch in the legacy ``apply_abpn`` and the
per-band Python loop in ``core.fusion.run_banded``:

* ``reference`` — the full-image layerwise oracle, ``vmap``-ed over frames.
* ``tilted``    — the pure-JAX tilted sweep.  Frames are reshaped to a flat
  ``(N * num_bands, R, W, C)`` band axis and the band dimension is folded
  into a single ``vmap`` (bands of a frame are independent under every
  vertical policy, including ``halo`` where each band carries its own
  recompute margin), so the whole batch traces to one XLA computation with
  no Python-level banding.
* ``kernel``    — the Pallas datapath; the same flat band axis becomes the
  kernel's sequential grid dimension (``kernels.ops.tilted_fused_frames``),
  so a batch of frames is ONE ``pallas_call``.

All backends share the anchor + pixel-shuffle epilogue and the plan's
numerics policy (fp32 / bf16 / int8 dequant-on-read weights).

Weight preparation (the numerics policy + the kernel's pad/pack) has two
homes:

* :func:`prepare_stack` builds a device-resident :class:`PreparedStack`
  ONCE per weight stack; :func:`build_stack_executor` compiles a serving
  executor that takes the stack as a plain pytree argument — so the int8
  quantise round-trip and the kernel's weight scatter never execute inside
  the per-batch jitted call.  This is what ``SRSession`` serves through.
* :func:`run`/:func:`build_executor` keep the self-contained signature
  (raw float layers in, preparation traced into the call) — the
  differentiable path QAT training uses, and the oracle the prepared path
  is tested bit-exact against.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import (
    ConvLayer,
    conv_stack_reference,
    halo_slabs,
    tilted_fused_band,
)
from repro.core.quant import dequantize_layers, quantize_layers
from repro.engine.plan import SRPlan

# models.abpn only imports engine lazily (inside apply_abpn), so the single
# tested pixel-shuffle/anchor convention can be shared without a cycle.
from repro.models.abpn import depth_to_space, make_anchor

__all__ = [
    "prepare_layers",
    "prepare_stack",
    "PreparedStack",
    "build_executor",
    "build_stack_executor",
    "build_band_executor",
    "executor_artifacts",
    "output_spec",
    "plan_cost",
    "run",
    "sr_epilogue",
    "sr_features",
]

def prepare_layers(layers: Sequence[ConvLayer], precision: str) -> List[ConvLayer]:
    """Apply the plan's numerics policy to a float conv stack.

    ``fp32`` passes through; ``bf16`` casts weights/biases (activations are
    cast at the executor boundary); ``int8`` round-trips the weights through
    symmetric per-channel quantisation — the accelerator's storage format —
    and computes in fp32 (dequant-on-read).
    """
    if precision == "fp32":
        return list(layers)
    if precision == "bf16":
        return [
            ConvLayer(
                w=l.w.astype(jnp.bfloat16), b=l.b.astype(jnp.bfloat16), relu=l.relu
            )
            for l in layers
        ]
    if precision == "int8":
        return dequantize_layers(quantize_layers(layers))
    raise ValueError(f"unknown precision {precision!r}")


@dataclasses.dataclass
class PreparedStack:
    """A weight stack with the plan's numerics + backend packing applied.

    Built ONCE per (weight stack, precision, backend) by
    :func:`prepare_stack`; the arrays are ordinary device-resident
    ``jax.Array``s, and the whole object is a pytree, so a jitted executor
    takes it as a plain argument — weight preparation never re-executes
    inside the per-batch call.  ``packed`` is only populated for the
    ``kernel`` backend (the Pallas launch's padded storage form).
    """

    layers: tuple  # Tuple[ConvLayer, ...], numerics applied
    packed: Optional[object]  # kernels.ops.PackedLayers | None
    precision: str
    backend: str

    def nbytes(self) -> int:
        """Device bytes this stack holds (prepared + packed forms)."""
        return sum(
            leaf.nbytes
            for leaf in jax.tree_util.tree_leaves((self.layers, self.packed))
            if hasattr(leaf, "nbytes")
        )


jax.tree_util.register_dataclass(
    PreparedStack,
    data_fields=["layers", "packed"],
    meta_fields=["precision", "backend"],
)


def compute_dtype_for(precision: str):
    """The on-chip compute dtype a precision policy implies (int8 stores
    quantised weights but computes dequantised in fp32)."""
    return jnp.bfloat16 if precision == "bf16" else jnp.float32


def prepare_stack(plan: SRPlan, layers: Sequence[ConvLayer]) -> PreparedStack:
    """Apply ``plan``'s numerics policy — and, for the ``kernel`` backend,
    the launch's weight pad/pack — producing a device-resident
    :class:`PreparedStack`.

    Called eagerly this executes the int8 quantise round-trip / bf16 cast /
    kernel pack exactly once; the returned arrays are then reused by every
    batch served through :func:`build_stack_executor`.  The function is
    pure jnp, so it also traces cleanly when invoked inside a jit (the
    legacy self-contained path) or under ``grad`` (QAT).
    """
    prepared = tuple(prepare_layers(layers, plan.precision))
    packed = None
    if plan.backend == "kernel":
        from repro.kernels import ops  # local import: kernels are optional

        packed = ops.pack_stack(prepared, dtype=compute_dtype_for(plan.precision))
    return PreparedStack(
        layers=prepared,
        packed=packed,
        precision=plan.precision,
        backend=plan.backend,
    )


# ----------------------------------------------------------------------
# Backend feature executors: (N, H, W, C0) -> (N, H, W, ChL)
# ----------------------------------------------------------------------
def _features_reference(plan: SRPlan, layers, frames: jax.Array) -> jax.Array:
    return jax.vmap(lambda im: conv_stack_reference(im, layers))(frames)


def _features_tilted(plan: SRPlan, layers, frames: jax.Array) -> jax.Array:
    N, H, W, C0 = frames.shape
    R, L = plan.band_rows, plan.num_layers
    B = plan.num_bands
    policy = plan.vertical_policy

    if policy in ("zero", "replicate"):
        bands = frames.reshape(N * B, R, W, C0)
        out = jax.vmap(
            lambda band: tilted_fused_band(
                band, layers, plan.tile_cols, row_pad=policy
            )
        )(bands)
        return out.reshape(N, H, W, out.shape[-1])

    # halo: every band is the (R + 2L)-row slab of the zero-padded frame
    # starting at its own row offset; rows outside the real image are
    # phantom and masked per-layer via row_valid (exactly run_banded's
    # semantics, but uniform across bands so the band axis vmaps).  The
    # slab/bounds geometry is shared with the Pallas marshalling
    # (core.fusion.halo_slabs — the one definition of halo).
    slabs, bounds = halo_slabs(frames, R, L)
    out = jax.vmap(
        lambda band, l, h: tilted_fused_band(
            band, layers, plan.tile_cols, row_pad="zero", row_valid=(l, h)
        )
    )(slabs, bounds[:, 0], bounds[:, 1])
    out = out[:, L : L + R]  # crop the recompute margin
    return out.reshape(N, H, W, out.shape[-1])


def _features_kernel(
    plan: SRPlan, layers, frames: jax.Array, packed=None
) -> jax.Array:
    from repro.kernels import ops  # local import: kernels are optional

    # The kernel covers the full plan space: zero/replicate run the bands
    # directly with the matching in-kernel row padding, halo marshals
    # (R+2L)-row slabs with per-band valid-row bounds, and bf16 plans
    # compute in bf16 on-chip (frames arrive already cast, so the compute
    # dtype rides in on the input dtype).  ``packed`` (from a
    # PreparedStack) skips the per-call weight pad/scatter.
    return ops.tilted_fused_frames(
        frames,
        layers,
        band_rows=plan.band_rows,
        tile_cols=plan.tile_cols,
        vertical_policy=plan.vertical_policy,
        compute_dtype=frames.dtype,
        packed=packed,
    )


_BACKENDS = {
    "reference": _features_reference,
    "tilted": _features_tilted,
}


def sr_features(plan: SRPlan, layers, frames: jax.Array, packed=None) -> jax.Array:
    """Run the plan's conv-stack backend over a frame batch (no epilogue).

    ``layers`` are assumed already numerics-prepared; ``packed`` (kernel
    backend only) supplies pre-packed launch weights.
    """
    if plan.backend == "kernel":
        return _features_kernel(plan, layers, frames, packed)
    return _BACKENDS[plan.backend](plan, layers, frames)


def _execute_stack(
    plan: SRPlan, stack: PreparedStack, frames: jax.Array
) -> jax.Array:
    """The per-batch computation over an already-prepared weight stack.

    This is what serving compiles: weight preparation happened when the
    :class:`PreparedStack` was built, so the jitted program contains ONLY
    the conv datapath + epilogue — no quantise round-trip, no kernel weight
    scatter (enforced by the ``repro.analysis.program_audit`` hot-path
    pass, which CI runs over every cached executor).
    """
    if frames.ndim != 4:
        raise ValueError(
            f"expected a frame batch (N, H, W, C), got shape {frames.shape}"
        )
    in_dtype = frames.dtype
    x = frames.astype(compute_dtype_for(plan.precision))
    feats = sr_features(plan, stack.layers, x, packed=stack.packed)
    return sr_epilogue(plan, x, feats, in_dtype)


def sr_epilogue(
    plan: SRPlan, x: jax.Array, feats: jax.Array, in_dtype
) -> jax.Array:
    """ABPN's residual epilogue: anchor add, pixel shuffle, clip, cast.

    Shared between the single-device executor and the band-sharded one —
    both paths assemble the HR batch from identical features, so any drift
    here would break the sharded bit-exactness guarantee.  Row-block local:
    ``depth_to_space`` maps LR row ``y`` to HR rows ``[y*s, y*s+s)``, so the
    epilogue can run independently on each row shard.
    """
    # make_anchor broadcasts over the frames axis, depth_to_space is vmapped.
    out = feats + make_anchor(x, plan.scale)
    hr = jax.vmap(lambda o: depth_to_space(o, plan.scale))(out)
    if plan.clip:
        hr = jnp.clip(hr, 0.0, 1.0)
    return hr.astype(in_dtype)


def _execute(plan: SRPlan, layers, frames: jax.Array) -> jax.Array:
    """The pure engine computation: ``(plan, layers, frames) -> HR batch``.

    Layers are a pytree ARGUMENT (not a closure), so this traces cleanly
    under ``grad``/``vmap`` (e.g. the QAT training example differentiates
    through it) and one jit cache entry serves every weight stack of the
    same structure.  Weight preparation is traced INTO the call here — the
    serving path avoids that via :func:`prepare_stack` +
    :func:`build_stack_executor`, which produce bit-identical results (the
    same preparation ops run on the same values, merely outside the jit).
    """
    return _execute_stack(plan, prepare_stack(plan, layers), frames)


# SRPlan is frozen/hashable -> static; layers/frames are pytree args, so the
# jit cache is keyed on (plan, layer structure & shapes, batch shape).
_execute_jit = jax.jit(_execute, static_argnums=0)


def build_executor(
    plan: SRPlan,
    layers: Sequence[ConvLayer],
    jit: bool = True,
    shared_jit: bool = True,
) -> Callable[[jax.Array], jax.Array]:
    """Bind plan + weights into ``frames (N,H,W,C) -> HR (N,sH,sW,C)``.

    The callable is compiled ONCE per batch size; every backend — including
    ``kernel`` — runs the whole batch inside that single jitted call.

    ``shared_jit=True`` dispatches through the module-level jit (one global
    cache shared with ``run`` — compiled programs are pinned for the
    process).  ``shared_jit=False`` gives the executor its OWN jit wrapper
    that dies with the returned callable, so nothing at this layer pins the
    program once the caller (the session's ``PlanCache``) drops it; any
    residual reuse on a rebuild comes from jax's internal bounded
    compilation caches, not from this module.
    """
    plan.check_invariants()
    bound = tuple(layers)
    if not jit:
        fn = _execute
    elif shared_jit:
        fn = _execute_jit
    else:
        fn = jax.jit(_execute, static_argnums=0)
    return functools.partial(fn, plan, bound)


def build_stack_executor(
    plan: SRPlan,
    stack: PreparedStack,
    *,
    donate_frames: bool = False,
) -> Callable[[jax.Array], jax.Array]:
    """The serving executor: bind plan + a :class:`PreparedStack` into
    ``frames (N,H,W,C) -> HR (N,sH,sW,C)``.

    The stack rides in as a pytree argument on every call (device-resident
    arrays — dispatch cost only), so the compiled program contains no
    weight preparation.  ``donate_frames=True`` compiles with the frame
    batch donated (``donate_argnums``): XLA may reuse the bucket-sized
    slab's memory for same-sized intermediates (e.g. the compute-dtype
    cast of the frames) and releases it at its last use instead of
    pinning it for the whole call — note the HR output itself is
    ``scale^2`` x larger than the input, so for ``scale > 1`` the output
    buffer never aliases the donated slab.  Callers must treat the input
    array as CONSUMED.  The executor gets its own jit
    wrapper (same lifetime rationale as ``build_executor(shared_jit=False)``:
    evicting the cache entry drops the program), exposed as ``.jitted`` on
    the returned callable so tests can assert its trace count.
    """
    plan.check_invariants()
    donate = (2,) if donate_frames else ()
    jitted = jax.jit(_execute_stack, static_argnums=0, donate_argnums=donate)
    fn = functools.partial(jitted, plan, stack)
    fn.jitted = jitted
    fn.donates_frames = donate_frames
    return fn


def _band_features(
    plan: SRPlan, stack: PreparedStack, slabs: jax.Array, bounds: jax.Array
) -> jax.Array:
    """Conv-stack features over an explicit band-slab stack.

    ``slabs`` is (k, rows, W, C0) with rows = R + 2L under ``halo`` (the
    ``core.fusion.halo_slabs`` geometry, ``bounds`` carrying each slab's
    valid-row interval) and rows = R otherwise.  Per band this runs the
    SAME per-slab computation as the full-frame path — the tilted
    backend maps the identical ``tilted_fused_band`` closure, the kernel
    backend runs the identical sequential band grid — so each output
    band is bit-identical to the corresponding band of a full launch.
    The reference backend has no band decomposition and cannot serve
    partial dispatches.
    """
    R, L = plan.band_rows, plan.num_layers
    policy = plan.vertical_policy
    if plan.backend == "kernel":
        from repro.kernels import ops  # local import: kernels are optional

        return ops.tilted_fused_band_stack(
            slabs,
            tile_cols=plan.tile_cols,
            vertical_policy=policy,
            row_bounds=bounds if policy == "halo" else None,
            compute_dtype=slabs.dtype,
            packed=stack.packed,
        )
    if plan.backend != "tilted":
        raise ValueError(
            f"backend {plan.backend!r} cannot serve partial-band dispatches "
            "(no band decomposition); use 'tilted' or 'kernel'"
        )
    layers = stack.layers
    if policy in ("zero", "replicate"):
        return jax.vmap(
            lambda band: tilted_fused_band(
                band, layers, plan.tile_cols, row_pad=policy
            )
        )(slabs)
    out = jax.vmap(
        lambda band, l, h: tilted_fused_band(
            band, layers, plan.tile_cols, row_pad="zero", row_valid=(l, h)
        )
    )(slabs, bounds[:, 0], bounds[:, 1])
    return out[:, L : L + R]  # crop the recompute margin


def _execute_band_stack(
    plan: SRPlan, stack: PreparedStack, slabs: jax.Array, bounds: jax.Array
) -> jax.Array:
    """Partial-band serving program: band slabs -> HR bands.

    The temporal delta path's executor body: (k, rows, W, C) input slabs
    (plus (k, 2) int32 valid-row bounds, meaningful under ``halo`` and
    dead-code-eliminated otherwise) -> (k, R*s, W*s, C) upscaled bands.
    The epilogue is row-block local (see :func:`sr_epilogue`), so running
    it on each band's own LR rows reproduces the full-frame epilogue's
    bytes for those rows exactly.
    """
    if slabs.ndim != 4:
        raise ValueError(
            f"expected a band-slab batch (k, rows, W, C), got {slabs.shape}"
        )
    in_dtype = slabs.dtype
    x = slabs.astype(compute_dtype_for(plan.precision))
    feats = _band_features(plan, stack, x, bounds)
    if plan.vertical_policy == "halo":
        L = plan.num_layers
        lr = x[:, L : L + plan.band_rows]  # each slab's own (anchor) rows
    else:
        lr = x
    return sr_epilogue(plan, lr, feats, in_dtype)


def build_band_executor(
    plan: SRPlan, stack: PreparedStack
) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """Compile the partial-band executor ``(slabs, bounds) -> HR bands``.

    Same shape as :func:`build_stack_executor` (own jit wrapper exposed
    as ``.jitted``, stack as a pytree argument) but never donates: band
    slabs are a small fraction of a frame and the splice path reads the
    dispatch result immediately.
    """
    plan.check_invariants()
    if plan.backend == "reference":
        raise ValueError(
            "reference backend cannot serve partial-band dispatches"
        )
    jitted = jax.jit(_execute_band_stack, static_argnums=0)
    fn = functools.partial(jitted, plan, stack)
    fn.jitted = jitted
    fn.donates_frames = False
    return fn


def plan_cost(
    plan: SRPlan,
    layers: Sequence[ConvLayer],
    batch: int,
    dtype=jnp.float32,
    *,
    stack: Optional[PreparedStack] = None,
) -> dict:
    """Roofline terms of the compiled serving executor for one bucket.

    Lowers + compiles ``_execute_stack`` for ``(batch, *lr_shape)`` input
    and walks the HLO (``roofline.hlo_parse``) for per-call FLOPs and HBM
    bytes — the software analogue of the paper's DRAM-traffic accounting,
    reported per frame alongside the weight bytes the PreparedStack keeps
    resident (the traffic weight hoisting removes from every batch).

    ``stack`` reuses an already-prepared weight stack across calls — the
    autotuner scores many candidate plans against ONE stack this way,
    without touching any session's ``PlanCache`` (the jit wrapper here is
    local to the call; nothing is cached at this layer).
    """
    from repro.roofline.hlo_parse import parse_hlo

    if stack is None:
        stack = prepare_stack(plan, layers)
    jitted = jax.jit(_execute_stack, static_argnums=0)
    lowered = jitted.lower(
        plan, stack, jax.ShapeDtypeStruct((batch, *plan.lr_shape), dtype)
    )
    cost = parse_hlo(lowered.compile().as_text())
    return {
        "batch": int(batch),
        "flops": int(cost.flops),
        "hbm_bytes": int(cost.hbm_bytes),
        "flops_per_frame": int(cost.flops // batch),
        "hbm_bytes_per_frame": int(cost.hbm_bytes // batch),
        "weight_bytes_resident": int(stack.nbytes()),
    }


def executor_artifacts(
    plan: SRPlan,
    stack: Optional[PreparedStack],
    batch: int,
    dtype=jnp.float32,
    *,
    layers: Optional[Sequence[ConvLayer]] = None,
    compiled: bool = True,
) -> dict:
    """The compiler-facing artifacts of the serving executor for one
    bucket: the traced jaxpr text and (``compiled=True``) the optimized
    HLO text — what ``repro.analysis.program_audit`` scans for forbidden
    patterns (quant ops, host callbacks/transfers, silent upcasts).

    Pass ``stack`` to audit exactly what serving runs
    (``_execute_stack`` over a :class:`PreparedStack`); pass ``layers``
    with ``stack=None`` to build the stack here.  Tracing is abstract
    (``ShapeDtypeStruct`` input) so no frame buffer is allocated; the
    compile (HLO path only) hits jax's internal caches when the session
    already compiled this key.
    """
    if stack is None:
        if layers is None:
            raise ValueError("need a PreparedStack or raw layers")
        stack = prepare_stack(plan, layers)
    spec = jax.ShapeDtypeStruct((int(batch), *plan.lr_shape), dtype)
    jaxpr = jax.make_jaxpr(
        functools.partial(_execute_stack, plan, stack)
    )(spec)
    out = {
        "plan": plan,
        "batch": int(batch),
        "dtype": np.dtype(jax.dtypes.canonicalize_dtype(np.dtype(dtype))).name,
        "jaxpr": str(jaxpr),
        "hlo": None,
    }
    if compiled:
        jitted = jax.jit(_execute_stack, static_argnums=0)
        out["hlo"] = jitted.lower(plan, stack, spec).compile().as_text()
    return out


def output_spec(
    plan: SRPlan, layers: Sequence[ConvLayer], batch: int, dtype
) -> jax.ShapeDtypeStruct:
    """The shape/dtype the executor emits for a ``(batch, *lr_shape)``
    input of ``dtype`` — derived by abstract evaluation, no compile.

    This is the one authority on the executor's output contract; degenerate
    serving paths (empty clips/requests) use it so their zero-length output
    matches a real batch exactly.
    """
    fn = build_executor(plan, layers, jit=False)
    return jax.eval_shape(
        fn, jax.ShapeDtypeStruct((batch, *plan.lr_shape), dtype)
    )


def run(plan: SRPlan, layers: Sequence[ConvLayer], frames: jax.Array) -> jax.Array:
    """One-shot convenience: run a frame batch through the plan's executor.

    Hits jax's jit cache on repeated calls with the same plan and layer
    structure — the serving steady state pays one dispatch, no retrace.
    """
    return _execute_jit(plan, tuple(layers), frames)
