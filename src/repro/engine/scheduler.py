"""Micro-batching scheduler — cross-request coalescing into bucket dispatches.

The paper's accelerator sustains its frame rate by keeping the datapath fed
with a continuous stream of bands; the serving analogue is keeping every
compiled bucket full of REAL frames.  ``MicroBatchScheduler`` is the pure
bookkeeping half of that (no jax, no compute — execution lives in
``engine.server``):

* **Admission.**  Requests enter per-key FIFO queues; the server enforces
  its ``max_inflight_frames`` bound at admission and raises
  :class:`QueueFullError` (or blocks and drains, or SHEDS queued work —
  see below) when the queue is full.
* **Deadlines.**  A request may carry an absolute monotonic ``deadline``;
  :meth:`MicroBatchScheduler.expire_due` removes queued, never-dispatched
  requests whose deadline has passed (the server fails their futures with
  ``DeadlineExceededError`` before they ever compile or dispatch).  A
  partially-served request is past recall — its in-flight frames complete
  regardless, exactly like :meth:`MicroBatchScheduler.drop`.
* **Load shedding.**  Under ``admission="shed"`` the server asks
  :meth:`MicroBatchScheduler.shed_victims` to evict the *lowest-priority,
  latest-deadline* queued work (never the newcomer, and never anything
  already dispatched) to make room; victims' futures fail with
  ``RequestShedError``.  If nothing strictly less urgent than the
  newcomer can free enough frames, the newcomer itself is rejected.
* **Coalescing.**  The key is ``(model, plan, dtype-name)`` — exactly the
  session's compile-cache key plus the model name — because frames that
  share a key are served by the SAME compiled executor, so frames from
  different requests can ride in ONE bucket-sized dispatch.  Two concurrent
  half-bucket requests become a single full bucket (fill ratio 1.0) instead
  of two padded dispatches.
* **Bucket choice.**  A dispatch's bucket is derived from the key's TOTAL
  pending frames (``session._bucket_for`` — power-of-two, ``max_bucket``
  capped), so queued traffic fills the largest legal bucket.  A request
  left partially served pins its bucket (the *carry* bucket) for its tail
  dispatches — the same program serves every chunk of a long clip, exactly
  like the pre-server pipelined path (no tail-driven recompiles).
* **Priority.**  Across keys, the key holding the highest-priority request
  dispatches first (FIFO on arrival within a priority level).  Within a
  key, requests coalesce in arrival order — they share dispatches anyway.

Counters (:meth:`MicroBatchScheduler.stats`) record dispatches, how many
coalesced multiple requests, real frames vs bucket slots (the mean fill
ratio — the padding the coalescer eliminated), queue depth peaks and
admission rejections; ``recent_dispatches`` keeps a bounded log for tests
and debugging.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional

__all__ = [
    "MicroBatchScheduler",
    "QueueFullError",
    "DeadlineExceededError",
    "RequestShedError",
    "SchedRequest",
    "Ticket",
    "Dispatch",
]

# bounded debug/test log of formed dispatches (oldest dropped first)
RECENT_DISPATCH_LOG = 256


class QueueFullError(RuntimeError):
    """Admission rejected: the server's ``max_inflight_frames`` bound is
    full and the admission policy is ``"reject"`` (or ``"shed"`` with the
    newcomer itself the least-urgent work queued)."""


class DeadlineExceededError(TimeoutError):
    """The request's deadline passed while it was still queued — it was
    cancelled before compiling or dispatching.  A ``TimeoutError``
    subclass, but distinct from the plain ``TimeoutError`` that
    ``SRFuture.result(timeout=)`` raises when only the *wait* expires
    (the request itself stays queued and may still complete)."""


class RequestShedError(QueueFullError):
    """This queued request was EVICTED under ``admission="shed"``: the
    bound was full and a newer, more urgent request claimed its frames.
    Subclasses :class:`QueueFullError` so callers handling queue-full
    rejection handle shedding too."""


@dataclasses.dataclass
class SchedRequest:
    """One admitted request: a flat ``(N, H, W, C)`` frame batch plus the
    assembly state the server needs to slice its results back out.

    ``served`` counts frames handed to dispatches, ``completed`` frames
    whose HR output has been sliced into ``pieces``; the request's future
    resolves when ``completed == n``.
    """

    seq: int
    key: tuple  # (model, plan, dtype_name) — the coalescing key
    session: object  # owning SRSession
    plan: object  # SRPlan
    flat: object  # (N, H, W, C) numpy or jax array, serving dtype applied
    n: int
    priority: int
    future: object  # SRFuture
    ndim: int  # caller's original rank (3 | 4 | 5)
    lead: Optional[tuple]  # (B, T) when ndim == 5
    # absolute time.monotonic() seconds; None = no deadline.  Checked by
    # expire_due while the request is still fully queued.
    deadline: Optional[float] = None
    # admission timestamp (time.monotonic()) — end-to-end latency anchor
    # for the server's degrade policy
    admitted_at: float = 0.0
    # partial-band request (temporal delta serving): the band indices the
    # ``n`` slab rows of ``flat`` correspond to.  None = whole frames.
    # Band requests use a "bands"-suffixed key, so the coalescer never
    # mixes band slabs and frames in one dispatch.
    bands: Optional[tuple] = None
    served: int = 0
    completed: int = 0
    pieces: List = dataclasses.field(default_factory=list)
    failed: bool = False


@dataclasses.dataclass
class Ticket:
    """One request's slice of a dispatch: frames ``[start, start + n)`` of
    the request occupy slab rows ``[slot, slot + n)``."""

    request: SchedRequest
    start: int
    n: int
    slot: int


@dataclasses.dataclass
class Dispatch:
    """A formed bucket-sized dispatch: which requests' frames fill which
    slab rows.  Rows past ``real`` are zero padding."""

    key: tuple
    session: object
    plan: object
    bucket: int
    tickets: List[Ticket]
    # replica index the server routed this dispatch to (mesh serving;
    # recorded at launch, None on single-device sessions)
    replica: Optional[int] = None
    # partial-band dispatch (temporal delta serving): the band index each
    # real slab row serves, in slot order.  None = a whole-frame dispatch.
    band_subset: Optional[tuple] = None

    @property
    def real(self) -> int:
        return sum(t.n for t in self.tickets)

    @property
    def fill(self) -> float:
        return self.real / self.bucket

    @property
    def requests(self) -> List[SchedRequest]:
        seen, out = set(), []
        for t in self.tickets:
            if id(t.request) not in seen:
                seen.add(id(t.request))
                out.append(t.request)
        return out


class MicroBatchScheduler:
    """Queues + coalescing policy; the server drives it under its lock."""

    def __init__(self):
        self._queues: Dict[tuple, Deque[SchedRequest]] = {}
        self._carry: Dict[tuple, int] = {}  # pinned bucket of a partial head
        self._seq = itertools.count()
        self.pending_frames = 0
        self.peak_pending_frames = 0
        self.submitted_requests = 0
        self.submitted_frames = 0
        self.dispatches = 0
        self.coalesced_dispatches = 0
        self.frames_dispatched = 0
        self.slots_dispatched = 0
        self.rejected = 0
        self.expired = 0  # queued requests cancelled past their deadline
        self.shed = 0  # queued requests evicted under admission="shed"
        # replica index -> dispatches routed there (mesh serving only;
        # stays empty on single-device sessions)
        self.replica_dispatches: Dict[int, int] = {}
        self.recent_dispatches: Deque[dict] = deque(maxlen=RECENT_DISPATCH_LOG)

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    def next_seq(self) -> int:
        return next(self._seq)

    def add(self, req: SchedRequest) -> None:
        self._queues.setdefault(req.key, deque()).append(req)
        self.submitted_requests += 1
        self.submitted_frames += req.n
        self.pending_frames += req.n
        self.peak_pending_frames = max(self.peak_pending_frames, self.pending_frames)

    def note_rejected(self) -> None:
        self.rejected += 1

    def note_empty_request(self) -> None:
        """An admitted zero-frame request (resolved without a dispatch)."""
        self.submitted_requests += 1

    def note_routed(self, replica: int) -> None:
        """A dispatch landed on a replica (server records it at launch)."""
        self.replica_dispatches[replica] = (
            self.replica_dispatches.get(replica, 0) + 1
        )

    def has_pending(self) -> bool:
        return self.pending_frames > 0

    def pending_for(self, key: tuple) -> int:
        q = self._queues.get(key)
        return sum(r.n - r.served for r in q) if q else 0

    def drop(self, req: SchedRequest) -> None:
        """Remove a failed request's undispatched remainder from its queue
        (frames already handed to in-flight dispatches are past recall —
        their tickets are skipped at completion)."""
        q = self._queues.get(req.key)
        if not q or req not in q:
            return
        remaining = req.n - req.served
        q.remove(req)
        self.pending_frames -= remaining
        if req.served > 0:
            # only a partially-served head pins a carry bucket — dropping
            # it must release the pin, or the next unrelated request would
            # dispatch at the dead request's bucket
            self._carry.pop(req.key, None)
        if not q:
            del self._queues[req.key]
            self._carry.pop(req.key, None)

    def expire_due(self, now: float) -> List[SchedRequest]:
        """Remove queued, never-dispatched requests whose deadline passed.

        Returns them (the server fails each future with
        ``DeadlineExceededError``).  A partially-served request is kept:
        its dispatched frames are in flight and its tail must ride the
        pinned carry bucket — cancelling half a clip would hand back a
        torn result.  Expiry is therefore all-or-nothing, decided before
        the first frame dispatches.
        """
        if not self._queues:
            return []
        expired: List[SchedRequest] = []
        for key in list(self._queues):
            q = self._queues[key]
            due = [r for r in q
                   if r.deadline is not None and r.served == 0
                   and r.deadline <= now]
            for r in due:
                q.remove(r)
                self.pending_frames -= r.n
                expired.append(r)
            if not q:
                del self._queues[key]
                self._carry.pop(key, None)
        self.expired += len(expired)
        return expired

    def shed_victims(self, need: int, *, priority: int,
                     deadline: Optional[float]) -> Optional[List[SchedRequest]]:
        """Pick queued work to evict so ``need`` frames fit, or ``None``.

        Only requests ranked strictly BELOW the newcomer are candidates:
        lower priority, or equal priority with a later deadline (no
        deadline sorts latest — unconstrained work is the first to go).
        Partially-served requests are immune (their frames are in
        flight).  Victims are taken worst-first — lowest priority, then
        latest deadline, then newest — and removed from their queues;
        the caller fails their futures with ``RequestShedError``.

        Returns ``None`` without evicting anything when the candidates
        cannot free ``need`` frames: the newcomer is then the least
        urgent work in the building and should be rejected instead.
        """
        inf = float("inf")
        new_dl = inf if deadline is None else deadline

        def rank(r: SchedRequest) -> tuple:
            r_dl = inf if r.deadline is None else r.deadline
            return (r.priority, -r_dl, -r.seq)  # ascending = worst first

        cands = [
            r for q in self._queues.values() for r in q
            if r.served == 0 and (
                r.priority < priority
                or (r.priority == priority
                    and (inf if r.deadline is None else r.deadline) > new_dl)
            )
        ]
        cands.sort(key=rank)
        victims: List[SchedRequest] = []
        freed = 0
        for r in cands:
            if freed >= need:
                break
            victims.append(r)
            freed += r.n
        if freed < need:
            return None
        for r in victims:
            self.drop(r)
        self.shed += len(victims)
        return victims

    # ------------------------------------------------------------------
    # Dispatch formation
    # ------------------------------------------------------------------
    def _select_key(self, ready) -> Optional[tuple]:
        """The next key to dispatch: highest pending priority wins, FIFO
        (head arrival order) within a priority level; keys whose session
        has no pipeline-depth slack (``ready``) are skipped this round."""
        best_key, best_rank = None, None
        for key, q in self._queues.items():
            if not q or not ready(q[0].session):
                continue
            rank = (-max(r.priority for r in q), q[0].seq)
            if best_rank is None or rank < best_rank:
                best_key, best_rank = key, rank
        return best_key

    def next_dispatch(self, ready, bucket_fn=None) -> Optional[Dispatch]:
        """Form the next bucket-sized dispatch, or ``None`` if nothing is
        pending for a ready session.  Consumes the taken frames from the
        queues and updates the coalescing counters.  ``bucket_fn``, when
        given, post-processes a freshly derived bucket size (the server's
        degrade policy shrinks buckets under pressure); a carry-pinned
        bucket is NEVER resized — a clip mid-flight keeps its program."""
        key = self._select_key(ready)
        if key is None:
            return None
        q = self._queues[key]
        session = q[0].session
        # a partially-served head pins the bucket its first chunk used, so
        # clip tails never compile a second (smaller) program; otherwise
        # size the bucket to everything pending for the key — coalesced
        # traffic fills the largest legal bucket
        bucket = self._carry.get(key)
        if bucket is None:
            bucket = session._bucket_for(self.pending_for(key))
            if bucket_fn is not None:
                bucket = max(1, int(bucket_fn(bucket)))
        tickets: List[Ticket] = []
        slot = 0
        while q and slot < bucket:
            r = q[0]
            take = min(r.n - r.served, bucket - slot)
            tickets.append(Ticket(request=r, start=r.served, n=take, slot=slot))
            r.served += take
            slot += take
            if r.served == r.n:
                q.popleft()
            else:
                break  # bucket full mid-request — it stays at the head
        if q and q[0].served > 0:
            self._carry[key] = bucket
        else:
            self._carry.pop(key, None)
        if not q:
            del self._queues[key]
        subset: Optional[tuple] = None
        if tickets[0].request.bands is not None:
            # band requests only ever share a queue with band requests
            # (the "bands" key marker), so every ticket carries indices
            picked: List[int] = []
            for t in tickets:
                picked.extend(t.request.bands[t.start : t.start + t.n])
            subset = tuple(picked)
        d = Dispatch(key=key, session=session, plan=tickets[0].request.plan,
                     bucket=bucket, tickets=tickets, band_subset=subset)
        self.pending_frames -= d.real
        self.dispatches += 1
        if len(d.requests) > 1:
            self.coalesced_dispatches += 1
        self.frames_dispatched += d.real
        self.slots_dispatched += bucket
        self.recent_dispatches.append({
            "model": key[0],
            "lr_shape": list(d.plan.lr_shape),
            "dtype": key[2],
            "bucket": bucket,
            "frames": d.real,
            "fill": d.fill,
            "requests": len(d.requests),
            "priority": max(t.request.priority for t in tickets),
            "bands": None if subset is None else list(subset),
        })
        return d

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Cumulative coalescing/queue counters.

        ``mean_fill_ratio`` is real frames over bucket slots across every
        dispatch — 1.0 means the coalescer padded nothing; ``padded_frames``
        is the absolute slack.  ``coalesced_dispatches`` counts dispatches
        that carried more than one request.
        """
        slots = self.slots_dispatched
        return {
            "submitted_requests": self.submitted_requests,
            "submitted_frames": self.submitted_frames,
            "pending_frames": self.pending_frames,
            "peak_pending_frames": self.peak_pending_frames,
            "dispatches": self.dispatches,
            "coalesced_dispatches": self.coalesced_dispatches,
            "frames_dispatched": self.frames_dispatched,
            "slots_dispatched": slots,
            "padded_frames": slots - self.frames_dispatched,
            "mean_fill_ratio": self.frames_dispatched / slots if slots else 0.0,
            "rejected": self.rejected,
            "expired": self.expired,
            "shed": self.shed,
            "replica_dispatches": dict(self.replica_dispatches),
            # live carry pins — an abandoned clip must release its pinned
            # bucket (the stream-cleanup leak test asserts this hits 0)
            "carry_buckets": len(self._carry),
        }
