"""Symmetric int8 quantisation (ABPN ships 8-bit weights; paper §I).

The accelerator stores 8-bit weights, biases and activations.  We model the
same numerics in JAX:

* :func:`quantize` / :func:`dequantize` — symmetric int8 with per-tensor or
  per-channel scales.
* :func:`fake_quant` — straight-through-estimator fake quantisation for
  quantisation-aware training (used by ``examples/train_abpn.py``).
* :func:`quantize_layers` — converts a float ``ConvLayer`` stack into an
  int8-weight stack with dequant-on-read semantics (what the PE array sees).

This module is also reused by the gradient-compression path
(``distributed/grad_sync.py``) — int8-with-error-feedback is the same
primitive applied to gradients.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.fusion import ConvLayer

__all__ = [
    "quantize",
    "dequantize",
    "fake_quant",
    "QuantizedConvLayer",
    "quantize_layers",
    "dequantize_layers",
]

_EPS = 1e-12


def _scale_for(x: jax.Array, axis: Optional[Tuple[int, ...]]) -> jax.Array:
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, _EPS) / 127.0


def quantize(
    x: jax.Array, axis: Optional[Tuple[int, ...]] = None
) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantisation.

    Args:
      x: float array.
      axis: axes to REDUCE when computing the scale. ``None`` = per-tensor;
        e.g. for HWIO conv weights, ``axis=(0, 1, 2)`` gives per-output-
        channel scales.

    Returns:
      (q, scale) with ``q`` int8 and ``x ≈ q * scale``.
    """
    scale = _scale_for(x, axis)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return q.astype(dtype) * scale.astype(dtype)


def fake_quant(x: jax.Array, axis: Optional[Tuple[int, ...]] = None) -> jax.Array:
    """Quantise-dequantise with a straight-through gradient (QAT)."""
    scale = _scale_for(x, axis)
    q = jnp.clip(jnp.round(x / scale), -127, 127) * scale
    return x + jax.lax.stop_gradient(q - x)


@dataclasses.dataclass
class QuantizedConvLayer:
    """int8 storage form of a :class:`ConvLayer` (per-out-channel scales)."""

    wq: jax.Array  # (3, 3, Ci, Co) int8
    w_scale: jax.Array  # (1, 1, 1, Co)
    bq: jax.Array  # (Co,) int32 (bias kept wide, as accumulators are)
    b_scale: jax.Array  # ()
    relu: bool = True


jax.tree_util.register_dataclass(
    QuantizedConvLayer,
    data_fields=["wq", "w_scale", "bq", "b_scale"],
    meta_fields=["relu"],
)


def quantize_layers(layers: Sequence[ConvLayer]) -> List[QuantizedConvLayer]:
    out = []
    for l in layers:
        wq, ws = quantize(l.w, axis=(0, 1, 2))
        bs = jnp.maximum(jnp.max(jnp.abs(l.b)), _EPS) / (2**23)  # wide bias
        bq = jnp.round(l.b / bs).astype(jnp.int32)
        out.append(QuantizedConvLayer(wq=wq, w_scale=ws, bq=bq, b_scale=bs, relu=l.relu))
    return out


def dequantize_layers(qlayers: Sequence[QuantizedConvLayer], dtype=jnp.float32) -> List[ConvLayer]:
    return [
        ConvLayer(
            w=dequantize(q.wq, q.w_scale, dtype),
            b=dequantize(q.bq, q.b_scale, dtype),
            relu=q.relu,
        )
        for q in qlayers
    ]
