"""The paper's primary contribution: tilted layer fusion."""

from repro.core.fusion import (
    ConvLayer,
    conv_stack_reference,
    run_banded,
    tilted_fused_band,
)
from repro.core.tiling import TileSchedule, make_schedule

__all__ = [
    "ConvLayer",
    "conv_stack_reference",
    "run_banded",
    "tilted_fused_band",
    "TileSchedule",
    "make_schedule",
]
