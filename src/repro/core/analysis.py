"""Analytic hardware models reproducing the paper's §IV analysis.

Everything here is *derived from the implementation geometry* (the same
``TileSchedule`` the executors use), then checked against the paper's
published numbers:

* :func:`buffer_sizes`        — eqs. (1)-(3) -> Table II (102.36 KB total)
* :func:`classical_buffer_sizes` — the 60x60-tile classical-fusion column
* :func:`dram_traffic`        — 5.03 GB/s layerwise vs 0.41 GB/s fused (−92%)
* :func:`pe_throughput_model` — 1260-MAC vectorwise dataflow -> Table I
  (FHD @ >60 fps at 600 MHz, ~87% MAC utilisation)

NOTE on units: the paper uses decimal KB (1 KB = 1000 B) — with that
convention its ping-pong (26.88), overlap (30.24) and residual (2.7) entries
are *bit-exact* against eqs. (1)-(3); we follow the same convention.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence

__all__ = [
    "ABPN_CHANNELS",
    "HWConfig",
    "weight_bytes",
    "buffer_sizes",
    "classical_buffer_sizes",
    "dram_traffic",
    "dram_reduction",
    "on_chip_budget_kb",
    "pe_throughput_model",
    "PAPER_TABLE2",
    "PAPER_CLAIMS",
]

# Feature-map channel counts F_0..F_7 of ABPN as used by the paper:
# input RGB -> 6x (3x3 conv, 28ch, ReLU) -> 3x3 conv, 27ch (= 3 * 3^2 for the
# x3 pixel shuffle).
ABPN_CHANNELS: List[int] = [3, 28, 28, 28, 28, 28, 28, 27]

# Published numbers we reproduce (decimal KB).
PAPER_TABLE2 = {
    "tilted": {
        "weight": 42.54,
        "ping_pong": 26.88,
        "overlap": 30.24,
        "residual": 2.7,
        "total": 102.36,
    },
    "classical": {
        "weight": 42.54,
        "ping_pong": 201.6,
        "overlap": 0.0,
        "residual": 10.8,
        "total": 254.94,
    },
}

PAPER_CLAIMS = {
    "dram_layerwise_gb_s": 5.03,
    "dram_fused_gb_s": 0.41,
    "dram_reduction": 0.92,
    "throughput_mpix_s": 124.4,
    "num_macs": 1260,
    "clock_mhz": 600,
    "utilization": 0.87,
    "sram_kb": 102.36,
    "lr_size": (360, 640),
    "hr_size": (1080, 1920),
    "fps": 60,
}


@dataclasses.dataclass(frozen=True)
class HWConfig:
    """Accelerator configuration (defaults = the paper's design point)."""

    band_rows: int = 60  # R
    tile_cols: int = 8  # C
    channels: Sequence[int] = tuple(ABPN_CHANNELS)  # F_0..F_L
    bytes_per_elem: int = 1  # 8-bit activations/weights
    overlap_queue_slots: int | None = None  # default L+2 (paper §IV-A.2 prose)
    # PE array (paper §III-B): 28 blocks x 3 arrays x (5 rows x 3 taps) MACs
    pe_blocks: int = 28
    pe_rows: int = 5
    clock_hz: float = 600e6
    lr_height: int = 360
    lr_width: int = 640
    scale: int = 3
    fps: float = 60.0

    @property
    def num_layers(self) -> int:
        return len(self.channels) - 1

    @property
    def max_channels(self) -> int:
        return max(self.channels)

    @property
    def num_macs(self) -> int:
        # 3 PE arrays per block finish a 3x3 conv column per cycle
        return self.pe_blocks * 3 * (self.pe_rows * 3)


def weight_bytes(cfg: HWConfig = HWConfig(), include_bias: bool = True) -> int:
    """3x3 conv weight (+bias) storage for the fused stack."""
    ch = cfg.channels
    w = sum(9 * ch[i] * ch[i + 1] for i in range(cfg.num_layers))
    b = sum(ch[1:]) if include_bias else 0
    return (w + b) * cfg.bytes_per_elem


def buffer_sizes(cfg: HWConfig = HWConfig()) -> Dict[str, float]:
    """Paper eqs. (1)-(3): on-chip buffer bytes for tilted layer fusion.

    Returns decimal-KB entries matching Table II's row names.
    """
    R, C, L = cfg.band_rows, cfg.tile_cols, cfg.num_layers
    chmax, ch0 = cfg.max_channels, cfg.channels[0]
    slots = cfg.overlap_queue_slots if cfg.overlap_queue_slots is not None else L + 2
    bpe = cfg.bytes_per_elem
    ping_pong = 2 * R * C * chmax * bpe  # eq. (1), x2 buffers
    overlap = slots * R * 2 * chmax * bpe  # eq. (2) with the RTL's L+2 slots
    residual = ch0 * R * (C + L) * bpe  # eq. (3)
    weights = weight_bytes(cfg)
    return {
        "weight_kb": weights / 1000,
        "ping_pong_kb": ping_pong / 1000,
        "overlap_kb": overlap / 1000,
        "residual_kb": residual / 1000,
        "total_kb": (ping_pong + overlap + residual + weights) / 1000,
    }


def classical_buffer_sizes(
    cfg: HWConfig = HWConfig(), tile_rows: int = 60, tile_cols: int = 60
) -> Dict[str, float]:
    """Classical (rectangular-tile) layer fusion buffer cost, per §IV-A.

    The classical scheme needs a 60x60 tile to amortise the boundary
    information loss that the tilt eliminates; there is no overlap buffer,
    but the ping-pong and residual buffers scale with the full tile area.
    """
    bpe = cfg.bytes_per_elem
    ping_pong = 2 * tile_rows * tile_cols * cfg.max_channels * bpe
    residual = cfg.channels[0] * tile_rows * tile_cols * bpe
    weights = weight_bytes(cfg)
    return {
        "weight_kb": weights / 1000,
        "ping_pong_kb": ping_pong / 1000,
        "overlap_kb": 0.0,
        "residual_kb": residual / 1000,
        "total_kb": (ping_pong + residual + weights) / 1000,
    }


def dram_traffic(cfg: HWConfig = HWConfig(), mode: str = "fused") -> Dict[str, float]:
    """Off-chip traffic model (paper §IV-B: 5.03 -> 0.41 GB/s, −92%).

    * ``layerwise`` — every intermediate feature map is written to DRAM and
      read back by the next layer (the [11]/[12] execution style).
    * ``fused``     — tilted layer fusion: only the input image, the output
      residual-added pixels and the weights cross the chip boundary; all
      intermediates live in the ping-pong/overlap SRAM (VMEM on TPU).
    """
    pix = cfg.lr_height * cfg.lr_width
    ch = cfg.channels
    bpe = cfg.bytes_per_elem
    in_bytes = pix * ch[0] * bpe
    out_bytes = pix * ch[-1] * bpe  # 27ch LR == 3ch HR after pixel shuffle
    w_bytes = weight_bytes(cfg)
    if mode == "layerwise":
        # write + read every intermediate F_1..F_{L-1}; F_L written once
        inter = sum(pix * c * bpe for c in ch[1:-1])
        per_frame = in_bytes + 2 * inter + out_bytes + w_bytes
    elif mode == "fused":
        per_frame = in_bytes + out_bytes + w_bytes
    else:
        raise ValueError(f"unknown mode {mode!r}")
    gb_s = per_frame * cfg.fps / 1e9
    return {"bytes_per_frame": per_frame, "gb_s": gb_s}


def on_chip_budget_kb(cfg: HWConfig = HWConfig()) -> float:
    """Table II's bottom line for the configured geometry, in decimal KB.

    This is the reference budget the static plan verifier
    (``repro.analysis.plan_check``) holds the Pallas kernel's real scratch
    allocation against; for the paper's design point it is 102.36 KB.
    """
    return buffer_sizes(cfg)["total_kb"]


def dram_reduction(cfg: HWConfig = HWConfig()) -> float:
    """Fractional DRAM-bandwidth reduction of fused vs layerwise (≈0.92)."""
    lw = dram_traffic(cfg, "layerwise")["gb_s"]
    fu = dram_traffic(cfg, "fused")["gb_s"]
    return 1.0 - fu / lw


def pe_throughput_model(cfg: HWConfig = HWConfig()) -> Dict[str, float]:
    """Cycle model of the vectorwise dataflow (paper §III-B/D -> Table I).

    Per cycle, the 28 PE blocks each process one *input* channel; the
    accumulator tree reduces them into one output channel's 5-row x 1-column
    segment with the full 3x3 receptive field (3 PE arrays cover the three
    weight columns).  Hence per tile and layer:

        cycles = C columns x ceil(R / 5) row groups x Ch_out

    Utilisation loss comes from layers with fewer than 28 input channels
    (layer 1 has 3) and from epilogue tiles — reproducing the paper's
    "average of 87% hardware utilization".
    """
    from repro.core.tiling import make_schedule

    R, C, L = cfg.band_rows, cfg.tile_cols, cfg.num_layers
    ch = cfg.channels
    sched = make_schedule(width=cfg.lr_width, tile_cols=C, num_layers=L)
    bands = math.ceil(cfg.lr_height / R)
    tiles_per_band = sched.num_tiles  # includes the tilt-flush epilogue
    row_groups = math.ceil(R / cfg.pe_rows)
    cycles_per_tile = sum(C * row_groups * ch[l + 1] for l in range(L))
    cycles_per_frame = bands * tiles_per_band * cycles_per_tile

    # MACs actually used: 9 taps x Ci x Co per output pixel, valid pixels only
    pix = cfg.lr_height * cfg.lr_width
    macs_per_frame = sum(9 * ch[l] * ch[l + 1] * pix for l in range(L))
    util = macs_per_frame / (cfg.num_macs * cycles_per_frame)

    fps = cfg.clock_hz / cycles_per_frame
    hr_pix = pix * cfg.scale * cfg.scale
    return {
        "cycles_per_frame": cycles_per_frame,
        "fps_capacity": fps,
        "meets_60fps": fps >= 60.0,
        "mpix_s_capacity": hr_pix * fps / 1e6,
        "mpix_s_at_target": hr_pix * min(fps, cfg.fps) / 1e6,
        "utilization": util,
        "num_macs": cfg.num_macs,
        "clock_mhz": cfg.clock_hz / 1e6,
    }
