"""Tilted layer fusion — pure-JAX reference executor (paper §II).

Three executors over the same 3x3-conv stack, cross-validated in tests:

* :func:`conv_stack_reference` — plain full-image, layer-by-layer SAME conv.
  Semantically the ground truth; also the model of the paper's *baseline*
  accelerators ([11]/[12]) that round-trip every feature map through DRAM.
* :func:`tilted_fused_band` — the paper's contribution: a single band swept
  by parallelepipedal column tiles via ``lax.scan``; the scan carry is the
  overlap buffer (the functional analogue of the queue-addressed SRAM of
  §III-F).  Horizontally EXACT w.r.t. the reference — the whole point of the
  tilt is that left/right boundary information is preserved.
* :func:`run_banded` — full-image driver: vertical band partitioning with a
  configurable boundary policy (``zero`` = paper's block-conv rows,
  ``halo`` = exact recompute margins, ``replicate`` = edge padding).

The Pallas TPU kernel in ``repro.kernels.tilted_fusion`` implements the same
schedule with the overlap buffer in persistent VMEM scratch; this module is
its oracle.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import TileSchedule, make_schedule

__all__ = [
    "ConvLayer",
    "conv_stack_reference",
    "tilted_fused_band",
    "run_banded",
    "halo_slabs",
    "max_channels",
]


@dataclasses.dataclass
class ConvLayer:
    """One fused 3x3 conv layer: HWIO weights, bias, ReLU flag."""

    w: jax.Array  # (3, 3, Ci, Co)
    b: jax.Array  # (Co,)
    relu: bool = True

    @property
    def ci(self) -> int:
        return self.w.shape[2]

    @property
    def co(self) -> int:
        return self.w.shape[3]


jax.tree_util.register_dataclass(
    ConvLayer, data_fields=["w", "b"], meta_fields=["relu"]
)


def max_channels(layers: Sequence[ConvLayer]) -> int:
    """max(Ch_i) over all feature maps F_0..F_L (paper's buffer bound)."""
    return max([layers[0].ci] + [l.co for l in layers])


# ----------------------------------------------------------------------
# Reference layerwise executor
# ----------------------------------------------------------------------
def _conv2d(x: jax.Array, w: jax.Array, padding) -> jax.Array:
    """NHWC/HWIO conv on a single (H, W, C) image."""
    return jax.lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(1, 1),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]


def conv_stack_reference(x: jax.Array, layers: Sequence[ConvLayer]) -> jax.Array:
    """Full-image layer-by-layer execution with SAME zero padding.

    This is both the numerical oracle for the fused executors and the model
    of layer-by-layer accelerators: each intermediate here corresponds to a
    full feature-map DRAM round trip (bandwidth modelled in
    ``core.analysis.dram_traffic``).
    """
    f = x
    for layer in layers:
        f = _conv2d(f, layer.w, "SAME") + layer.b
        if layer.relu:
            f = jax.nn.relu(f)
    return f


# ----------------------------------------------------------------------
# Tilted fused executor (one band)
# ----------------------------------------------------------------------
def _conv_tile(f: jax.Array, layer: ConvLayer, row_pad: str) -> jax.Array:
    """3x3 conv of a (R, C+2, Ci) tile slab -> (R, C, Co).

    Columns are VALID (the slab already carries the +-1 column halo, courtesy
    of the overlap buffer); rows are padded per the band policy.
    """
    if row_pad == "zero":
        f = jnp.pad(f, ((1, 1), (0, 0), (0, 0)))
    elif row_pad == "replicate":
        f = jnp.pad(f, ((1, 1), (0, 0), (0, 0)), mode="edge")
    else:  # pragma: no cover - guarded by caller
        raise ValueError(f"unknown row_pad {row_pad!r}")
    out = _conv2d(f, layer.w, "VALID") + layer.b
    if layer.relu:
        out = jax.nn.relu(out)
    return out


def tilted_fused_band(
    x: jax.Array,
    layers: Sequence[ConvLayer],
    tile_cols: int = 8,
    row_pad: str = "zero",
    row_valid: Optional[Tuple[int, int]] = None,
) -> jax.Array:
    """Run the tilted layer-fusion sweep over one band.

    Args:
      x: band input, shape ``(R, W, Ch0)``.
      layers: the fused conv stack (L layers).
      tile_cols: C, the parallelepiped width (paper: 8).
      row_pad: vertical boundary policy *within* the band.
      row_valid: optional ``(lo, hi)`` band-row range that is real image
        content; rows outside it are phantom (e.g. the zero margin a
        ``halo`` band carries past the image edge) and are re-zeroed after
        every layer so they behave exactly like SAME padding.

    Returns:
      ``(R, W, Ch_L)`` — bit-compatible with
      ``conv_stack_reference`` horizontally (rows differ only per band
      policy, which is the caller's concern — see :func:`run_banded`).

    Implementation notes (mirrors the hardware):
      * the scan carry is the overlap buffer, shape ``(L, R, 2, Chmax)`` —
        feature index 0 is the *input* stream (so only C fresh input columns
        stream per tile, the source of the DRAM-bandwidth reduction);
      * phantom columns (absolute col < 0 or >= W) are zeroed after every
        layer so that edge effects match SAME padding exactly
        (``tiling.phantom_mask``).
    """
    if tile_cols < 2:
        raise ValueError("tile_cols must be >= 2 (overlap hand-off is 2 columns)")
    R, W, C0 = x.shape
    L = len(layers)
    sched = make_schedule(width=W, tile_cols=tile_cols, num_layers=L)
    K, C = sched.num_tiles, tile_cols
    chmax = max_channels(layers)
    dtype = x.dtype

    # Fresh input stream: tile k consumes absolute input columns
    # [k*C + 1, k*C + C]; pad the image with zeros out to column K*C.
    x_pad = jnp.pad(x, ((0, 0), (0, K * C + 1 - W), (0, 0)))
    xs = x_pad[:, 1 : K * C + 1, :]  # columns 1 .. K*C
    xs = xs.reshape(R, K, C, C0).transpose(1, 0, 2, 3)  # (K, R, C, C0)

    # Overlap buffer init: all zeros except feature 0 holds input columns
    # [-1, 0] = [zero-pad, first real column].
    overlap0 = jnp.zeros((L, R, 2, chmax), dtype)
    overlap0 = overlap0.at[0, :, 1, :C0].set(x[:, 0, :])

    col_idx = jnp.arange(C)
    if row_valid is not None:
        row_mask = (jnp.arange(R) >= row_valid[0]) & (jnp.arange(R) < row_valid[1])
    else:
        row_mask = None

    def tile_step(overlap, inputs):
        k, fresh = inputs
        new_overlap = overlap
        # Assemble the input slab: 2 overlap columns ++ C fresh columns.
        f = jnp.concatenate([overlap[0, :, :, :C0], fresh], axis=1)  # (R, C+2, C0)
        new_overlap = new_overlap.at[0, :, :, :C0].set(f[:, -2:, :])
        out = None
        for l, layer in enumerate(layers):
            g = _conv_tile(f, layer, row_pad)  # (R, C, Co)
            # Zero phantom columns: output cols are k*C - l + [0, C).
            abs_cols = k * C - l + col_idx
            valid = (abs_cols >= 0) & (abs_cols < W)
            g = jnp.where(valid[None, :, None], g, 0)
            if row_mask is not None:
                g = jnp.where(row_mask[:, None, None], g, 0)
            if l < L - 1:
                left = overlap[l + 1, :, :, : layer.co]  # F_{l+1} left 2 cols
                new_overlap = new_overlap.at[l + 1, :, :, : layer.co].set(
                    g[:, -2:, :]
                )
                f = jnp.concatenate([left, g], axis=1)  # (R, C+2, Co)
            else:
                out = g
        return new_overlap, out

    ks = jnp.arange(K)
    _, tiles = jax.lax.scan(tile_step, overlap0, (ks, xs))
    # tiles: (K, R, C, ChL). Tile k's output occupies absolute columns
    # [k*C - (L-1), k*C - (L-1) + C) -> contiguous; slice off the tilt.
    out = tiles.transpose(1, 0, 2, 3).reshape(R, K * C, layers[-1].co)
    return jax.lax.slice_in_dim(out, L - 1, L - 1 + W, axis=1)


# ----------------------------------------------------------------------
# Halo slab marshalling (shared by the tilted and Pallas backends)
# ----------------------------------------------------------------------
def halo_slabs(frames: jax.Array, band_rows: int, num_layers: int):
    """Marshal halo slabs: (N, H, W, C0) -> (N*B, R+2L, W, C0) + (N*B, 2).

    Each band's slab is the (R + 2L)-row window of the zero-padded frame
    starting at its own row offset; the int32 bounds mark which slab rows
    are real image content (``[lo, hi)`` in slab coordinates).  Rows
    outside the bounds are phantom and must be re-zeroed after every conv
    layer (``tilted_fused_band``'s ``row_valid`` / the kernel's
    ``row_bounds``) so they behave exactly like SAME padding; cropping L
    rows per side afterwards reproduces the full-image result.

    This is the ONE definition of the engine's halo geometry — both the
    pure-JAX executor and the Pallas kernel marshalling consume it.
    """
    N, H, W, C0 = frames.shape
    R, L = band_rows, num_layers
    B = H // R
    slab = R + 2 * L
    padded = jnp.pad(frames, ((0, 0), (L, L), (0, 0), (0, 0)))
    slabs = jnp.stack(
        [padded[:, b * R : b * R + slab] for b in range(B)], axis=1
    )  # (N, B, R+2L, W, C0)
    starts = np.arange(B) * R
    lo = np.clip(L - starts, 0, slab)
    hi = np.clip(L + H - starts, 0, slab)
    bounds = np.tile(np.stack([lo, hi], axis=1), (N, 1)).astype(np.int32)
    return slabs.reshape(N * B, slab, W, C0), jnp.asarray(bounds)


# ----------------------------------------------------------------------
# Full-image banded driver
# ----------------------------------------------------------------------
def run_banded(
    image: jax.Array,
    layers: Sequence[ConvLayer],
    band_rows: int = 60,
    tile_cols: int = 8,
    vertical_policy: str = "zero",
) -> jax.Array:
    """Tilted layer fusion over a full image, band by band.

    vertical_policy:
      * ``"zero"`` — the paper's scheme: each R-row band is convolved with
        zero padding at its top/bottom edges (block convolution vertically).
        Information at the 5 interior band boundaries of a 360-row image is
        discarded; the PSNR penalty is <0.2 dB (reproduced in
        ``benchmarks/psnr_penalty.py``).
      * ``"halo"`` — exact: each band is extracted with an L-row margin on
        each side and the margin is cropped after the fused stack, trading
        ~2*L/R recompute for bit-exactness with the full-image reference.
      * ``"replicate"`` — zero-cost variant of "zero" with edge-replicate
        padding (usually a slightly smaller PSNR penalty on natural images).
    """
    H, W, _ = image.shape
    L = len(layers)
    if H % band_rows != 0:
        raise ValueError(f"image height {H} must be a multiple of band_rows {band_rows}")
    n_bands = H // band_rows
    outs = []
    for b in range(n_bands):
        r0 = b * band_rows
        if vertical_policy in ("zero", "replicate"):
            band = image[r0 : r0 + band_rows]
            out = tilted_fused_band(band, layers, tile_cols, row_pad=vertical_policy)
        elif vertical_policy == "halo":
            lo = max(0, r0 - L)
            hi = min(H, r0 + band_rows + L)
            band = image[lo:hi]
            # zero-pad to a full halo if clipped by the image edge; the pad
            # rows are phantom and must stay zero through every layer
            # (row_valid) to match SAME padding semantics exactly.
            pad_top = L - (r0 - lo)
            pad_bot = L - (hi - r0 - band_rows)
            band = jnp.pad(band, ((pad_top, pad_bot), (0, 0), (0, 0)))
            out = tilted_fused_band(
                band,
                layers,
                tile_cols,
                row_pad="zero",
                row_valid=(pad_top, pad_top + hi - lo),
            )
            out = out[L : L + band_rows]
        else:
            raise ValueError(f"unknown vertical_policy {vertical_policy!r}")
        outs.append(out)
    return jnp.concatenate(outs, axis=0)
