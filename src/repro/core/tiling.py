"""Tilted tile geometry (paper §II, Fig. 2).

The tilted layer fusion schedule partitions a feature-map band (R rows tall,
W image columns wide) into *parallelepipedal* tiles: tile ``k`` at layer ``l``
(the conv producing feature map ``F_{l+1}`` from ``F_l``) covers output
columns ``[k*C - l, k*C - l + C)`` — each layer's tile region is shifted one
column LEFT of the previous layer's, because a 3x3 conv consumes a one-column
halo per side.

Consequences (all encoded and unit-tested here):

* RIGHT boundary: layer ``l`` needs ``F_l`` up to column ``k*C - l + C``
  (inclusive); the same tile's layer ``l-1`` just produced ``F_l`` up to
  exactly that column — data is ready with zero waiting and zero storage.
* LEFT boundary: layer ``l`` needs ``F_l`` columns ``k*C - l - 1`` and
  ``k*C - l``; these are precisely the LAST TWO columns of ``F_l`` produced
  by tile ``k-1`` — retained in the overlap buffer (paper §III-F).
* The overlap buffer therefore stores, for each of the L fused feature maps
  ``F_0 .. F_{L-1}``, two columns of R rows: ``M_o = L * R * 2 * max(Ch)``
  (paper eq. 2; the RTL allocates L+2 queue slots for pipelining).

Column coordinates here are *absolute image columns*; negative columns and
columns ``>= W`` are phantom (outside the image). Phantom columns must read
as zero wherever consumed so the fused result matches SAME-padded
convolution exactly — see :func:`phantom_mask`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import numpy as np

__all__ = [
    "TileSchedule",
    "make_schedule",
    "phantom_mask",
]


@dataclasses.dataclass(frozen=True)
class TileSchedule:
    """Static geometry of a tilted layer-fusion sweep over one band.

    Attributes:
      width: W, image width in columns.
      tile_cols: C, tile width in columns (paper uses 8).
      num_layers: L, number of fused conv layers (paper's ABPN uses 7).
      num_tiles: K, total tiles per band *including* the epilogue tiles that
        flush the last output columns (the final layer's tile is shifted
        L-1 columns left, so ``K = ceil((W + L - 1) / C)``).
    """

    width: int
    tile_cols: int
    num_layers: int

    def __post_init__(self):
        if self.width <= 0 or self.tile_cols <= 0 or self.num_layers <= 0:
            raise ValueError(
                f"width={self.width}, tile_cols={self.tile_cols}, "
                f"num_layers={self.num_layers} must all be positive"
            )

    # ------------------------------------------------------------------
    # Derived geometry
    # ------------------------------------------------------------------
    @property
    def num_tiles(self) -> int:
        """K — includes epilogue tiles that flush the tilted tail."""
        return math.ceil((self.width + self.num_layers - 1) / self.tile_cols)

    def out_cols(self, k: int, layer: int) -> Tuple[int, int]:
        """Absolute [start, stop) columns of F_{layer+1} produced by tile k."""
        start = k * self.tile_cols - layer
        return start, start + self.tile_cols

    def in_cols(self, k: int, layer: int) -> Tuple[int, int]:
        """Absolute [start, stop) columns of F_layer consumed by tile k.

        A 3x3 conv over output columns [a, a+C) reads input [a-1, a+C+1).
        """
        a, b = self.out_cols(k, layer)
        return a - 1, b + 1

    def overlap_cols(self, k: int, layer: int) -> Tuple[int, int]:
        """The two F_layer columns tile k reads from the overlap buffer."""
        a, _ = self.in_cols(k, layer)
        return a, a + 2

    def saved_cols(self, k: int, feature: int) -> Tuple[int, int]:
        """The two columns of F_feature tile k writes INTO the overlap buffer.

        ``feature`` 0 is the band input; features 1..L-1 are intermediate
        outputs.  These are always the last two columns tile k holds of that
        feature map.
        """
        if feature == 0:
            _, b = self.in_cols(k, 0)  # input slab spans in_cols of layer 0
            return b - 2, b
        a, b = self.out_cols(k, feature - 1)
        return b - 2, b

    def fresh_input_cols(self, k: int) -> Tuple[int, int]:
        """Absolute F_0 columns streamed from HBM/DRAM for tile k.

        The input slab of tile k is ``in_cols(k, 0)`` = C+2 columns; the left
        two arrive from the overlap buffer (saved by tile k-1), so only C
        fresh columns stream per tile — the core of the bandwidth saving.
        """
        a, b = self.in_cols(k, 0)
        return a + 2, b

    @property
    def final_offset(self) -> int:
        """Column of F_L produced first (tile 0): ``-(L-1)``.

        Reassembly places tile k's final-layer output at
        ``k*C - (L-1)``; slicing ``[L-1 : L-1+W]`` from the concatenated
        tiles recovers image columns ``[0, W)``.
        """
        return -(self.num_layers - 1)

    # ------------------------------------------------------------------
    # Invariants (used by property tests; also self-documenting)
    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Assert the schedule's correctness properties for every tile/layer.

        1. Right-readiness: layer l's input never extends past what layer
           l-1 of the SAME tile has produced.
        2. Left-overlap: the two left input columns of tile k, layer l are
           exactly the columns tile k-1 saved for feature l.
        3. Output coverage: final-layer outputs of consecutive tiles are
           contiguous and disjoint, and their union covers [0, W).
        """
        L, C, W, K = self.num_layers, self.tile_cols, self.width, self.num_tiles
        for k in range(K):
            for l in range(L):
                in_a, in_b = self.in_cols(k, l)
                if l > 0:
                    prod_a, prod_b = self.out_cols(k, l - 1)
                    # (1) everything needed beyond the overlap columns is
                    # covered by the same tile's previous-layer output
                    assert in_b <= prod_b, (k, l, in_b, prod_b)
                    assert in_a + 2 == prod_a, (k, l)
                if k > 0:
                    sa, sb = self.saved_cols(k - 1, l)
                    oa, ob = self.overlap_cols(k, l)
                    # (2) the overlap hand-off is exact
                    assert (sa, sb) == (oa, ob), (k, l, (sa, sb), (oa, ob))
        # (3) coverage of the final feature map
        lo = self.out_cols(0, L - 1)[0]
        hi = self.out_cols(K - 1, L - 1)[1]
        assert lo <= 0 and hi >= W, (lo, hi, W)
        for k in range(K - 1):
            assert self.out_cols(k, L - 1)[1] == self.out_cols(k + 1, L - 1)[0]

    # ------------------------------------------------------------------
    # Tabulation helpers (used by the HW analysis + visual debugging)
    # ------------------------------------------------------------------
    def table(self) -> List[dict]:
        rows = []
        for k in range(self.num_tiles):
            for l in range(self.num_layers):
                rows.append(
                    dict(
                        tile=k,
                        layer=l,
                        in_cols=self.in_cols(k, l),
                        out_cols=self.out_cols(k, l),
                        overlap_read=self.overlap_cols(k, l),
                        overlap_write=self.saved_cols(k, l),
                    )
                )
        return rows


def make_schedule(width: int, tile_cols: int, num_layers: int) -> TileSchedule:
    """Build and validate a :class:`TileSchedule`."""
    sched = TileSchedule(width=width, tile_cols=tile_cols, num_layers=num_layers)
    return sched


def phantom_mask(col_start: int, num_cols: int, width: int) -> np.ndarray:
    """Boolean mask over ``num_cols`` absolute columns starting at ``col_start``.

    True for real image columns ``0 <= c < width``; False for phantom columns.
    Phantom columns produced by the tilted sweep MUST be zeroed before they
    are consumed by the next layer, otherwise values computed from edge
    padding leak into real columns and the result diverges from SAME-padded
    convolution (tested in ``tests/test_tilted_fusion.py``).
    """
    cols = np.arange(col_start, col_start + num_cols)
    return (cols >= 0) & (cols < width)
