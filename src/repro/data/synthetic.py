"""Deterministic synthetic data: LM token streams and SR image pairs.

Every batch is a pure function of (seed, step) — restarts and elastic
re-shards reproduce the exact same stream, which the fault-tolerance tests
rely on.  The SR pair generator produces band-limited textures (filtered
noise) so that bicubic-ish downsampling leaves learnable structure; ABPN
training on these pairs shows real PSNR gains in a few hundred steps.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["lm_batch", "sr_pair_batch", "downsample"]


def lm_batch(cfg, step: int, batch: int, seq: int, seed: int = 0) -> Dict[str, jax.Array]:
    """Markov-ish token batch: tokens, next-token targets, mask.

    Tokens follow a noisy arithmetic progression modulo vocab so there is
    actual structure for a model to learn (loss drops well below uniform).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2 = jax.random.split(key)
    start = jax.random.randint(k1, (batch, 1), 0, cfg.vocab_size, jnp.int32)
    stride = jax.random.randint(k2, (batch, 1), 1, 7, jnp.int32)
    pos = jnp.arange(seq + 1, dtype=jnp.int32)[None, :]
    stream = (start + stride * pos) % cfg.vocab_size
    tokens, targets = stream[:, :-1], stream[:, 1:]
    return {
        "tokens": tokens,
        "targets": targets,
        "mask": jnp.ones_like(tokens),
    }


def _smooth_noise(key, h: int, w: int, c: int, octaves: int = 3) -> jax.Array:
    """Band-limited texture in [0, 1]: sum of upsampled noise octaves."""
    img = jnp.zeros((h, w, c))
    for o in range(octaves):
        f = 2 ** (o + 2)
        key, k = jax.random.split(key)
        coarse = jax.random.uniform(k, (max(h // f, 1), max(w // f, 1), c))
        img = img + jax.image.resize(coarse, (h, w, c), "bilinear") / (o + 1)
    lo, hi = img.min(), img.max()
    return (img - lo) / jnp.maximum(hi - lo, 1e-6)


def downsample(hr: jax.Array, scale: int) -> jax.Array:
    """Area (box) downsample — the LR degradation model."""
    h, w, c = hr.shape
    return hr.reshape(h // scale, scale, w // scale, scale, c).mean(axis=(1, 3))


def sr_pair_batch(
    step: int,
    batch: int,
    lr_shape: Tuple[int, int] = (60, 64),
    scale: int = 3,
    channels: int = 3,
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """(lr (B,h,w,C), hr (B,h*s,w*s,C)) pairs, deterministic in step."""
    h, w = lr_shape
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 7), step)
    keys = jax.random.split(key, batch)
    hr = jnp.stack([_smooth_noise(k, h * scale, w * scale, channels) for k in keys])
    lr = jnp.stack([downsample(im, scale) for im in hr])
    return lr, hr
