"""Input pipeline: deterministic generation + background prefetch + sharding.

``Prefetcher`` overlaps host-side batch synthesis with device compute via a
bounded queue on a worker thread (double buffering by default — the same
role the paper's DMA/ping-pong input staging plays).  When a mesh context
is active, batches are placed with their logical-axis NamedShardings so
jit steps consume them without host round-trips.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax

from repro.distributed import partitioning as pt

__all__ = ["Prefetcher", "make_lm_stream"]


class Prefetcher:
    """Bounded background prefetch over a step-indexed batch function."""

    def __init__(
        self,
        batch_fn: Callable[[int], Dict],
        start_step: int = 0,
        depth: int = 2,
        place: Optional[Callable] = None,
    ):
        self._fn = batch_fn
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._place = place
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._fn(step)
            if self._place is not None:
                batch = self._place(batch)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def make_lm_stream(cfg, batch: int, seq: int, seed: int = 0, start_step: int = 0,
                   batch_axes: Optional[Dict] = None) -> Prefetcher:
    from repro.data.synthetic import lm_batch

    place = None
    mesh = pt.current_mesh()
    if mesh is not None and batch_axes:
        def place(b):
            return {
                k: jax.device_put(
                    v,
                    jax.sharding.NamedSharding(
                        mesh, pt.shape_aware_spec(batch_axes[k], v.shape)
                    ),
                )
                for k, v in b.items()
            }

    return Prefetcher(
        lambda s: lm_batch(cfg, s, batch, seq, seed), start_step=start_step,
        place=place,
    )
