"""repro.data substrate."""
