"""repro.optim"""
