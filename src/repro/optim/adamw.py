"""AdamW with global-norm clipping and warmup+cosine schedule.

Functional, pytree-based.  Moment dtype is configurable: the ≥200B configs
run bf16 moments so that params + both moments fit 16 GB/chip under FSDP
(DESIGN.md §6); parameter updates are computed in fp32 regardless.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_opt_state", "adamw_update", "lr_schedule", "global_norm"]


def init_opt_state(params, moment_dtype=jnp.float32) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def lr_schedule(step, tcfg) -> jax.Array:
    """Linear warmup then cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - tcfg.warmup_steps)
        / jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * t))
    return tcfg.learning_rate * warm * cos


def adamw_update(
    grads, opt_state, params, tcfg
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(step, tcfg)
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        p_new = p.astype(jnp.float32) - lr * (update + wd * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
