"""Compiled-program audit: scan what serving ACTUALLY runs.

The serving guarantees established by PRs 4-5 — weight prep hoisted out
of the hot path, no host round-trips inside the drain loop, donation
when requested — are properties of the *compiled* executor, not of the
Python source.  This pass scans the jaxpr and optimized HLO of every
executor a session's :class:`~repro.engine.session.PlanCache` holds:

* ``quant_in_hot_path`` — quantise/dequantise rounding in a prepared
  program.  The int8 policy rounds weights exactly once at
  ``prepare_stack`` time; a ``round`` primitive inside the per-batch
  program means the round-trip got traced back in (the regression PR 4's
  bespoke jaxpr test guarded; this pass is that guarantee, generalized).
* ``host_callback`` / ``host_transfer`` — ``pure_callback``/``io_callback``
  in the jaxpr, or infeed/outfeed/send/recv ops and callback
  custom-calls in the HLO.  Any of these serializes the serving loop on
  the host.
* ``fp32_upcast`` — a bf16 plan whose conv/dot ops all emit fp32: the
  on-chip compute silently fell back to full precision (fp32
  *accumulation* with bf16 outputs is fine and expected on the MXU).
  int8 plans deliberately compute in fp32 (dequant-on-read), so the rule
  applies to ``bf16`` only.
* ``missing_donation`` — the session resolved ``donate_frames=True`` but
  the cached executor was built without donation (or the entry's
  bookkeeping disagrees with the executor).
* ``recompile`` — a ``(plan, bucket, dtype)`` cache key that compiled
  more than once (evicted and re-missed): steady-state latency paid a
  hidden compile.
"""

from __future__ import annotations

import re
from typing import List, Optional

from repro.analysis.findings import Finding

__all__ = [
    "audit_jaxpr",
    "audit_hlo",
    "audit_entry",
    "audit_session",
    "QUANT_TOKEN",
    "HOST_TRANSFER_OPCODES",
]

# The quantise round-trip's jaxpr fingerprint: `round` is emitted by
# core.quant's round-to-nearest and by nothing else in the datapath
# (clipping lowers to clamp, casts to convert_element_type).
QUANT_TOKEN = "round"

HOST_TRANSFER_OPCODES = frozenset(
    {"infeed", "outfeed", "send", "send-done", "recv", "recv-done"}
)

# jaxpr eqn line: `c:f32[2,60,64,28] = conv_general_dilated[...] a b`
_MATMUL_EQN_RE = re.compile(r"=\s*(conv_general_dilated|dot_general)\b")
_OUT_DTYPE_RE = re.compile(r":([a-z][a-z0-9]*)\[")


def audit_jaxpr(
    jaxpr_text: str, *, precision: Optional[str] = None, where: str = ""
) -> List[Finding]:
    """Scan a traced program's jaxpr text for hot-path violations."""
    findings: List[Finding] = []
    if QUANT_TOKEN in jaxpr_text:
        findings.append(Finding(
            checker="program",
            rule="quant_in_hot_path",
            severity="error",
            message=(
                "quantise rounding traced into the per-batch program — "
                "weight prep must happen once in prepare_stack, never "
                "inside the serving call"
            ),
            where=where,
        ))
    if "callback" in jaxpr_text:
        findings.append(Finding(
            checker="program",
            rule="host_callback",
            severity="error",
            message=(
                "host callback in the serving program — every batch would "
                "synchronize with the Python host"
            ),
            where=where,
        ))
    if precision == "bf16":
        matmul_dtypes: List[str] = []
        for line in jaxpr_text.splitlines():
            if _MATMUL_EQN_RE.search(line):
                lhs = line.split("=", 1)[0]
                matmul_dtypes.extend(_OUT_DTYPE_RE.findall(lhs))
        if matmul_dtypes and "bf16" not in matmul_dtypes:
            findings.append(Finding(
                checker="program",
                rule="fp32_upcast",
                severity="warning",
                message=(
                    "bf16 plan, but every conv/dot in the program emits "
                    f"{sorted(set(matmul_dtypes))} — on-chip compute "
                    "silently upcast to full precision"
                ),
                where=where,
            ))
    return findings


def audit_hlo(hlo_text: str, *, where: str = "") -> List[Finding]:
    """Scan optimized HLO for host transfers and callback custom-calls."""
    from repro.roofline.hlo_parse import _split_computations

    findings: List[Finding] = []
    transfers: List[str] = []
    callbacks: List[str] = []
    for comp_ops in _split_computations(hlo_text).values():
        for op in comp_ops:
            if not hasattr(op, "opcode"):
                continue
            if op.opcode in HOST_TRANSFER_OPCODES:
                transfers.append(op.opcode)
            elif op.opcode == "custom-call" and "callback" in op.rest:
                callbacks.append(op.name)
    if transfers:
        findings.append(Finding(
            checker="program",
            rule="host_transfer",
            severity="error",
            message=(
                f"host-transfer ops in compiled program: "
                f"{sorted(set(transfers))} — the serving loop would stall "
                "on host I/O every dispatch"
            ),
            where=where,
        ))
    if callbacks:
        findings.append(Finding(
            checker="program",
            rule="host_callback",
            severity="error",
            message=(
                f"{len(callbacks)} callback custom-call(s) in compiled "
                "program — every batch round-trips through the Python host"
            ),
            where=where,
        ))
    return findings


def _entry_where(entry) -> str:
    p = entry.plan
    return (
        f"executor {p.backend}/{p.precision} {p.height}x{p.width} "
        f"bucket={entry.bucket} {entry.dtype}"
    )


def audit_entry(session, entry, *, compiled: bool = True) -> List[Finding]:
    """Audit ONE cached executor: its traced jaxpr, its optimized HLO
    (``compiled=True``; cached keys re-lower from jax's internal caches),
    and its donation bookkeeping against the session's resolved policy."""
    import jax

    from repro.engine.executor import executor_artifacts

    plan = entry.plan
    where = _entry_where(entry)
    rec = session._stacks.get(entry.stack_key)
    stack = rec.stack if rec is not None else None
    arts = executor_artifacts(
        plan, stack, entry.bucket, entry.dtype,
        layers=session.layers, compiled=compiled,
    )
    findings = audit_jaxpr(
        arts["jaxpr"], precision=plan.precision, where=where
    )
    if arts["hlo"] is not None:
        findings.extend(audit_hlo(arts["hlo"], where=where))

    requested = session._resolve_donate()
    built = bool(getattr(entry.fn, "donates_frames", entry.donates))
    if bool(entry.donates) != built:
        findings.append(Finding(
            checker="program",
            rule="donation_bookkeeping",
            severity="error",
            message=(
                f"cache entry records donates={entry.donates} but the "
                f"executor was built with donate_frames={built}"
            ),
            where=where,
        ))
    elif requested and not entry.donates:
        findings.append(Finding(
            checker="program",
            rule="missing_donation",
            severity="error",
            message=(
                "session resolves donate_frames=True but this executor "
                "was compiled without donation — the bucket slab stays "
                "pinned for the whole call"
            ),
            where=where,
        ))
    elif entry.donates and jax.default_backend() == "cpu":
        findings.append(Finding(
            checker="program",
            rule="donation_ignored",
            severity="info",
            message=(
                "executor donates its frame batch, but XLA:CPU does not "
                "implement input-output aliasing — donation is a no-op "
                "here (harmless)"
            ),
            where=where,
        ))
    return findings


def audit_session(session, *, compiled: bool = True) -> List[Finding]:
    """Audit EVERY executor the session's PlanCache currently holds, plus
    the per-key compile counters (recompile detection)."""
    findings: List[Finding] = []
    for entry in session._cache.entries():
        findings.extend(audit_entry(session, entry, compiled=compiled))
    for key, count in session._compile_counts.items():
        if count > 1:
            plan, bucket, dtype = key
            findings.append(Finding(
                checker="program",
                rule="recompile",
                severity="warning",
                message=(
                    f"cache key compiled {count} times (evicted and "
                    "re-missed) — steady-state traffic paid a hidden "
                    "compile; consider a larger cache_capacity"
                ),
                where=(
                    f"executor {plan.backend}/{plan.precision} "
                    f"{plan.height}x{plan.width} bucket={bucket} {dtype}"
                ),
            ))
    return findings
