"""repro.analysis — the static-analysis subsystem (CI gate).

Three checkers, one shape of diagnostic (:class:`Finding`), one front
door (``python -m repro.analysis``):

* :mod:`repro.analysis.plan_check` — prove an ``SRPlan``'s geometry:
  band coverage, halo sufficiency vs receptive-field growth, and the
  Pallas kernel's real on-chip bytes vs the paper's Table II budget
  (102.36 KB).  Wired into ``SRPlan.verify()`` and
  ``SRSession.open(..., strict=True)``.
* :mod:`repro.analysis.program_audit` — scan every compiled executor's
  jaxpr/HLO for quant ops in the hot path, host callbacks/transfers,
  silent fp32 upcasts, missing donation, and recompiles.
* :mod:`repro.analysis.concurrency_lint` — AST lint of the serving
  sources for blocking calls / ``await`` under a held lock and
  lock-order cycles.

NOTE: this package is imported lazily by the engine (never the reverse
at import time), so ``repro.engine`` stays importable without it and no
cycle forms.
"""

from repro.analysis.findings import (
    Finding,
    PlanVerificationError,
    SEVERITIES,
    count_by_checker,
    count_by_severity,
    errors,
    format_findings,
)

__all__ = [
    "Finding",
    "PlanVerificationError",
    "SEVERITIES",
    "count_by_checker",
    "count_by_severity",
    "errors",
    "format_findings",
]
