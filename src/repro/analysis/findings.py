"""Structured diagnostics shared by the three static checkers.

Every checker (``plan_check``, ``program_audit``, ``concurrency_lint``)
reports ``Finding`` records instead of raising ad hoc, so the CLI, CI
gate, tests, and ``SRSession(strict=True)`` all consume one shape.

Severity contract:
  * ``error``   — a proven invariant violation; CI fails, strict sessions
    raise ``PlanVerificationError``.
  * ``warning`` — legal but suspicious (degenerate band fallback, budget
    overshoot on a backend without a hard VMEM wall, recompiles).
  * ``info``    — observations useful in reports (e.g. donation requested
    on a platform where XLA ignores it).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

__all__ = [
    "SEVERITIES",
    "Finding",
    "PlanVerificationError",
    "count_by_severity",
    "count_by_checker",
    "errors",
    "format_findings",
]

SEVERITIES: Tuple[str, ...] = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic from a static checker.

    ``checker`` names the pass (``plan`` | ``program`` | ``concurrency``),
    ``rule`` the specific invariant (e.g. ``band_coverage``,
    ``quant_in_hot_path``, ``await_under_lock``), ``where`` the subject
    (a plan repr, cache key, or ``file:line``).
    """

    checker: str
    rule: str
    severity: str
    message: str
    where: str = ""

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity {self.severity!r} not in {SEVERITIES}"
            )

    def format(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.severity.upper():7s} {self.checker}.{self.rule}{loc}: {self.message}"


class PlanVerificationError(ValueError):
    """Raised by strict-mode plan verification; carries the findings."""

    def __init__(self, findings: Sequence[Finding]):
        self.findings: List[Finding] = list(findings)
        super().__init__(
            "plan verification failed:\n"
            + "\n".join(f.format() for f in self.findings)
        )


def errors(findings: Iterable[Finding]) -> List[Finding]:
    return [f for f in findings if f.severity == "error"]


def count_by_severity(findings: Iterable[Finding]) -> Dict[str, int]:
    counts = {s: 0 for s in SEVERITIES}
    for f in findings:
        counts[f.severity] += 1
    return counts


def count_by_checker(findings: Iterable[Finding]) -> Dict[str, Dict[str, int]]:
    out: Dict[str, Dict[str, int]] = {}
    for f in findings:
        out.setdefault(f.checker, {s: 0 for s in SEVERITIES})[f.severity] += 1
    return out


def format_findings(findings: Sequence[Finding], *, header: str = "") -> str:
    lines = [header] if header else []
    if not findings:
        lines.append("  (clean)")
    lines.extend("  " + f.format() for f in findings)
    return "\n".join(lines)
