"""Representative repo-wide sweeps for the three static checkers.

This is what ``python -m repro.analysis`` and the benchmark's
``analysis`` section run:

* :func:`sweep_lint` — the concurrency lint over the engine's serving
  sources.
* :func:`sweep_plans` — static plan verification across the full
  backend x vertical-policy x precision grid at the paper's design
  point (ABPN, 360-row frames, 60-row bands) — no compilation.
* :func:`sweep_programs` — compile small representative sessions
  (tilted fp32/bf16/int8 + the reference oracle, ``autotune="off"`` so
  the tuning DB is never touched) and audit every cached executor's
  jaxpr/HLO.

:func:`analysis_report` bundles the outcome as per-checker severity
counts plus a ``clean`` verdict — the shape ``BENCH_engine.json``
records and ``check_bench_schema.py`` validates.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.analysis import concurrency_lint, plan_check, program_audit
from repro.analysis.findings import Finding, count_by_severity, errors

__all__ = [
    "sweep_lint",
    "sweep_plans",
    "sweep_programs",
    "analysis_report",
    "PLAN_SWEEP_SHAPE",
    "PROGRAM_SWEEP_SHAPE",
    "PROGRAM_SWEEP_CONFIGS",
]

# The paper's design point: 360-row frames in 60-row bands.
PLAN_SWEEP_SHAPE: Tuple[int, int, int] = (360, 640, 3)

# Small enough to compile everywhere in seconds, banded (24 = 2 bands of
# 12 after derive_band_rows picks 24... a single 24-row band) — the
# audit rules are shape-independent.
PROGRAM_SWEEP_SHAPE: Tuple[int, int, int] = (24, 16, 3)

# (backend, precision) grid the program sweep compiles.  The kernel
# backend is exercised by the parity/bench suites; compiling its
# interpret-mode Pallas program here would dominate CI time without
# adding audit coverage (its jaxpr is a single pallas_call).
PROGRAM_SWEEP_CONFIGS: Tuple[Tuple[str, str], ...] = (
    ("tilted", "fp32"),
    ("tilted", "bf16"),
    ("tilted", "int8"),
    ("reference", "fp32"),
)


def sweep_lint() -> List[Finding]:
    """Concurrency-lint the engine serving sources."""
    return concurrency_lint.lint_files()


def sweep_plans(lr_shape: Tuple[int, int, int] = PLAN_SWEEP_SHAPE) -> List[Finding]:
    """Statically verify the full legal plan grid at the design point."""
    from repro.engine.plan import (
        BACKENDS,
        PRECISIONS,
        VERTICAL_POLICIES,
        SRPlan,
    )

    findings: List[Finding] = []
    for backend in BACKENDS:
        for policy in VERTICAL_POLICIES:
            for precision in PRECISIONS:
                plan = SRPlan.from_request(
                    lr_shape,
                    num_layers=7,
                    backend=backend,
                    vertical_policy=policy,
                    precision=precision,
                )
                findings.extend(plan_check.verify_plan(plan))
    return findings


def sweep_programs(
    lr_shape: Tuple[int, int, int] = PROGRAM_SWEEP_SHAPE,
    configs: Tuple[Tuple[str, str], ...] = PROGRAM_SWEEP_CONFIGS,
) -> List[Finding]:
    """Compile representative sessions and audit every cached executor."""
    import numpy as np

    from repro.engine.session import SRSession

    findings: List[Finding] = []
    frame = np.zeros(lr_shape, np.float32)
    for backend, precision in configs:
        session = SRSession.open(
            "abpn_x3",
            backend=backend,
            precision=precision,
            autotune="off",
            cache_capacity=4,
        )
        session.upscale(frame)  # populate the cache: one real compile
        findings.extend(program_audit.audit_session(session))
    return findings


def analysis_report(*, programs: bool = True) -> Dict:
    """Run every sweep; per-checker severity counts + a ``clean`` verdict
    (no error-level findings anywhere)."""
    by_checker = {
        "concurrency": sweep_lint(),
        "plan": sweep_plans(),
        "program": sweep_programs() if programs else [],
    }
    all_findings = [f for fs in by_checker.values() for f in fs]
    return {
        "concurrency": count_by_severity(by_checker["concurrency"]),
        "plan": count_by_severity(by_checker["plan"]),
        "program": count_by_severity(by_checker["program"]),
        "clean": not errors(all_findings),
    }
