"""Concurrency lint: the PR 5 review's hand-caught bug class, mechanized.

An AST pass over the engine's serving sources (``server.py``,
``scheduler.py``, ``session.py`` by default) that flags:

* ``blocking_under_lock`` — a blocking call (``jax.block_until_ready``,
  ``.result()``, ``np.asarray`` on device data, ``time.sleep``,
  ``.join()``, a nested ``.acquire()``) made while a lock is lexically
  held.  Device waits under the server lock serialize EVERY submitter on
  one dispatch — exactly the bug PR 5's review caught by hand.
  Condition-variable methods (``wait``/``wait_for``/``notify``/
  ``notify_all``) are safe-listed: a CV wait *releases* the lock, and
  that is the sanctioned blocking-under-lock pattern.
* ``await_under_lock`` — ``await`` inside a ``with <lock>:`` body of an
  ``async def``: the coroutine suspends while holding a thread lock any
  other task may need, a classic event-loop deadlock.
* ``blocking_in_async`` — a blocking call made directly inside an
  ``async def`` (not wrapped in ``asyncio.to_thread``): it stalls the
  whole event loop, not just this request.
* ``lock_order_cycle`` — lock-acquisition-order extraction: every
  ``with A: ... with B:`` nesting contributes an A->B edge; a cycle in
  the resulting graph means two code paths can acquire the same pair of
  locks in opposite orders (deadlock-capable).
* ``wall_clock`` — a ``time.time()`` call anywhere in a serving source:
  deadline and latency arithmetic must use ``time.monotonic()`` /
  ``time.perf_counter()``.  Wall clocks jump (NTP slew, manual resets),
  and a backwards jump turns every queued deadline into "already
  expired" — the deadline/shed paths this gate grew to cover are exactly
  where that failure is silent and catastrophic.

The pass is LEXICAL: it sees lock scopes and calls within one function
body, not across call boundaries or aliasing — by design.  It is a
cheap, zero-false-negative-within-scope gate, not an alias analysis;
cross-function patterns (the server's off-lock ``block_until_ready``
discipline, for instance) are enforced by the runtime tests.

Lock-like names are recognized by their terminal identifier segment
(``lock``/``mutex``/``cv``/``cond``/``sem``/``semaphore``), so
``self._lock``, ``self._cv`` and ``queue_cond`` all count.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding

__all__ = [
    "lint_source",
    "lint_files",
    "default_lint_targets",
    "BLOCKING_CALLS",
    "SAFE_UNDER_LOCK",
    "WALL_CLOCK_CALLS",
    "LOCK_NAME_RE",
]

# Terminal attribute/function names whose call blocks the calling thread.
BLOCKING_CALLS = frozenset({
    "block_until_ready",
    "result",
    "asarray",
    "device_get",
    "sleep",
    "join",
    "acquire",
})

# Condition-variable methods that are the SANCTIONED way to block under a
# lock (wait releases it; notify is non-blocking bookkeeping).
SAFE_UNDER_LOCK = frozenset({"wait", "wait_for", "notify", "notify_all"})

# Terminal names whose call reads the WALL clock — banned outright in
# serving sources (deadline/latency math must survive NTP jumps).  The
# monotonic family (monotonic, perf_counter) is the sanctioned clock.
WALL_CLOCK_CALLS = frozenset({"time"})

LOCK_NAME_RE = re.compile(
    r"(^|_)(lock|mutex|cv|cond|sem|semaphore)s?($|_)", re.IGNORECASE
)


def _terminal_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _lock_id(expr: ast.AST) -> Optional[str]:
    """The lock a with-item acquires, as its source text — or None if the
    expression does not look lock-like."""
    name = _terminal_name(expr)
    if name is not None and LOCK_NAME_RE.search(name):
        try:
            return ast.unparse(expr)
        except Exception:
            return name
    return None


def _call_name(call: ast.Call) -> Optional[str]:
    return _terminal_name(call.func)


class _FunctionLinter(ast.NodeVisitor):
    """Walk ONE function body tracking the lexically-held lock stack."""

    def __init__(self, filename: str, func_name: str, is_async: bool,
                 findings: List[Finding],
                 lock_edges: Set[Tuple[str, str]]):
        self.filename = filename
        self.func_name = func_name
        self.is_async = is_async
        self.findings = findings
        self.lock_edges = lock_edges
        self.held: List[str] = []

    def _where(self, node: ast.AST) -> str:
        return f"{self.filename}:{node.lineno} in {self.func_name}"

    # --- lock scopes ---------------------------------------------------
    def _visit_with(self, node) -> None:
        acquired: List[str] = []
        for item in node.items:
            lock = _lock_id(item.context_expr)
            if lock is not None:
                for outer in self.held:
                    if outer != lock:
                        self.lock_edges.add((outer, lock))
                self.held.append(lock)
                acquired.append(lock)
        for stmt in node.body:
            self.visit(stmt)
        for _ in acquired:
            self.held.pop()
        # with-item expressions themselves may contain calls to inspect
        for item in node.items:
            self.visit(item.context_expr)

    def visit_With(self, node: ast.With) -> None:
        self._visit_with(node)

    def visit_AsyncWith(self, node: ast.AsyncWith) -> None:
        self._visit_with(node)

    # --- blocking constructs -------------------------------------------
    def visit_Await(self, node: ast.Await) -> None:
        if self.held:
            self.findings.append(Finding(
                checker="concurrency",
                rule="await_under_lock",
                severity="error",
                message=(
                    f"await while holding {self.held[-1]!r} — the "
                    "coroutine suspends with the lock held; any other "
                    "task needing it deadlocks the event loop"
                ),
                where=self._where(node),
            ))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        name = _call_name(node)
        if name in WALL_CLOCK_CALLS:
            self.findings.append(Finding(
                checker="concurrency",
                rule="wall_clock",
                severity="error",
                message=(
                    f"wall-clock call {name}() in serving code — deadline "
                    "and latency math must use time.monotonic() or "
                    "time.perf_counter(); an NTP jump would expire (or "
                    "immortalize) every queued deadline at once"
                ),
                where=self._where(node),
            ))
        if name in SAFE_UNDER_LOCK:
            pass  # CV wait/notify: the sanctioned pattern
        elif name in BLOCKING_CALLS:
            if self.held:
                self.findings.append(Finding(
                    checker="concurrency",
                    rule="blocking_under_lock",
                    severity="error",
                    message=(
                        f"blocking call {name}() while holding "
                        f"{self.held[-1]!r} — every other thread "
                        "contending for the lock stalls on this wait"
                    ),
                    where=self._where(node),
                ))
            elif self.is_async:
                self.findings.append(Finding(
                    checker="concurrency",
                    rule="blocking_in_async",
                    severity="error",
                    message=(
                        f"blocking call {name}() directly inside an async "
                        "function stalls the whole event loop — wrap it "
                        "in asyncio.to_thread"
                    ),
                    where=self._where(node),
                ))
        self.generic_visit(node)

    # Nested defs get their own linter (their body runs later, under
    # whatever locks hold at CALL time, which this lexical pass cannot
    # know — so they are linted lock-free from scratch).
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        _lint_function(node, self.filename, self.findings, self.lock_edges)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        _lint_function(node, self.filename, self.findings, self.lock_edges)


def _lint_function(node, filename: str, findings: List[Finding],
                   lock_edges: Set[Tuple[str, str]]) -> None:
    linter = _FunctionLinter(
        filename, node.name,
        isinstance(node, ast.AsyncFunctionDef),
        findings, lock_edges,
    )
    for stmt in node.body:
        linter.visit(stmt)


def _find_cycle(edges: Set[Tuple[str, str]]) -> Optional[List[str]]:
    """First lock-order cycle found by DFS, as the lock path, or None."""
    graph: Dict[str, List[str]] = {}
    for a, b in sorted(edges):
        graph.setdefault(a, []).append(b)
    done: Set[str] = set()

    def dfs(n: str, path: List[str]) -> Optional[List[str]]:
        if n in path:
            return path[path.index(n):] + [n]
        if n in done:
            return None
        path.append(n)
        for m in graph.get(n, ()):
            cyc = dfs(m, path)
            if cyc is not None:
                return cyc
        path.pop()
        done.add(n)
        return None

    for start in list(graph):
        cyc = dfs(start, [])
        if cyc is not None:
            return cyc
    return None


def lint_source(source: str, filename: str = "<string>") -> List[Finding]:
    """Lint one module's source text; returns all findings."""
    findings: List[Finding] = []
    lock_edges: Set[Tuple[str, str]] = set()
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError as exc:
        return [Finding(
            checker="concurrency",
            rule="unparseable",
            severity="error",
            message=f"cannot parse: {exc}",
            where=filename,
        )]
    # traverse module and class bodies only, so each function is linted
    # exactly once by _lint_function (nested defs recurse inside it)
    def visit_body(body) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _lint_function(stmt, filename, findings, lock_edges)
            elif isinstance(stmt, ast.ClassDef):
                visit_body(stmt.body)

    visit_body(tree.body)
    cycle = _find_cycle(lock_edges)
    if cycle is not None:
        findings.append(Finding(
            checker="concurrency",
            rule="lock_order_cycle",
            severity="error",
            message=(
                "inconsistent lock acquisition order — two paths can "
                "acquire these locks in opposite orders (deadlock): "
                + " -> ".join(cycle)
            ),
            where=filename,
        ))
    return findings


def default_lint_targets(root: Optional[str] = None) -> List[Path]:
    """The engine's serving-loop sources — the files where a blocking
    call under a lock stalls live traffic.  ``runtime/resilience.py``
    joined the set when the server grew deadline/degrade/injection paths
    through it (its EMA core and FailureInjector run inside the serving
    loop).  The ``engine/temporal`` sources joined with delta serving:
    the output cache takes a lock on the splice path and DeltaSession
    runs inside ``stream()``'s worker threads — the wall-clock and lock
    rules apply to them from day one."""
    base = Path(root) if root else Path(__file__).resolve().parents[1]
    eng = base / "engine"
    return [
        eng / "server.py",
        eng / "scheduler.py",
        eng / "session.py",
        eng / "temporal" / "band_diff.py",
        eng / "temporal" / "delta_stream.py",
        eng / "temporal" / "output_cache.py",
        base / "runtime" / "resilience.py",
    ]


def lint_files(paths: Optional[Iterable] = None) -> List[Finding]:
    """Lint source files (default: the engine serving sources)."""
    findings: List[Finding] = []
    for p in (paths if paths is not None else default_lint_targets()):
        p = Path(p)
        findings.extend(lint_source(p.read_text(), filename=p.name))
    return findings
