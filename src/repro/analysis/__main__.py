"""``python -m repro.analysis`` — run the static checkers as a CI gate.

Selects checkers via ``--lint`` / ``--plans`` / ``--programs`` (or
``--all``, the default when no selector is given), prints every finding
grouped by checker, and exits nonzero when any ERROR-level finding
survives — warnings and infos are reported but do not fail the build.
"""

from __future__ import annotations

import argparse
import sys
from typing import List

from repro.analysis import sweep
from repro.analysis.findings import (
    Finding,
    count_by_severity,
    errors,
    format_findings,
)


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verification: plan geometry, compiled-program "
                    "audit, concurrency lint.",
    )
    ap.add_argument("--all", action="store_true",
                    help="run every checker (default when none selected)")
    ap.add_argument("--lint", action="store_true",
                    help="concurrency-lint the engine serving sources")
    ap.add_argument("--plans", action="store_true",
                    help="statically verify the design-point plan grid")
    ap.add_argument("--programs", action="store_true",
                    help="compile representative sessions and audit their "
                         "executors (the slow sweep)")
    args = ap.parse_args(argv)
    run_all = args.all or not (args.lint or args.plans or args.programs)

    findings: List[Finding] = []
    if run_all or args.lint:
        got = sweep.sweep_lint()
        print(format_findings(got, header="concurrency lint (engine sources):"))
        findings.extend(got)
    if run_all or args.plans:
        got = sweep.sweep_plans()
        print(format_findings(
            got, header="plan verification (design-point grid):"
        ))
        findings.extend(got)
    if run_all or args.programs:
        got = sweep.sweep_programs()
        print(format_findings(
            got, header="program audit (representative sessions):"
        ))
        findings.extend(got)

    counts = count_by_severity(findings)
    errs = errors(findings)
    print(
        f"\n{counts['error']} error(s), {counts['warning']} warning(s), "
        f"{counts['info']} info — {'FAIL' if errs else 'OK'}"
    )
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
