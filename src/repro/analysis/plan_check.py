"""Static plan verification: prove an :class:`~repro.engine.plan.SRPlan`'s
geometry before anything compiles.

Three invariant families, each reported as :class:`Finding`s:

* **Band coverage** — the bands partition the frame height exactly
  (``num_bands * band_rows == height``); a gap or overlap would corrupt
  the output silently.
* **Halo sufficiency** — for the ``halo`` vertical policy, the slab
  margin provided by ``core.fusion.halo_slabs`` must cover the
  receptive-field growth of the fused stack: L stacked 3x3 convs grow
  the field by exactly one row per side per layer, so the margin must be
  ``>= num_layers``.  The provided margin is *measured* from the
  ``halo_slabs`` geometry itself (slab height minus band height over
  two), not restated here, so the checker can never drift from the code.
* **On-chip budget** — the Pallas kernel's REAL per-step buffer
  allocation (``kernels.tilted_fusion.kernel_buffers``: overlap queue,
  residual ring, streamed blocks, resident weights, padded channels) is
  held against the paper's Table II budget
  (``core.analysis.on_chip_budget_kb``, 102.36 KB at the design point).
  The logical (unpadded) element counts must match the analytical model
  exactly; the padded total may exceed the budget by at most
  :data:`BUDGET_TOLERANCE` — the documented headroom for TPU
  sublane/lane padding (``chp/chmax = 32/28``, ``c0p/ch0 = 8/3``) plus
  the streamed input/output blocks Table II accounts under the ping-pong
  row.

``verify_plan`` accepts any *plan-like* object (the ``SRPlan`` field
names, duck-typed) so tests can probe deliberately-illegal geometry that
``SRPlan.__post_init__`` would reject at construction.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from repro.analysis.findings import Finding
from repro.core import analysis as core_analysis
from repro.core.fusion import halo_slabs

__all__ = [
    "verify_plan",
    "verify_delta_cover",
    "table2_crosscheck",
    "measured_halo_margin",
    "required_halo_margin",
    "plan_buffer_report",
    "BUDGET_TOLERANCE",
    "TABLE2_TOTAL_KB",
    "BANDED_BACKENDS",
]

# Table II bottom line (decimal KB) — the ASIC's fixed on-chip allocation.
TABLE2_TOTAL_KB = core_analysis.PAPER_TABLE2["tilted"]["total"]

# Documented budget headroom: the kernel pads channels to the TPU sublane
# multiple (28 -> 32 feature channels, 3 -> 8 image channels) and streams
# one input + one output block per grid step where Table II counts a
# shared ping-pong pair.  At the paper's design point the padded total is
# ~1.16x the 102.36 KB budget; 1.30x is the alarm line.
BUDGET_TOLERANCE = 0.30

BANDED_BACKENDS = ("tilted", "kernel")

# Table II counts one byte per element (the int8 ASIC convention).
_PAPER_BYTES_PER_ELEM = 1


def required_halo_margin(num_layers: int) -> int:
    """Receptive-field growth of ``num_layers`` stacked 3x3 convs: one row
    per side per layer."""
    return int(num_layers)


def measured_halo_margin(band_rows: int, num_layers: int) -> int:
    """The halo margin ``core.fusion.halo_slabs`` ACTUALLY provides,
    measured from the geometry it returns for a one-band probe frame."""
    import jax.numpy as jnp  # deferred: keep plan checks importable sans device

    probe = jnp.zeros((1, int(band_rows), 1, 1), jnp.float32)
    slabs, _bounds = halo_slabs(probe, int(band_rows), int(num_layers))
    slab_rows = int(slabs.shape[1])
    return (slab_rows - int(band_rows)) // 2


def _default_channels(plan) -> List[int]:
    """Feature-map channels F_0..F_L for the budget check.  ABPN's stack
    when the plan matches the paper's geometry; otherwise a conservative
    estimate (hidden width = the pixel-shuffle output width)."""
    abpn = core_analysis.ABPN_CHANNELS
    if plan.num_layers == len(abpn) - 1 and plan.in_channels == abpn[0]:
        return list(abpn)
    hidden = max(plan.in_channels * plan.scale * plan.scale, plan.in_channels)
    return [plan.in_channels] + [hidden] * plan.num_layers


def plan_buffer_report(plan, channels: Optional[Sequence[int]] = None) -> dict:
    """The kernel's buffer introspection for this plan's geometry
    (``kernels.tilted_fusion.kernel_buffers``)."""
    from repro.kernels.tilted_fusion import kernel_buffers  # deferred: no jax import cost

    return kernel_buffers(
        channels=list(channels) if channels else _default_channels(plan),
        band_rows=plan.band_rows,
        tile_cols=plan.tile_cols,
    )


def _check_band_coverage(plan, findings: List[Finding], where: str) -> None:
    if plan.backend == "reference":
        return  # full-image path: no bands to cover
    bands, rem = divmod(plan.height, plan.band_rows)
    if rem != 0 or bands < 1:
        findings.append(Finding(
            checker="plan",
            rule="band_coverage",
            severity="error",
            message=(
                f"{bands} bands of {plan.band_rows} rows cover "
                f"{bands * plan.band_rows} of {plan.height} frame rows — "
                f"{rem} rows would be dropped; bands must partition the "
                "height exactly"
            ),
            where=where,
        ))
    if getattr(plan, "degenerate_bands", False):
        findings.append(Finding(
            checker="plan",
            rule="degenerate_bands",
            severity="warning",
            message=(
                f"height {plan.height} had no legal band decomposition and "
                f"fell back to ONE {plan.band_rows}-row band — banded "
                "backends lose streaming locality at this height"
            ),
            where=where,
        ))


def _check_halo(plan, findings: List[Finding], where: str,
                halo_margin: Optional[int]) -> None:
    if plan.vertical_policy != "halo" or plan.backend == "reference":
        return
    need = required_halo_margin(plan.num_layers)
    have = (int(halo_margin) if halo_margin is not None
            else measured_halo_margin(plan.band_rows, plan.num_layers))
    if have < need:
        findings.append(Finding(
            checker="plan",
            rule="halo_sufficiency",
            severity="error",
            message=(
                f"halo slab provides {have} margin rows per side but "
                f"{plan.num_layers} stacked 3x3 layers grow the receptive "
                f"field by {need} rows per side — band boundaries would "
                "read stale/phantom rows"
            ),
            where=where,
        ))


def _check_shards(plan, findings: List[Finding], where: str,
                  band_shards: Optional[int],
                  shard_halo_margin: Optional[int]) -> None:
    """Band-sharded serving (``engine.sharding``) invariants.

    A shard boundary is a band boundary that additionally crosses devices:
    the bands must split into equal per-device blocks, and under the
    ``halo`` policy the exchanged shard-edge margin must still cover the
    stack's receptive-field growth (L rows per side) — a short exchange
    would read stale rows from the neighbour shard, silently, because the
    in-shard bands still validate.
    """
    if not band_shards or int(band_shards) <= 1:
        return
    band_shards = int(band_shards)
    if plan.backend == "reference":
        findings.append(Finding(
            checker="plan",
            rule="shard_backend",
            severity="error",
            message=(
                "reference backend computes over the full frame and "
                f"cannot band-shard {band_shards} ways — use the tilted "
                "or kernel backend"
            ),
            where=where,
        ))
        return
    bands, rem = divmod(plan.height, plan.band_rows)
    if rem != 0:
        return  # band_coverage already reported the broken geometry
    if bands % band_shards != 0:
        findings.append(Finding(
            checker="plan",
            rule="shard_band_alignment",
            severity="error",
            message=(
                f"{bands} bands do not split into {band_shards} equal "
                "shards — each device must own whole bands "
                f"(height {plan.height}, band_rows {plan.band_rows})"
            ),
            where=where,
        ))
        return
    if plan.vertical_policy != "halo":
        return  # zero/replicate bands are independent: no shard coupling
    need = required_halo_margin(plan.num_layers)
    have = (int(shard_halo_margin) if shard_halo_margin is not None
            else measured_halo_margin(plan.band_rows, plan.num_layers))
    if have < need:
        findings.append(Finding(
            checker="plan",
            rule="shard_halo_sufficiency",
            severity="error",
            message=(
                f"shard edges exchange {have} margin rows per side but "
                f"{plan.num_layers} stacked 3x3 layers need {need} — "
                "bands at device boundaries would read stale neighbour "
                "rows"
            ),
            where=where,
        ))


def _check_schedule(plan, findings: List[Finding], where: str) -> None:
    try:
        plan.check_invariants()
    except Exception as exc:  # surfaced as a finding, not a crash
        findings.append(Finding(
            checker="plan",
            rule="tile_handoff",
            severity="error",
            message=f"tilted schedule invariants failed: {exc}",
            where=where,
        ))


def _check_budget(plan, findings: List[Finding], where: str,
                  channels: Optional[Sequence[int]],
                  budget_kb: Optional[float]) -> None:
    if plan.backend not in BANDED_BACKENDS:
        return
    budget = (float(budget_kb) if budget_kb is not None
              else core_analysis.on_chip_budget_kb())
    report = plan_buffer_report(plan, channels)
    padded_kb = (
        report["total_elements"] * _PAPER_BYTES_PER_ELEM
        + report["row_bounds_smem_bytes"]
    ) / 1000.0
    limit = budget * (1.0 + BUDGET_TOLERANCE)
    if padded_kb > limit:
        # A hard wall only where the allocation is literally VMEM scratch
        # (the Pallas kernel); the pure-JAX tilted path has no fixed
        # on-chip buffer, so overshooting the paper budget is advisory.
        severity = "error" if plan.backend == "kernel" else "warning"
        findings.append(Finding(
            checker="plan",
            rule="on_chip_budget",
            severity=severity,
            message=(
                f"kernel buffers need {padded_kb:.2f} KB at "
                f"band_rows={plan.band_rows} — over the {budget:.2f} KB "
                f"Table II budget by more than the documented "
                f"{BUDGET_TOLERANCE:.0%} padding tolerance "
                f"(limit {limit:.2f} KB)"
            ),
            where=where,
        ))


def verify_plan(
    plan,
    *,
    channels: Optional[Sequence[int]] = None,
    budget_kb: Optional[float] = None,
    halo_margin: Optional[int] = None,
    band_shards: Optional[int] = None,
    shard_halo_margin: Optional[int] = None,
) -> List[Finding]:
    """Statically verify a plan-like object; returns all findings (possibly
    empty).  ``channels`` supplies the model's real feature-map widths for
    the budget check (defaults to ABPN when the geometry matches);
    ``budget_kb`` and ``halo_margin`` override the Table II budget and the
    measured slab margin — test hooks for probing illegal geometry.
    ``band_shards`` (> 1) additionally verifies band-sharded serving:
    shard alignment and shard-edge halo sufficiency
    (``shard_halo_margin`` overrides the exchanged margin the same way
    ``halo_margin`` does in-shard).
    """
    findings: List[Finding] = []
    where = (
        f"plan {plan.backend}/{plan.precision} "
        f"{plan.height}x{plan.width} R={plan.band_rows} C={plan.tile_cols} "
        f"{plan.vertical_policy}"
    )
    if band_shards and int(band_shards) > 1:
        where += f" shards={int(band_shards)}"
    _check_band_coverage(plan, findings, where)
    _check_halo(plan, findings, where, halo_margin)
    _check_shards(plan, findings, where, band_shards, shard_halo_margin)
    _check_schedule(plan, findings, where)
    _check_budget(plan, findings, where, channels, budget_kb)
    return findings


def table2_crosscheck(
    channels: Optional[Sequence[int]] = None,
    band_rows: int = 60,
    tile_cols: int = 8,
) -> dict:
    """Cross-check the Pallas kernel's buffer accounting against the
    analytical Table II model (``core.analysis.buffer_sizes``).

    Returns, in decimal KB at the paper's 1-byte-per-element convention:

    * ``kernel_*_kb``  — the kernel's *logical* (unpadded) element counts
      for the overlap queue, residual ring and weights+bias.  These must
      equal the analytical model EXACTLY (``model_*_kb``): same eqs.,
      independently coded.  (The kernel keeps L overlap slots — one per
      fused layer — vs the RTL's L+2, so the model is evaluated at
      ``overlap_queue_slots=L``.)
    * ``kernel_padded_total_kb`` — what the kernel launch REALLY
      allocates (sublane/lane-padded channels, streamed blocks, SMEM row
      bounds); ``budget_ratio`` = padded total / Table II total, bounded
      by ``1 + BUDGET_TOLERANCE`` at the design point.
    """
    from repro.kernels.tilted_fusion import kernel_buffers

    channels = list(channels) if channels else list(core_analysis.ABPN_CHANNELS)
    L = len(channels) - 1
    report = kernel_buffers(
        channels=channels, band_rows=band_rows, tile_cols=tile_cols
    )
    cfg = core_analysis.HWConfig(
        band_rows=band_rows,
        tile_cols=tile_cols,
        channels=tuple(channels),
        bytes_per_elem=_PAPER_BYTES_PER_ELEM,
        overlap_queue_slots=L,
    )
    model = core_analysis.buffer_sizes(cfg)
    buf = report["buffers"]
    kernel_weight = (
        buf["weights"]["logical_elements"] + buf["bias"]["logical_elements"]
    )
    padded_total_kb = (
        report["total_elements"] * _PAPER_BYTES_PER_ELEM
        + report["row_bounds_smem_bytes"]
    ) / 1000.0
    return {
        "kernel_overlap_kb": buf["overlap"]["logical_elements"] / 1000.0,
        "model_overlap_kb": model["overlap_kb"],
        "kernel_residual_kb": buf["residual"]["logical_elements"] / 1000.0,
        "model_residual_kb": model["residual_kb"],
        "kernel_weight_kb": kernel_weight / 1000.0,
        "model_weight_kb": model["weight_kb"],
        "kernel_padded_total_kb": padded_total_kb,
        "table2_total_kb": TABLE2_TOTAL_KB,
        "budget_ratio": padded_total_kb / TABLE2_TOTAL_KB,
        "tolerance": BUDGET_TOLERANCE,
    }


def verify_delta_cover(plan, dirty_bands, changed_bands=None) -> List[Finding]:
    """Verify a temporal delta step's splice invariant for ``plan``.

    The delta path serves ``dirty_bands`` fresh and splices every other
    band from the output cache; the HR frame is correct iff the two sets
    partition the output rows AND the dirty set is at least the
    halo-reach dilation of the bands whose content actually changed.
    Error-level rules:

    * ``delta_cover`` — every dirty index in range, no duplicates, and
      dirty + spliced bands account for every output row exactly once
      (with bands partitioning the height this is the row-count
      identity; a non-partitioning plan already fails
      ``band_coverage``).
    * ``delta_dilation`` — for each changed band, every band within the
      halo reach (``ceil(L / R)`` under ``halo``, 0 otherwise — the
      ``core.fusion.halo_slabs`` receptive-field geometry) is dirty.
      A clean band inside the reach would splice stale rows: its cached
      output depends on rows that just changed.

    ``changed_bands=None`` skips the dilation rule (callers that only
    know the final dirty set).  Returns findings; empty = valid.
    """
    # deferred: analysis must stay importable without the engine package
    from repro.engine.temporal.band_diff import halo_reach

    findings: List[Finding] = []
    where = (
        f"delta {plan.backend}/{plan.vertical_policy} "
        f"{plan.height}x{plan.width} R={plan.band_rows}"
    )
    num_bands = plan.height // plan.band_rows
    dirty = [int(b) for b in dirty_bands]
    bad = [b for b in dirty if not 0 <= b < num_bands]
    dirty_set = set(dirty)
    if bad or len(dirty_set) != len(dirty):
        findings.append(Finding(
            checker="plan",
            rule="delta_cover",
            severity="error",
            message=(
                f"dirty band set {sorted(dirty)} is not a valid subset of "
                f"[0, {num_bands}): out-of-range {sorted(set(bad))}, "
                f"{len(dirty) - len(dirty_set)} duplicate(s)"
            ),
            where=where,
        ))
        return findings
    spliced = num_bands - len(dirty_set)
    covered_rows = (len(dirty_set) + spliced) * plan.band_rows
    if covered_rows != plan.height:
        findings.append(Finding(
            checker="plan",
            rule="delta_cover",
            severity="error",
            message=(
                f"{len(dirty_set)} dirty + {spliced} spliced bands of "
                f"{plan.band_rows} rows cover {covered_rows} of "
                f"{plan.height} output rows — the splice would drop or "
                "double-write rows"
            ),
            where=where,
        ))
    if changed_bands is not None:
        reach = halo_reach(
            plan.band_rows, plan.num_layers, plan.vertical_policy
        )
        missing = set()
        for c in changed_bands:
            c = int(c)
            if c not in dirty_set:
                missing.add(c)
            lo = max(0, c - reach)
            hi = min(num_bands, c + reach + 1)
            missing.update(b for b in range(lo, hi) if b not in dirty_set)
        if missing:
            findings.append(Finding(
                checker="plan",
                rule="delta_dilation",
                severity="error",
                message=(
                    f"changed bands {sorted(int(c) for c in changed_bands)} "
                    f"require dirty coverage within halo reach {reach}, but "
                    f"bands {sorted(missing)} are not dirty — their cached "
                    "output depends on rows that changed"
                ),
                where=where,
            ))
    return findings
