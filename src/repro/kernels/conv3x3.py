"""Pallas TPU kernel: single-layer vectorwise 3x3 convolution.

The standalone analogue of one PE-block pass (paper §III-B/D): an input
*column slab* is broadcast against the three weight columns and accumulated
along the diagonal — on the MXU this is three shifted matmuls
``(R*C, 3*Ci) @ (3*Ci, Co)`` (rows im2col'd), one per weight column, or
equivalently the 9-tap accumulation used here for symmetry with the fused
kernel.

Grid: one step per C-column output tile.  The input stays unblocked in VMEM
(whole band) because a single layer has no overlap state to carry — this
kernel exists as the layer-by-layer *baseline* datapath (the [11]/[12]
execution style the paper compares against) and as a unit-testable slice of
the fused kernel's math.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["conv3x3_call"]


def _kernel(x_ref, w_ref, b_ref, o_ref, *, tile_cols, band_rows, relu, acc_dtype):
    C, R = tile_cols, band_rows
    k = pl.program_id(0)
    ci = x_ref.shape[-1]
    co = o_ref.shape[-1]
    # slab: rows already carry the +-1 zero-pad halo; columns sliced with halo
    slab = x_ref[:, pl.dslice(k * C, C + 2), :].astype(acc_dtype)  # (R+2, C+2, Ci)
    acc = jnp.zeros((R * C, co), acc_dtype)
    for dy in range(3):
        for dx in range(3):
            patch = jax.lax.dynamic_slice(slab, (dy, dx, 0), (R, C, ci))
            acc = acc + jax.lax.dot(
                patch.reshape(R * C, ci),
                w_ref[dy, dx].astype(acc_dtype),
                preferred_element_type=acc_dtype,
            )
    out = acc.reshape(R, C, co) + b_ref[...].astype(acc_dtype)[None, None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


def conv3x3_call(
    x: jax.Array,  # (R, W, Ci)
    w: jax.Array,  # (3, 3, Ci, Co)
    b: jax.Array,  # (Co,)
    *,
    tile_cols: int = 8,
    relu: bool = True,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """SAME-padded 3x3 conv over a band, tiled along columns."""
    R, W, Ci = x.shape
    Co = w.shape[-1]
    C = tile_cols
    K = -(-W // C)  # ceil
    # zero SAME padding: +-1 rows, left 1 col, right up to the tile grid
    xp = jnp.pad(x, ((1, 1), (1, K * C + 1 - W), (0, 0)))
    out = pl.pallas_call(
        functools.partial(
            _kernel, tile_cols=C, band_rows=R, relu=relu, acc_dtype=acc_dtype
        ),
        grid=(K,),
        in_specs=[
            pl.BlockSpec((R + 2, K * C + 2, Ci), lambda k: (0, 0, 0)),
            pl.BlockSpec((3, 3, Ci, Co), lambda k: (0, 0, 0, 0)),
            pl.BlockSpec((Co,), lambda k: (0,)),
        ],
        out_specs=pl.BlockSpec((R, C, Co), lambda k: (0, k, 0)),
        out_shape=jax.ShapeDtypeStruct((R, K * C, Co), x.dtype),
        interpret=interpret,
    )(xp, w, b)
    return out[:, :W, :]
