"""Pallas TPU kernels for the paper's compute hot-spots.

kernels/
  tilted_fusion.py — the paper's contribution: fused L-layer conv stack,
                     overlap queue in persistent VMEM scratch
  conv3x3.py       — single-layer vectorwise conv (layerwise baseline)
  ops.py           — jit'd public wrappers (channel padding, stream layout)
  ref.py           — pure-jnp oracles

All kernels are written against real TPU semantics (pl.pallas_call +
BlockSpec VMEM tiling, MXU matmuls, sequential-grid scratch carry) and
validated on CPU with ``interpret=True``.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
