"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each ``*_ref`` matches the public signature of its ``ops`` counterpart and
is implemented with nothing but ``jax.lax``/``jnp`` primitives on the full
arrays — no tiling, no scratch, no streaming — so any disagreement points at
the kernel's dataflow, not at the math.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.fusion import ConvLayer, conv_stack_reference

__all__ = ["conv3x3_ref", "tilted_fused_stack_ref"]


def conv3x3_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, relu: bool = True
) -> jax.Array:
    """SAME-padded 3x3 conv over a (R, W, Ci) band."""
    out = jax.lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0] + b
    return jax.nn.relu(out) if relu else out


def tilted_fused_stack_ref(
    x: jax.Array,
    layers: Sequence[ConvLayer],
    *,
    band_rows: int = 60,
    add_anchor: bool = False,
    anchor_repeats: int = 9,
) -> jax.Array:
    """Oracle for the fused kernel: per-band SAME conv stack (+ anchor).

    Bands are convolved independently with zero padding at band edges —
    the paper's vertical block-conv policy — matching the kernel's grid
    semantics exactly (the kernel is bit-exact horizontally).
    """
    H, W, _ = x.shape
    R = band_rows
    outs = []
    for r0 in range(0, H, R):
        band = x[r0 : r0 + R]
        out = conv_stack_reference(band, layers)
        if add_anchor:
            out = out + jnp.pad(
                jnp.repeat(band, anchor_repeats, axis=-1),
                ((0, 0), (0, 0), (0, out.shape[-1] - band.shape[-1] * anchor_repeats)),
            )
        outs.append(out)
    return jnp.concatenate(outs, axis=0)
