"""Public jit'd wrappers around the Pallas kernels.

These handle the host-side data marshalling that the accelerator's DMA
engine performs in the paper: channel padding to TPU-friendly widths,
building the fresh-column stream, and undoing the output tilt.

``interpret`` defaults to True on CPU backends (kernel body executed in
Python for validation) and False on TPU (compiled to Mosaic).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.fusion import ConvLayer
from repro.core.tiling import make_schedule
from repro.kernels import conv3x3 as _conv3x3
from repro.kernels import tilted_fusion as _tilted

__all__ = ["conv3x3", "tilted_fused_stack", "pack_layers", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() == "cpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pack_layers(layers: Sequence[ConvLayer], chp: Optional[int] = None, dtype=None):
    """Zero-pad a heterogeneous conv stack to uniform (L,3,3,Chp,Chp) + (L,Chp).

    Padded input/output channels carry zero weights and biases, so they stay
    identically zero through every ReLU layer — the kernel never masks
    channels. ``chp`` defaults to max(Ch) rounded up to 8 (sublane); pass 128
    for full MXU lane alignment (§Perf studies both).
    """
    chmax = max([layers[0].ci] + [l.co for l in layers])
    chp = chp or _round_up(chmax, 8)
    if chp < chmax:
        raise ValueError(f"chp={chp} < max channels {chmax}")
    dtype = dtype or layers[0].w.dtype
    L = len(layers)
    w = jnp.zeros((L, 3, 3, chp, chp), dtype)
    b = jnp.zeros((L, chp), dtype)
    for i, l in enumerate(layers):
        w = w.at[i, :, :, : l.ci, : l.co].set(l.w.astype(dtype))
        b = b.at[i, : l.co].set(l.b.astype(dtype))
    return w, b, chp


def tilted_fused_stack(
    x: jax.Array,
    layers: Sequence[ConvLayer],
    *,
    band_rows: int = 60,
    tile_cols: int = 8,
    chp: Optional[int] = None,
    add_anchor: bool = False,
    anchor_repeats: int = 9,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Tilted layer fusion of a full (H, W, C0) image via the Pallas kernel.

    Returns (H, W, Ch_L) features (or anchored output when ``add_anchor``),
    numerically identical to ``ref.tilted_fused_stack_ref``.
    """
    H, W, C0 = x.shape
    R, C, L = band_rows, tile_cols, len(layers)
    if H % R != 0:
        raise ValueError(f"height {H} must be a multiple of band_rows {R}")
    B = H // R
    interpret = default_interpret() if interpret is None else interpret
    sched = make_schedule(width=W, tile_cols=C, num_layers=L)
    K = sched.num_tiles
    co_l = layers[-1].co

    w, b, chp = pack_layers(layers, chp)
    c0p = _round_up(C0, 8)

    # Band-major layout + channel padding.
    xb = x.reshape(B, R, W, C0)
    xb = jnp.pad(xb, ((0, 0), (0, 0), (0, 0), (0, c0p - C0)))
    # Fresh stream: tile k consumes input columns [k*C + 1, k*C + C].
    xs = jnp.pad(xb, ((0, 0), (0, 0), (0, K * C + 1 - W), (0, 0)))[:, :, 1 : K * C + 1, :]
    first_col = xb[:, :, 0:1, :]

    out = _tilted.tilted_fusion_call(
        xs,
        first_col,
        w,
        b,
        width=W,
        tile_cols=C,
        relu_flags=[l.relu for l in layers],
        add_anchor=add_anchor,
        in_channels=C0,
        anchor_repeats=anchor_repeats,
        interpret=interpret,
    )
    # Undo the tilt: tile k's block holds F_L columns [k*C - (L-1), ...+C).
    out = out.reshape(B * R, K * C, chp)
    out = jax.lax.slice(out, (0, L - 1, 0), (B * R, L - 1 + W, co_l))
    return out


def conv3x3(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    tile_cols: int = 8,
    relu: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Single-layer vectorwise 3x3 conv (the layerwise-baseline datapath)."""
    interpret = default_interpret() if interpret is None else interpret
    return _conv3x3.conv3x3_call(
        x, w, b, tile_cols=tile_cols, relu=relu, interpret=interpret
    )
