"""Public jit'd wrappers around the Pallas kernels.

These handle the host-side data marshalling that the accelerator's DMA
engine performs in the paper: channel padding to TPU-friendly widths,
building the fresh-column stream, and undoing the output tilt.

``interpret`` defaults to True on CPU backends (kernel body executed in
Python for validation) and False on TPU (compiled to Mosaic).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.fusion import ConvLayer, halo_slabs
from repro.core.tiling import make_schedule
from repro.kernels import conv3x3 as _conv3x3
from repro.kernels import tilted_fusion as _tilted

__all__ = [
    "conv3x3",
    "tilted_fused_stack",
    "tilted_fused_frames",
    "tilted_fused_band_stack",
    "pack_layers",
    "pack_stack",
    "PackedLayers",
    "default_interpret",
]

VERTICAL_POLICIES = ("zero", "halo", "replicate")


def default_interpret() -> bool:
    return jax.default_backend() == "cpu"


def _round_up(x: int, m: int) -> int:
    # Delegates to the kernel's canonical padding rule so ops-level packing
    # and the static analyser (repro.analysis.plan_check) count identically.
    return _tilted.round_up_channels(x, m)


def pack_layers(layers: Sequence[ConvLayer], chp: Optional[int] = None, dtype=None):
    """Zero-pad a heterogeneous conv stack to uniform (L,3,3,Chp,Chp) + (L,Chp).

    Padded input/output channels carry zero weights and biases, so they stay
    identically zero through every ReLU layer — the kernel never masks
    channels. ``chp`` defaults to max(Ch) rounded up to 8 (sublane); pass 128
    for full MXU lane alignment (§Perf studies both).
    """
    chmax = max([layers[0].ci] + [l.co for l in layers])
    chp = chp or _round_up(chmax, 8)
    if chp < chmax:
        raise ValueError(f"chp={chp} < max channels {chmax}")
    dtype = dtype or layers[0].w.dtype
    L = len(layers)
    w = jnp.zeros((L, 3, 3, chp, chp), dtype)
    b = jnp.zeros((L, chp), dtype)
    for i, l in enumerate(layers):
        w = w.at[i, :, :, : l.ci, : l.co].set(l.w.astype(dtype))
        b = b.at[i, : l.co].set(l.b.astype(dtype))
    return w, b, chp


@dataclasses.dataclass
class PackedLayers:
    """A conv stack in the kernel's packed storage form, plus its static
    facts (channel pad, ReLU flags, real output channels).

    Packing happens where this object is built — typically ONCE per weight
    stack, outside any jitted serving call (``engine.executor.prepare_stack``)
    — so the per-batch kernel launch takes the padded ``(L,3,3,Chp,Chp)`` /
    ``(L,Chp)`` arrays as plain device-resident inputs instead of re-running
    the zero-pad scatter on every forward.
    """

    w: jax.Array  # (L, 3, 3, Chp, Chp)
    b: jax.Array  # (L, Chp)
    chp: int
    relu: Tuple[bool, ...]
    out_channels: int  # Ch_L of the real (unpadded) stack

    @property
    def num_layers(self) -> int:
        return len(self.relu)


jax.tree_util.register_dataclass(
    PackedLayers,
    data_fields=["w", "b"],
    meta_fields=["chp", "relu", "out_channels"],
)


def pack_stack(
    layers: Sequence[ConvLayer], chp: Optional[int] = None, dtype=None
) -> PackedLayers:
    """Pack a conv stack for the kernel (``pack_layers``) and bundle the
    static facts the launch needs, so callers can pre-pack device-resident
    weights and pass them via ``tilted_fused_frames(..., packed=...)``."""
    w, b, chp = pack_layers(layers, chp, dtype=dtype)
    return PackedLayers(
        w=w,
        b=b,
        chp=chp,
        relu=tuple(bool(l.relu) for l in layers),
        out_channels=layers[-1].co,
    )


def _tilted_fused_bands(
    xb: jax.Array,  # (B, R, W, C0) band-major input
    packed: PackedLayers,
    *,
    tile_cols: int,
    add_anchor: bool,
    anchor_repeats: int,
    interpret: bool,
    row_policy: str = "zero",
    row_bounds: Optional[jax.Array] = None,
    compute_dtype=None,
) -> jax.Array:
    """Run the Pallas kernel over a flat batch of bands -> (B, R, W, ChL).

    The band axis is the kernel's sequential grid axis: scratch (overlap
    queue + residual ring) is re-zeroed whenever the column index wraps, so
    bands from different frames can share one launch — this is what lets the
    engine serve a whole frame batch with a single ``pallas_call``.
    """
    B, R, W, C0 = xb.shape
    C, L = tile_cols, packed.num_layers
    sched = make_schedule(width=W, tile_cols=C, num_layers=L)
    K = sched.num_tiles
    chp, co_l = packed.chp, packed.out_channels

    c0p = _round_up(C0, 8)

    xb = jnp.pad(xb, ((0, 0), (0, 0), (0, 0), (0, c0p - C0)))
    # Fresh stream: tile k consumes input columns [k*C + 1, k*C + C].
    xs = jnp.pad(xb, ((0, 0), (0, 0), (0, K * C + 1 - W), (0, 0)))[:, :, 1 : K * C + 1, :]
    first_col = xb[:, :, 0:1, :]

    out = _tilted.tilted_fusion_call(
        xs,
        first_col,
        packed.w,
        packed.b,
        width=W,
        tile_cols=C,
        relu_flags=list(packed.relu),
        add_anchor=add_anchor,
        in_channels=C0,
        anchor_repeats=anchor_repeats,
        row_policy=row_policy,
        row_bounds=row_bounds,
        compute_dtype=compute_dtype,
        interpret=interpret,
    )
    # Undo the tilt: tile k's block holds F_L columns [k*C - (L-1), ...+C).
    out = out.reshape(B, R, K * C, chp)
    out = jax.lax.slice(out, (0, 0, L - 1, 0), (B, R, L - 1 + W, co_l))
    return out


def tilted_fused_stack(
    x: jax.Array,
    layers: Sequence[ConvLayer],
    *,
    band_rows: int = 60,
    tile_cols: int = 8,
    chp: Optional[int] = None,
    add_anchor: bool = False,
    anchor_repeats: int = 9,
    vertical_policy: str = "zero",
    compute_dtype=None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Tilted layer fusion of a full (H, W, C0) image via the Pallas kernel.

    Returns (H, W, Ch_L) features (or anchored output when ``add_anchor``),
    numerically identical to ``ref.tilted_fused_stack_ref``.
    """
    H, W, C0 = x.shape
    out = tilted_fused_frames(
        x[None],
        layers,
        band_rows=band_rows,
        tile_cols=tile_cols,
        chp=chp,
        add_anchor=add_anchor,
        anchor_repeats=anchor_repeats,
        vertical_policy=vertical_policy,
        compute_dtype=compute_dtype,
        interpret=interpret,
    )
    return out.reshape(H, W, out.shape[-1])


def tilted_fused_frames(
    frames: jax.Array,
    layers: Optional[Sequence[ConvLayer]] = None,
    *,
    band_rows: int = 60,
    tile_cols: int = 8,
    chp: Optional[int] = None,
    add_anchor: bool = False,
    anchor_repeats: int = 9,
    vertical_policy: str = "zero",
    compute_dtype=None,
    interpret: Optional[bool] = None,
    packed: Optional[PackedLayers] = None,
) -> jax.Array:
    """Tilted layer fusion of a batch of frames (N, H, W, C0) -> (N, H, W, ChL).

    All N * (H / band_rows) bands are folded into the kernel's sequential
    band grid axis, so the whole batch is ONE ``pallas_call`` launch.

    ``vertical_policy`` selects the band boundary treatment (``zero`` |
    ``halo`` | ``replicate``, same semantics as ``core.fusion.run_banded``):
    ``zero``/``replicate`` run the R-row bands directly with the matching
    in-kernel row padding; ``halo`` marshals (R + 2L)-row slabs with
    per-band valid-row bounds and crops the recompute margin, so the result
    is exact w.r.t. the full-image reference up to matmul accumulation
    order.  ``compute_dtype`` is the kernel's on-chip feature-map dtype
    (defaults to the input dtype; MXU accumulation stays fp32).

    ``packed`` supplies a pre-packed weight stack (:func:`pack_stack`); when
    given, ``layers`` is ignored and the per-call weight pad/scatter is
    skipped — the serving engine packs once per weight stack and reuses the
    device-resident arrays across every batch.
    """
    N, H, W, C0 = frames.shape
    R = band_rows
    if H % R != 0:
        raise ValueError(f"height {H} must be a multiple of band_rows {R}")
    if vertical_policy not in VERTICAL_POLICIES:
        raise ValueError(
            f"vertical_policy {vertical_policy!r} not in {VERTICAL_POLICIES}"
        )
    if packed is None:
        if layers is None:
            raise ValueError("pass either layers or packed")
        packed = pack_stack(layers, chp, dtype=compute_dtype)
    interpret = default_interpret() if interpret is None else interpret
    L = packed.num_layers
    if vertical_policy == "halo":
        slabs, bounds = halo_slabs(frames, R, L)
        out = _tilted_fused_bands(
            slabs,
            packed,
            tile_cols=tile_cols,
            add_anchor=add_anchor,
            anchor_repeats=anchor_repeats,
            interpret=interpret,
            row_policy="zero",
            row_bounds=bounds,
            compute_dtype=compute_dtype,
        )
        out = out[:, L : L + R]  # crop the recompute margin
    else:
        out = _tilted_fused_bands(
            frames.reshape(N * (H // R), R, W, C0),
            packed,
            tile_cols=tile_cols,
            add_anchor=add_anchor,
            anchor_repeats=anchor_repeats,
            interpret=interpret,
            row_policy=vertical_policy,
            compute_dtype=compute_dtype,
        )
    return out.reshape(N, H, W, out.shape[-1])


def tilted_fused_band_stack(
    bands: jax.Array,
    layers: Optional[Sequence[ConvLayer]] = None,
    *,
    tile_cols: int = 8,
    vertical_policy: str = "zero",
    row_bounds: Optional[jax.Array] = None,
    chp: Optional[int] = None,
    compute_dtype=None,
    interpret: Optional[bool] = None,
    packed: Optional[PackedLayers] = None,
) -> jax.Array:
    """Tilted fusion over an explicit band stack (k, rows, W, C0) -> (k, R, W, ChL).

    The partial-band entry point for temporal delta serving: the caller
    has already marshalled per-band input slabs (an arbitrary subset of
    one or more frames' bands) and, under ``halo``, the matching
    per-slab valid-row bounds in the ``core.fusion.halo_slabs``
    geometry.  ``tilted_fused_frames`` cannot serve this case — its
    internal ``halo_slabs`` would borrow margin rows from whatever band
    happens to be adjacent in the stack, which for a subset is not the
    spatial neighbor.

    Under ``halo`` the slabs carry ``rows = R + 2L`` and the recompute
    margin is cropped from the output; under ``zero``/``replicate`` the
    slabs are the bare R rows.  The bands run on the kernel's sequential
    band grid axis with scratch re-zeroed per band, so each output band
    is byte-identical to the same band of a full-frame launch — the
    invariant the delta path's bit-exact splice rests on.
    """
    if bands.ndim != 4:
        raise ValueError(f"bands must be (k, rows, W, C0), got {bands.shape}")
    if vertical_policy not in VERTICAL_POLICIES:
        raise ValueError(
            f"vertical_policy {vertical_policy!r} not in {VERTICAL_POLICIES}"
        )
    if packed is None:
        if layers is None:
            raise ValueError("pass either layers or packed")
        packed = pack_stack(layers, chp, dtype=compute_dtype)
    interpret = default_interpret() if interpret is None else interpret
    if vertical_policy == "halo":
        L = packed.num_layers
        R = bands.shape[1] - 2 * L
        if R <= 0:
            raise ValueError(
                f"halo slabs need rows > 2L; got rows={bands.shape[1]}, L={L}"
            )
        if row_bounds is None:
            raise ValueError("halo band stacks require row_bounds")
        out = _tilted_fused_bands(
            bands,
            packed,
            tile_cols=tile_cols,
            add_anchor=False,
            anchor_repeats=9,
            interpret=interpret,
            row_policy="zero",
            row_bounds=row_bounds,
            compute_dtype=compute_dtype,
        )
        return out[:, L : L + R]  # crop the recompute margin
    return _tilted_fused_bands(
        bands,
        packed,
        tile_cols=tile_cols,
        add_anchor=False,
        anchor_repeats=9,
        interpret=interpret,
        row_policy=vertical_policy,
        compute_dtype=compute_dtype,
    )


def conv3x3(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    tile_cols: int = 8,
    relu: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Single-layer vectorwise 3x3 conv (the layerwise-baseline datapath)."""
    interpret = default_interpret() if interpret is None else interpret
    return _conv3x3.conv3x3_call(
        x, w, b, tile_cols=tile_cols, relu=relu, interpret=interpret
    )
