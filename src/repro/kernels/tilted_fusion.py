"""Pallas TPU kernel: tilted layer fusion (the paper's chip, one core/band).

TPU-native adaptation of the accelerator (DESIGN.md §2):

* HBM -> VMEM streaming replaces DRAM -> SRAM: because of the tilt, each
  grid step consumes a *disjoint* C-column input slab — overlapping halo
  reads are converted into clean non-overlapping ``BlockSpec`` streaming
  (this is exactly the paper's bandwidth insight, expressed as a BlockSpec).
* The overlap SRAM queue (paper §III-F) becomes a persistent VMEM scratch
  array ``(L, R, 2, Chp)``: TPU grids execute sequentially, so scratch
  carries the last two columns of every fused feature map from tile k to
  tile k+1.  It is re-zeroed when the column index wraps (new band).
* The residual SRAM (paper eq. 3) becomes a ``(R, C+L, Ch0)`` VMEM ring that
  retains exactly the last C+L input columns — the anchor for tile k's
  output is always the ring's leading C columns.
* The 28x3x(5x3)-MAC diagonal PE array becomes 9 shifted MXU matmuls per
  layer: ``(R*C, Chp) @ (Chp, Chp)`` — the diagonal partial-sum accumulation
  of the vectorwise dataflow is what a systolic matmul performs internally.

Channel counts are padded to a uniform ``Chp`` (multiple of 8, up to 128 for
full MXU lanes); padded weights/biases are zero, so padded channels stay
identically zero through ReLU — no masking needed on channels.  Phantom
*columns* (outside the image) ARE masked every layer, which keeps the kernel
bit-compatible with SAME-padded convolution (see ``core.tiling``).

The kernel covers the full ``SRPlan`` space:

* ``row_policy`` selects the vertical boundary treatment of each band —
  ``zero`` (the paper's block-conv rows) or ``replicate`` (edge-row padding
  at every layer, matching ``core.fusion._conv_tile``).
* ``row_bounds`` (per-band ``[lo, hi)`` SMEM scalars) marks real-image rows
  of a halo slab; rows outside are phantom and re-zeroed after every layer,
  so an (R + 2L)-row slab cropped by L rows per side reproduces the exact
  full-image result (the engine's ``halo`` policy).
* ``compute_dtype`` is the on-chip feature-map dtype: bf16 plans hold the
  overlap queue / residual ring in bf16 and round every fused feature map to
  bf16, while MXU accumulation stays fp32 — the TPU-native reading of the
  chip's reduced-precision datapath.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "tilted_fusion_kernel",
    "tilted_fusion_call",
    "round_up_channels",
    "scratch_shapes",
    "kernel_buffers",
]


def round_up_channels(n: int, multiple: int = 8) -> int:
    """The kernel's channel-padding rule: round up to the TPU sublane
    multiple (8).  ``ops.pack_layers`` and the static analyser both go
    through this, so padded storage and the verifier's byte accounting can
    never drift apart."""
    return -(-int(n) // multiple) * multiple


def scratch_shapes(num_layers: int, band_rows: int, tile_cols: int,
                   chp: int, c0p: int):
    """The kernel's persistent VMEM scratch shapes — ``(overlap_queue,
    residual_ring)`` — as plain tuples.

    This is the ONE definition of the scratch geometry: the
    ``pallas_call`` launch below allocates exactly these shapes, and the
    static plan verifier (``repro.analysis.plan_check``) computes its
    on-chip budget from them.
    """
    overlap = (num_layers, band_rows, 2, chp)
    residual = (band_rows, tile_cols + num_layers, c0p)
    return overlap, residual


def _elems(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def kernel_buffers(
    *,
    channels,  # Sequence[int]: feature-map channel counts F_0..F_L
    band_rows: int,
    tile_cols: int,
    chp: int = None,
) -> dict:
    """Static introspection of every on-chip buffer ``tilted_fusion_call``
    allocates for one grid step, in ELEMENTS (dtype-free).

    For each buffer the entry carries the padded ``shape`` the launch
    really allocates (channels rounded up to the sublane multiple — the
    ``elements`` count) and the ``logical_elements`` the algorithm
    fundamentally retains (unpadded channels — the quantity the paper's
    eqs. (1)-(3) count).  ``repro.analysis.plan_check`` cross-checks the
    logical counts against ``core.analysis.buffer_sizes`` (Table II) and
    budget-gates the padded totals.

    Buffers:
      * ``overlap``    — the persistent overlap-queue VMEM scratch
        (paper eq. 2; here L slots, one per fused layer, vs the RTL's L+2).
      * ``residual``   — the residual-ring VMEM scratch (paper eq. 3).
      * ``stream_in``  — the fresh-column input block + first-column block
        streamed per grid step (the tilt's replacement for half the
        ping-pong pair).
      * ``stream_out`` — the output block written per grid step.
      * ``weights``/``bias`` — the packed weight/bias blocks resident in
        VMEM across the whole launch.
      * ``row_bounds`` — the per-band SMEM scalars (bytes, not elements —
        always int32).
    """
    channels = [int(c) for c in channels]
    L = len(channels) - 1
    if L < 1:
        raise ValueError(f"channels {channels!r} must list F_0..F_L, L >= 1")
    R, C = int(band_rows), int(tile_cols)
    chmax, ch0, chl = max(channels), channels[0], channels[-1]
    chp = int(chp) if chp else round_up_channels(chmax)
    c0p = round_up_channels(ch0)
    overlap_shape, residual_shape = scratch_shapes(L, R, C, chp, c0p)
    buffers = {
        "overlap": {
            "shape": overlap_shape,
            "elements": _elems(overlap_shape),
            "logical_elements": L * R * 2 * chmax,
        },
        "residual": {
            "shape": residual_shape,
            "elements": _elems(residual_shape),
            "logical_elements": ch0 * R * (C + L),
        },
        "stream_in": {
            # x block (1, R, C, c0p) + first_col block (1, R, 1, c0p)
            "shape": (1, R, C + 1, c0p),
            "elements": R * (C + 1) * c0p,
            "logical_elements": ch0 * R * (C + 1),
        },
        "stream_out": {
            "shape": (1, R, C, chp),
            "elements": R * C * chp,
            "logical_elements": chl * R * C,
        },
        "weights": {
            "shape": (L, 3, 3, chp, chp),
            "elements": L * 9 * chp * chp,
            "logical_elements": sum(
                9 * channels[i] * channels[i + 1] for i in range(L)
            ),
        },
        "bias": {
            "shape": (L, chp),
            "elements": L * chp,
            "logical_elements": sum(channels[1:]),
        },
    }
    report = {
        "num_layers": L,
        "band_rows": R,
        "tile_cols": C,
        "chp": chp,
        "c0p": c0p,
        "buffers": buffers,
        "row_bounds_smem_bytes": 2 * 4,  # (1, 2) int32 per grid step
        "scratch_elements": (
            buffers["overlap"]["elements"] + buffers["residual"]["elements"]
        ),
        "total_elements": sum(b["elements"] for b in buffers.values()),
        "total_logical_elements": sum(
            b["logical_elements"] for b in buffers.values()
        ),
    }
    return report


def _conv_tile_mxu(f, w_l, b_l, R: int, C: int, chp: int, acc_dtype, row_policy: str):
    """3x3 conv of one (R, C+2, Chp) slab -> (R, C, Chp) via 9 MXU matmuls.

    ``row_policy`` is the band's vertical boundary treatment: ``zero`` pads
    the +-1 row halo with zeros (the paper's block-conv rows), ``replicate``
    with copies of the band's edge rows — matching ``core.fusion._conv_tile``
    so the kernel stays layer-for-layer compatible with the pure-JAX sweep.
    """
    if row_policy == "replicate":
        frow = jnp.concatenate([f[:1], f, f[-1:]], axis=0)
    else:  # "zero"
        frow = jnp.pad(f, ((1, 1), (0, 0), (0, 0)))
    acc = jnp.zeros((R * C, chp), acc_dtype)
    for dy in range(3):
        for dx in range(3):
            patch = jax.lax.dynamic_slice(frow, (dy, dx, 0), (R, C, chp))
            acc = acc + jax.lax.dot(
                patch.reshape(R * C, chp),
                w_l[dy, dx],
                preferred_element_type=acc_dtype,
            )
    return acc.reshape(R, C, chp) + b_l[None, None, :]


def tilted_fusion_kernel(
    # inputs (VMEM blocks; row bounds live in SMEM)
    first_col_ref,  # (1, R, 1, C0p)   first real input column of the band
    x_ref,  # (1, R, C, C0p)   fresh input stream slab for tile k
    w_ref,  # (L, 3, 3, Chp, Chp)
    b_ref,  # (L, Chp)
    rows_ref,  # (1, 2) int32   this band's [valid_lo, valid_hi) row range
    # outputs
    o_ref,  # (1, R, C, Chp)
    # scratch (persistent across sequential grid steps)
    overlap_ref,  # (L, R, 2, Chp)
    resid_ref,  # (R, C+L, C0p)
    *,
    num_layers: int,
    width: int,
    tile_cols: int,
    band_rows: int,
    chp: int,
    c0p: int,
    relu_flags: Sequence[bool],
    add_anchor: bool,
    in_channels: int,
    anchor_repeats: int,
    row_policy: str = "zero",
    mask_rows: bool = False,
    compute_dtype=jnp.float32,
    acc_dtype=jnp.float32,
):
    L, C, R, W = num_layers, tile_cols, band_rows, width
    k = pl.program_id(1)  # column-tile index (fastest-varying)
    out_dtype = o_ref.dtype
    cdt = compute_dtype

    # ---- new band: reset the overlap queue and the residual ring ----
    @pl.when(k == 0)
    def _init():
        overlap_ref[...] = jnp.zeros_like(overlap_ref)
        resid_ref[...] = jnp.zeros_like(resid_ref)
        # overlap slot for F_0 holds input columns [-1, 0]:
        # col -1 is zero padding; col 0 is the band's first real column.
        first = first_col_ref[0, :, 0, :]
        overlap_ref[0, :, 1, :c0p] = first.astype(overlap_ref.dtype)
        # residual ring: after this tile's shift-append the ring spans input
        # columns [-L+1, C]; pre-place col 0 so it lands at ring index L-1.
        resid_ref[:, C + L - 1, :] = first.astype(resid_ref.dtype)

    fresh = x_ref[0].astype(cdt)  # (R, C, C0p)

    # ---- residual ring: shift left by C, append the fresh slab ----
    if add_anchor:
        ring = resid_ref[...]
        ring = jnp.concatenate([ring[:, C:, :], fresh.astype(resid_ref.dtype)], axis=1)
        resid_ref[...] = ring

    # ---- input slab: 2 overlap columns ++ C fresh columns, pad channels ----
    left0 = overlap_ref[0, :, :, :c0p].astype(cdt)  # (R, 2, C0p)
    f = jnp.concatenate([left0, fresh], axis=1)  # (R, C+2, C0p)
    overlap_ref[0, :, :, :c0p] = f[:, -2:, :].astype(overlap_ref.dtype)
    f = jnp.pad(f, ((0, 0), (0, 0), (0, chp - c0p)))

    col_iota = jax.lax.broadcasted_iota(jnp.int32, (1, C, 1), 1)
    if mask_rows:
        # Phantom rows (outside this band's valid range, e.g. the zero
        # margin a halo slab carries past the image edge) are re-zeroed
        # after every layer so they behave exactly like SAME padding.
        row_iota = jax.lax.broadcasted_iota(jnp.int32, (R, 1, 1), 0)
        row_ok = (row_iota >= rows_ref[0, 0]) & (row_iota < rows_ref[0, 1])

    for l in range(L):
        g = _conv_tile_mxu(
            f, w_ref[l].astype(cdt), b_ref[l].astype(acc_dtype),
            R, C, chp, acc_dtype, row_policy,
        )
        if relu_flags[l]:
            g = jnp.maximum(g, 0.0)
        # zero phantom columns: this layer's output covers cols k*C - l + [0, C)
        abs_cols = k * C - l + col_iota
        g = jnp.where((abs_cols >= 0) & (abs_cols < W), g, 0.0)
        if mask_rows:
            g = jnp.where(row_ok, g, 0.0)
        # bf16 plans round every fused feature map to the compute dtype —
        # the on-chip SRAM width — exactly like the pure-JAX sweep does.
        g = g.astype(cdt)
        if l < L - 1:
            left = overlap_ref[l + 1, :, :, :].astype(cdt)  # (R, 2, Chp)
            overlap_ref[l + 1, :, :, :] = g[:, -2:, :].astype(overlap_ref.dtype)
            f = jnp.concatenate([left, g], axis=1)  # (R, C+2, Chp)
        else:
            if add_anchor:
                # anchor = input cols [kC-L+1, kC-L+C) = the ring's head,
                # each channel repeated scale^2 times (channel-major),
                # zero-padded up to Chp so padded channels stay clean.
                anchor = resid_ref[:, :C, :in_channels].astype(cdt)
                anchor = jnp.repeat(anchor, anchor_repeats, axis=-1)
                anchor = jnp.pad(
                    anchor, ((0, 0), (0, 0), (0, chp - in_channels * anchor_repeats))
                )
                # phantom anchor columns must be masked like g's
                anchor = jnp.where((abs_cols >= 0) & (abs_cols < W), anchor, 0.0)
                g = g + anchor
            o_ref[0] = g.astype(out_dtype)


def tilted_fusion_call(
    x_stream: jax.Array,  # (B, R, K*C, C0p) fresh streams per band
    first_col: jax.Array,  # (B, R, 1, C0p)
    w: jax.Array,  # (L, 3, 3, Chp, Chp) zero-padded weights
    b: jax.Array,  # (L, Chp)
    *,
    width: int,
    tile_cols: int,
    relu_flags: Sequence[bool],
    add_anchor: bool,
    in_channels: int,
    anchor_repeats: int = 9,
    row_policy: str = "zero",
    row_bounds: jax.Array = None,  # (B, 2) int32 [valid_lo, valid_hi) per band
    compute_dtype=None,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Launch the fused kernel over grid (bands, column tiles).

    ``row_policy`` selects the vertical boundary treatment inside every
    band (``zero`` | ``replicate``); ``row_bounds`` optionally marks each
    band's real-image row range — rows outside it are phantom and re-zeroed
    per layer (the halo-slab mechanism); ``compute_dtype`` is the on-chip
    feature-map dtype (MXU accumulation stays fp32).
    """
    B, R, KC, c0p = x_stream.shape
    L, _, _, chp, _ = w.shape
    C = tile_cols
    K = KC // C
    if add_anchor and in_channels * anchor_repeats > chp:
        raise ValueError("anchor channels exceed padded channel count")
    if row_policy not in ("zero", "replicate"):
        raise ValueError(f"row_policy {row_policy!r} not in ('zero', 'replicate')")
    out_dtype = out_dtype or x_stream.dtype
    compute_dtype = compute_dtype or x_stream.dtype
    mask_rows = row_bounds is not None
    if not mask_rows:  # full-band validity placeholder (kernel ignores it)
        row_bounds = jnp.broadcast_to(jnp.array([0, R], jnp.int32), (B, 2))
    row_bounds = row_bounds.astype(jnp.int32)

    kernel = functools.partial(
        tilted_fusion_kernel,
        num_layers=L,
        width=width,
        tile_cols=C,
        band_rows=R,
        chp=chp,
        c0p=c0p,
        relu_flags=tuple(relu_flags),
        add_anchor=add_anchor,
        in_channels=in_channels,
        anchor_repeats=anchor_repeats,
        row_policy=row_policy,
        mask_rows=mask_rows,
        compute_dtype=compute_dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, K),
        in_specs=[
            pl.BlockSpec((1, R, 1, c0p), lambda bnd, k: (bnd, 0, 0, 0)),
            pl.BlockSpec((1, R, C, c0p), lambda bnd, k: (bnd, 0, k, 0)),
            pl.BlockSpec((L, 3, 3, chp, chp), lambda bnd, k: (0, 0, 0, 0, 0)),
            pl.BlockSpec((L, chp), lambda bnd, k: (0, 0)),
            pl.BlockSpec((1, 2), lambda bnd, k: (bnd, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, R, C, chp), lambda bnd, k: (bnd, 0, k, 0)),
        out_shape=jax.ShapeDtypeStruct((B, R, KC, chp), out_dtype),
        scratch_shapes=[
            pltpu.VMEM(shape, compute_dtype)
            for shape in scratch_shapes(L, R, C, chp, c0p)
        ],
        interpret=interpret,
    )(first_col, x_stream, w, b, row_bounds)
