"""abpn_x3 — the paper's own model: ABPN x3 super-resolution (ISCAS 2022).

Not an LM: this config routes to the SR pipeline (core.fusion + the
tilted-fusion Pallas kernel).  640x360 -> 1920x1080, 7 conv layers,
28 feature channels, 8-bit quantised deployment.
"""

from repro.models.abpn import ABPNConfig

CONFIG = ABPNConfig(in_channels=3, feature_channels=28, num_layers=7, scale=3)

# The accelerator design point (buffers, PE array) lives in
# repro.core.analysis.HWConfig and defaults to this model.
