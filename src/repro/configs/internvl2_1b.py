"""internvl2-1b — VLM: InternViT stub frontend + Qwen2-0.5B-style LM.
[arXiv:2404.16821; hf]

The vision tower is a STUB per the assignment: ``input_specs`` supplies
256 precomputed patch embeddings (B, 256, d_model) that are concatenated
ahead of the token embeddings.  The language backbone keeps the assigned
geometry (24L d896 14H kv2 d_ff 4864, vocab 151655, QKV bias).
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    d_ff=4864,
    vocab_size=151655,
    attention="gqa",
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
    frontend_tokens=256,
    rope_theta=1e6,
    remat="full",
)
