"""zamba2-2.7b — hybrid: Mamba2 backbone + weight-shared attention blocks.
[arXiv:2411.15242; hf]

54 Mamba2 layers (d_model=2560, expand 2 -> d_inner 5120, headdim 64 ->
80 SSM heads, state 64); after every 6 Mamba layers one of 2 weight-shared
transformer blocks (32 heads MHA, d_ff 10240) is applied, alternating.
Sub-quadratic between attention points -> runs the long_500k shape.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    d_ff=10240,
    vocab_size=32000,
    attention="gqa",
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    shared_attn_period=6,
    num_shared_blocks=2,
    tie_embeddings=True,
    rope_theta=1e4,
    remat="full",
)
