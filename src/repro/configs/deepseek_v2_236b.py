"""deepseek-v2-236b — MLA + fine-grained MoE. [arXiv:2405.04434; hf]

60L d_model=5120, 128 heads with Multi-head Latent Attention
(kv_lora_rank=512, q_lora_rank=1536, decoupled rope dim 64, per-head
nope/v dims 128), vocab 102400.  MoE: 160 routed experts top-6 with
expert hidden 1536 (the assigned d_ff) plus 2 shared experts; layer 0 is
dense (first_k_dense=1).
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    d_ff=1536,
    vocab_size=102400,
    attention="mla",
    num_heads=128,
    num_kv_heads=128,  # MLA: informational (cache is the shared latent)
    head_dim=128,  # per-head "nope" dim
    rope_head_dim=64,
    v_head_dim=128,
    kv_lora_rank=512,
    q_lora_rank=1536,
    num_experts=160,
    experts_per_token=6,
    moe_d_ff=1536,
    num_shared_experts=2,
    first_k_dense=1,
    rope_theta=1e4,
    param_dtype="bfloat16",
    remat="full",
    fsdp=True,
)
