"""qwen2-0.5b — dense, GQA with QKV bias, tied embeddings. [arXiv:2407.10671; hf]"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    d_ff=4864,
    vocab_size=151936,
    attention="gqa",
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    remat="full",
)
