"""Architecture config registry (``--arch <id>``).

The ten assigned LM-family architectures plus the paper's own ABPN model.
``get_config(name)`` returns the full published configuration;
``get_config(name).reduced()`` is the CPU smoke-test variant.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

__all__ = ["ARCH_IDS", "LM_ARCH_IDS", "get_config"]

# arch id -> module name
_REGISTRY: Dict[str, str] = {
    "arctic-480b": "arctic_480b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen3-14b": "qwen3_14b",
    "qwen3-8b": "qwen3_8b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen3-1.7b": "qwen3_1_7b",
    "internvl2-1b": "internvl2_1b",
    "zamba2-2.7b": "zamba2_2_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-130m": "mamba2_130m",
    "abpn-x3": "abpn_x3",
}

ARCH_IDS: List[str] = list(_REGISTRY)
LM_ARCH_IDS: List[str] = [a for a in ARCH_IDS if a != "abpn-x3"]


def get_config(name: str):
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[name]}")
    return mod.CONFIG
