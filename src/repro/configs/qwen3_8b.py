"""qwen3-8b — dense, GQA + qk_norm. [hf:Qwen/Qwen3-8B; hf]"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    d_ff=12288,
    vocab_size=151936,
    attention="gqa",
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    remat="full",
)
