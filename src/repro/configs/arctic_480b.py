"""arctic-480b — Snowflake Arctic base: dense-MoE hybrid.

[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128 experts top-2 PLUS a dense residual MLP in
parallel with the routed output.  bf16 params + bf16 Adam moments so the
~0.47T parameters fit 256 chips with FSDP (see partitioning.fsdp_rules).
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    d_ff=4864,
    vocab_size=32000,
    attention="gqa",
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    num_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    dense_residual=True,
    rope_theta=1e6,
    param_dtype="bfloat16",
    remat="full",
    fsdp=True,
)
