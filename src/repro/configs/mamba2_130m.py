"""mamba2-130m — attention-free SSD LM. [arXiv:2405.21060; unverified]

24 layers, d_model=768 (d_inner 1536, headdim 64 -> 24 SSM heads),
state N=128, conv width 4, GPT-NeoX vocab 50280, tied embeddings.
The chunked SSD scan is the sequence-axis analogue of tilted layer
fusion (DESIGN.md §5) — this arch is the technique's closest LM relative.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    tie_embeddings=True,
    remat="full",
)
