"""Assigned input shapes and their ShapeDtypeStruct providers.

Four shapes per LM arch (assignment):
  train_4k     seq 4,096   x global batch 256   (training step)
  prefill_32k  seq 32,768  x global batch 32    (inference prefill)
  decode_32k   seq 32,768  x global batch 128   (one-token decode, full cache)
  long_500k    seq 524,288 x global batch 1     (long-context decode)

``decode_*``/``long_*`` lower ``serve_step`` (a single new token against a
KV cache of ``seq_len``), NOT ``train_step``.  ``long_500k`` requires
sub-quadratic attention: it runs for ssm/hybrid archs and is recorded as a
SKIP for pure full-attention archs (DESIGN.md §5).

``input_specs`` returns weak-type-correct ShapeDtypeStructs only — the
dry-run never allocates real data.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["SHAPES", "ShapeSpec", "input_specs", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg, shape_name: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip policy."""
    spec = SHAPES[shape_name]
    if spec.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            f"{cfg.name} is pure full-attention; 524k decode is quadratic-"
            "cost/cache-prohibitive — skipped per assignment (sub-quadratic "
            "archs only)"
        )
    return True, ""


def batch_specs(cfg, shape: ShapeSpec, override_batch: Optional[int] = None,
                override_seq: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input ShapeDtypeStructs for one step (cache/params excluded)."""
    B = override_batch or shape.global_batch
    S = override_seq or shape.seq_len
    i32 = jnp.int32
    act = cfg.activation_dtype
    d = cfg.d_model

    if shape.kind == "train":
        if cfg.family == "encdec":
            return {
                "src": jax.ShapeDtypeStruct((B, S, d), act),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "targets": jax.ShapeDtypeStruct((B, S), i32),
                "mask": jax.ShapeDtypeStruct((B, S), i32),
            }
        out = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "targets": jax.ShapeDtypeStruct((B, S), i32),
            "mask": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.family == "vlm":
            f = cfg.frontend_tokens
            out["frontend"] = jax.ShapeDtypeStruct((B, f, d), act)
            out["tokens"] = jax.ShapeDtypeStruct((B, S - f), i32)
            out["targets"] = jax.ShapeDtypeStruct((B, S - f), i32)
            out["mask"] = jax.ShapeDtypeStruct((B, S - f), i32)
        return out

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "src": jax.ShapeDtypeStruct((B, S, d), act),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
            }
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "vlm":
            f = cfg.frontend_tokens
            out["frontend"] = jax.ShapeDtypeStruct((B, f, d), act)
            out["tokens"] = jax.ShapeDtypeStruct((B, S - f), i32)
        return out

    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

    raise ValueError(f"unknown shape kind {shape.kind!r}")


def input_specs(cfg, shape_name: str, **overrides):
    return batch_specs(cfg, SHAPES[shape_name], **overrides)
