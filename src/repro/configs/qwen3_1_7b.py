"""qwen3-1.7b — dense, GQA + qk_norm, tied embeddings. [hf:Qwen/Qwen3-8B; hf]"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    d_ff=6144,
    vocab_size=151936,
    attention="gqa",
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e6,
    remat="full",
)
