"""seamless-m4t-large-v2 — enc-dec multimodal backbone. [arXiv:2308.11596; hf]

24 encoder + 24 decoder layers, d_model=1024, 16 heads (MHA), d_ff=8192
ReLU (non-gated) FFN, vocab 256206.  The audio frontend is a STUB:
``input_specs`` supplies precomputed frame embeddings (B, S, d_model) as
the encoder input.  RoPE replaces the original positions (DESIGN.md §2).
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    d_ff=8192,
    vocab_size=256206,
    attention="gqa",
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    mlp_act="relu",
    rope_theta=1e4,
    remat="full",
)
