"""Sharded, atomic, async checkpointing with retention GC.

Layout (one directory per step)::

    <dir>/step_000120/
        manifest.json      {step, keys, fingerprint, complete: true}
        arrays.npz         one entry per flattened pytree leaf

Guarantees:
  * atomicity — written to ``<dir>/.tmp_<step>`` then ``os.replace``d;
    a crash mid-write never corrupts the latest checkpoint (the restart
    loop in ``runtime.resilience`` relies on this);
  * async — ``save(..., blocking=False)`` snapshots to host memory
    synchronously (cheap) and writes on a worker thread so the train loop
    overlaps I/O with compute;
  * retention — ``keep`` newest checkpoints survive GC;
  * fingerprint — config hash checked on restore (mismatched architecture
    restores fail loudly, not with shape errors later).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "fingerprint", "wait_pending"]

_PENDING: list = []


def fingerprint(cfg) -> str:
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            manifest = os.path.join(ckpt_dir, name, "manifest.json")
            try:
                with open(manifest) as f:
                    if json.load(f).get("complete"):
                        steps.append(int(name[5:]))
            except (OSError, ValueError, json.JSONDecodeError):
                continue
    return max(steps) if steps else None


def _write(ckpt_dir: str, step: int, flat: Dict[str, np.ndarray], fp: str, keep: int):
    tmp = os.path.join(ckpt_dir, f".tmp_{step}")
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(
            {"step": step, "keys": sorted(flat), "fingerprint": fp, "complete": True},
            f,
        )
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # retention
    done = sorted(
        n for n in os.listdir(ckpt_dir) if n.startswith("step_")
    )
    for name in done[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def save(ckpt_dir: str, step: int, state, cfg=None, keep: int = 3,
         blocking: bool = True) -> None:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)  # synchronous host snapshot
    fp = fingerprint(cfg) if cfg is not None else ""
    if blocking:
        _write(ckpt_dir, step, flat, fp, keep)
        return
    t = threading.Thread(target=_write, args=(ckpt_dir, step, flat, fp, keep),
                         daemon=True)
    t.start()
    _PENDING.append(t)


def wait_pending() -> None:
    while _PENDING:
        _PENDING.pop().join()


def restore(ckpt_dir: str, reference_state, cfg=None,
            step: Optional[int] = None) -> Tuple[int, Any]:
    """Restore into the structure (and shardings) of ``reference_state``."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if cfg is not None and manifest["fingerprint"] not in ("", fingerprint(cfg)):
        raise ValueError(
            f"checkpoint fingerprint {manifest['fingerprint']} does not match "
            f"config {fingerprint(cfg)} — wrong architecture?"
        )
    data = np.load(os.path.join(path, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(reference_state)
    leaves = []
    for path_elems, ref_leaf in paths:
        key = "/".join(str(p) for p in path_elems)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        sharding = getattr(ref_leaf, "sharding", None)
        leaf = jax.device_put(arr, sharding) if sharding else jax.numpy.asarray(arr)
        leaves.append(leaf.astype(ref_leaf.dtype))
    return step, jax.tree_util.tree_unflatten(treedef, leaves)
