"""Fault tolerance: restart loop, straggler detection, elastic re-mesh.

Designed for 1000+ node posture, exercised here on fake device meshes:

* **Checkpoint/restart** — :func:`resilient_train_loop` wraps any step
  function; on failure (hardware fault, injected fault, preemption) it
  restores the newest complete checkpoint and replays the data stream from
  that step (the stream is a pure function of step, see ``data.synthetic``).
* **Straggler detection** — :class:`StragglerDetector` keeps an EMA of
  step times and flags z-score outliers; the loop records them and (policy)
  can trigger a re-mesh.  On real fleets this signal comes per-host; the
  detection logic is host-count agnostic.  Its EMA mean/variance core is
  :class:`EMAMeanVar`, shared with the serving stack's
  ``engine.server.DegradePolicy`` (rolling p99 estimation).
* **Fault injection** — :class:`FailureInjector` covers both the training
  restart loop (``fail_at_steps``/``maybe_fail``) and the serving path:
  ``SRServer(..., injector=...)`` calls :meth:`FailureInjector.on_dispatch`
  before every launch, so tests and the load harness can fail the k-th
  dispatch, delay a replica, or poison one hosted model and prove the
  server fails only the affected requests.
* **Elastic re-mesh** — :func:`elastic_remesh` moves the training state
  onto a smaller/larger mesh by re-resolving every leaf's logical sharding
  against the new mesh and ``device_put``-ing.  Tested 8 -> 4 devices.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax

from repro.distributed import partitioning as pt
from repro.runtime import checkpoint as ckpt_lib

__all__ = ["EMAMeanVar", "StragglerDetector", "FailureInjector",
           "InjectedFailure", "resilient_train_loop", "elastic_remesh"]


class EMAMeanVar:
    """Exponential moving mean/variance of a latency stream.

    The shared core under :class:`StragglerDetector` (per-step training
    latency, z-score outliers) and ``engine.server.DegradePolicy``
    (per-request serving latency, rolling p99 estimate).  The variance is
    SEEDED from the first nonzero delta: the plain recurrence leaves
    ``var == 0`` after a constant-latency prefix, which silently disarms
    any ``var > 0`` z-score gate downstream for one fold longer than its
    warmup promises.
    """

    def __init__(self, alpha: float = 0.1):
        self.alpha = alpha
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0

    def fold(self, x: float) -> None:
        """Fold one observation into the moving statistics."""
        self.n += 1
        if self.mean is None:
            self.mean = float(x)
            return
        delta = x - self.mean
        if self.var == 0.0 and delta != 0.0:
            self.var = delta * delta
        else:
            self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        self.mean += self.alpha * delta

    @property
    def std(self) -> float:
        return self.var ** 0.5

    def zscore(self, x: float) -> float:
        """How many moving standard deviations ``x`` sits from the mean.
        With zero variance (a perfectly constant history) any deviation is
        infinitely surprising: returns ``±inf`` rather than 0, so a spike
        after constant warmup is still flagged."""
        if self.mean is None:
            return 0.0
        delta = x - self.mean
        if self.var > 0:
            return delta / self.var ** 0.5
        if delta == 0:
            return 0.0
        return float("inf") if delta > 0 else float("-inf")

    def upper(self, z: float) -> float:
        """``mean + z * std`` — the normal-approximation upper quantile
        (z=2.326 ~ p99) the serving degrade policy tracks."""
        if self.mean is None:
            return 0.0
        return self.mean + z * self.std


class StragglerDetector:
    """EMA-based per-step latency outlier detection."""

    def __init__(self, alpha: float = 0.1, z_threshold: float = 3.0,
                 warmup: int = 5):
        self.alpha, self.z = alpha, z_threshold
        self.warmup = warmup
        self._ema = EMAMeanVar(alpha)
        self.n = 0
        self.flagged: list = []

    # the EMA state reads like before — .mean/.var are the moving stats
    # (outliers are never folded, so they track the clean baseline)
    @property
    def mean(self) -> Optional[float]:
        return self._ema.mean

    @property
    def var(self) -> float:
        return self._ema.var

    def update(self, step: int, seconds: float) -> bool:
        self.n += 1
        if self._ema.mean is None:
            self._ema.fold(seconds)
            return False
        is_straggler = False
        if self.n > self.warmup:
            zscore = self._ema.zscore(seconds)
            if zscore > self.z:
                is_straggler = True
                self.flagged.append((step, seconds, zscore))
        # only fold non-outliers into the stats (outliers would mask repeats)
        if not is_straggler:
            self._ema.fold(seconds)
        return is_straggler


class InjectedFailure(RuntimeError):
    """Raised by :class:`FailureInjector` at a configured injection point
    — distinguishable from organic failures in tests and harness output."""


class FailureInjector:
    """Deterministic failure injection for restart AND serving tests.

    Training path (``resilient_train_loop``): ``fail_at_steps`` + a
    ``maybe_fail(step)`` call at the top of each step.

    Serving path: pass the injector to ``SRServer(..., injector=...)``;
    the server calls :meth:`on_dispatch` before every launch, after
    executor/replica resolution, so the injection flows through the
    server's normal dispatch-failure isolation:

    * ``fail_dispatches`` — zero-based global dispatch indices that raise
      :class:`InjectedFailure` (the k-th dispatch fails; only that
      dispatch's requests may fail, the server must keep serving).
    * ``delay_dispatches`` — ``{index: seconds}``: stall those launches (a
      transient straggler; the requests still complete).
    * ``poison_models`` — model names whose EVERY dispatch fails (a bad
      weight load; other hosted models must keep serving).
    * ``delay_replicas`` — ``{replica_index: seconds}``: stall every
      dispatch routed to one mesh replica (a straggler device).
    """

    def __init__(self, fail_at_steps=(), *, fail_dispatches=(),
                 delay_dispatches=None, poison_models=(),
                 delay_replicas=None):
        self.fail_at = set(fail_at_steps)
        self.fired = set()
        self.fail_dispatches = set(fail_dispatches)
        self.delay_dispatches = dict(delay_dispatches or {})
        self.poison_models = set(poison_models)
        self.delay_replicas = dict(delay_replicas or {})
        self.dispatch_index = 0  # dispatches seen via on_dispatch
        self.injected_failures = 0
        self.injected_delays = 0

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")

    def on_dispatch(self, *, model: Optional[str] = None,
                    replica: Optional[int] = None) -> None:
        """Serving-path injection point: called once per dispatch launch."""
        k = self.dispatch_index
        self.dispatch_index += 1
        delay = self.delay_dispatches.get(k, 0.0)
        if replica is not None:
            delay = max(delay, self.delay_replicas.get(replica, 0.0))
        if delay > 0:
            self.injected_delays += 1
            time.sleep(delay)
        if model is not None and model in self.poison_models:
            self.injected_failures += 1
            raise InjectedFailure(f"injected poison: model {model!r}")
        if k in self.fail_dispatches:
            self.injected_failures += 1
            raise InjectedFailure(f"injected failure at dispatch {k}")

    def stats(self) -> Dict[str, int]:
        return {
            "dispatches_seen": self.dispatch_index,
            "injected_failures": self.injected_failures,
            "injected_delays": self.injected_delays,
        }


def resilient_train_loop(
    *,
    init_state,
    train_step: Callable,
    batch_fn: Callable[[int], Dict],
    total_steps: int,
    ckpt_dir: str,
    cfg=None,
    checkpoint_every: int = 50,
    keep: int = 3,
    max_restarts: int = 5,
    injector: Optional[FailureInjector] = None,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> Tuple[Any, Dict]:
    """Run to ``total_steps`` surviving failures. Returns (state, report)."""
    detector = StragglerDetector()
    restarts = 0
    state = init_state
    start = ckpt_lib.latest_step(ckpt_dir)
    if start is not None:
        start, state = ckpt_lib.restore(ckpt_dir, state, cfg)
        start += 1
    else:
        start = 0

    step = start
    while step < total_steps:
        try:
            # monotonic: step-latency deltas must not jump with NTP slews
            t0 = time.monotonic()
            if injector is not None:
                injector.maybe_fail(step)
            state, metrics = train_step(state, batch_fn(step))
            jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
            detector.update(step, time.monotonic() - t0)
            if on_metrics is not None:
                on_metrics(step, metrics)
            if checkpoint_every and (step + 1) % checkpoint_every == 0:
                ckpt_lib.save(ckpt_dir, step, state, cfg, keep=keep,
                              blocking=False)
            step += 1
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt_lib.wait_pending()
            last = ckpt_lib.latest_step(ckpt_dir)
            if last is not None:
                _, state = ckpt_lib.restore(ckpt_dir, state, cfg)
                step = last + 1
            else:
                state = init_state
                step = 0
    ckpt_lib.wait_pending()
    return state, {
        "restarts": restarts,
        "stragglers": list(detector.flagged),
        "finished_step": step,
    }


def elastic_remesh(state, axes_tree, new_mesh, rules=None):
    """Re-shard a state pytree onto a new mesh (scale down/up).

    Every leaf's LOGICAL axes are re-resolved against the new mesh shape —
    dims that no longer divide fall back toward replication via
    ``shape_aware_spec`` — and the data is device_put across.
    """
    def move(axes, leaf):
        spec = pt.shape_aware_spec(axes, leaf.shape, new_mesh, rules)
        return jax.device_put(leaf, jax.sharding.NamedSharding(new_mesh, spec))

    return jax.tree_util.tree_map(
        move, axes_tree, state, is_leaf=lambda x: isinstance(x, tuple)
    )
