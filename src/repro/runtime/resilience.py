"""Fault tolerance: restart loop, straggler detection, elastic re-mesh.

Designed for 1000+ node posture, exercised here on fake device meshes:

* **Checkpoint/restart** — :func:`resilient_train_loop` wraps any step
  function; on failure (hardware fault, injected fault, preemption) it
  restores the newest complete checkpoint and replays the data stream from
  that step (the stream is a pure function of step, see ``data.synthetic``).
* **Straggler detection** — :class:`StragglerDetector` keeps an EMA of
  step times and flags z-score outliers; the loop records them and (policy)
  can trigger a re-mesh.  On real fleets this signal comes per-host; the
  detection logic is host-count agnostic.
* **Elastic re-mesh** — :func:`elastic_remesh` moves the training state
  onto a smaller/larger mesh by re-resolving every leaf's logical sharding
  against the new mesh and ``device_put``-ing.  Tested 8 -> 4 devices.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax

from repro.distributed import partitioning as pt
from repro.runtime import checkpoint as ckpt_lib

__all__ = ["StragglerDetector", "FailureInjector", "resilient_train_loop",
           "elastic_remesh"]


class StragglerDetector:
    """EMA-based per-step latency outlier detection."""

    def __init__(self, alpha: float = 0.1, z_threshold: float = 3.0,
                 warmup: int = 5):
        self.alpha, self.z = alpha, z_threshold
        self.warmup = warmup
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.flagged: list = []

    def update(self, step: int, seconds: float) -> bool:
        self.n += 1
        if self.mean is None:
            self.mean = seconds
            return False
        delta = seconds - self.mean
        is_straggler = False
        if self.n > self.warmup and self.var > 0:
            zscore = delta / (self.var ** 0.5)
            if zscore > self.z:
                is_straggler = True
                self.flagged.append((step, seconds, zscore))
        # only fold non-outliers into the stats (outliers would mask repeats)
        if not is_straggler:
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        return is_straggler


class FailureInjector:
    """Deterministic failure injection for restart tests."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


def resilient_train_loop(
    *,
    init_state,
    train_step: Callable,
    batch_fn: Callable[[int], Dict],
    total_steps: int,
    ckpt_dir: str,
    cfg=None,
    checkpoint_every: int = 50,
    keep: int = 3,
    max_restarts: int = 5,
    injector: Optional[FailureInjector] = None,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> Tuple[Any, Dict]:
    """Run to ``total_steps`` surviving failures. Returns (state, report)."""
    detector = StragglerDetector()
    restarts = 0
    state = init_state
    start = ckpt_lib.latest_step(ckpt_dir)
    if start is not None:
        start, state = ckpt_lib.restore(ckpt_dir, state, cfg)
        start += 1
    else:
        start = 0

    step = start
    while step < total_steps:
        try:
            t0 = time.time()
            if injector is not None:
                injector.maybe_fail(step)
            state, metrics = train_step(state, batch_fn(step))
            jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
            detector.update(step, time.time() - t0)
            if on_metrics is not None:
                on_metrics(step, metrics)
            if checkpoint_every and (step + 1) % checkpoint_every == 0:
                ckpt_lib.save(ckpt_dir, step, state, cfg, keep=keep,
                              blocking=False)
            step += 1
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt_lib.wait_pending()
            last = ckpt_lib.latest_step(ckpt_dir)
            if last is not None:
                _, state = ckpt_lib.restore(ckpt_dir, state, cfg)
                step = last + 1
            else:
                state = init_state
                step = 0
    ckpt_lib.wait_pending()
    return state, {
        "restarts": restarts,
        "stragglers": list(detector.flagged),
        "finished_step": step,
    }


def elastic_remesh(state, axes_tree, new_mesh, rules=None):
    """Re-shard a state pytree onto a new mesh (scale down/up).

    Every leaf's LOGICAL axes are re-resolved against the new mesh shape —
    dims that no longer divide fall back toward replication via
    ``shape_aware_spec`` — and the data is device_put across.
    """
    def move(axes, leaf):
        spec = pt.shape_aware_spec(axes, leaf.shape, new_mesh, rules)
        return jax.device_put(leaf, jax.sharding.NamedSharding(new_mesh, spec))

    return jax.tree_util.tree_map(
        move, axes_tree, state, is_leaf=lambda x: isinstance(x, tuple)
    )
