"""repro.runtime substrate."""
