"""Dry-run engine: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
we build ShapeDtypeStruct stand-ins (zero allocation), jit with explicit
NamedShardings derived from the logical-axis rules, ``lower().compile()``
on the production mesh, and record:

  * ``compiled.memory_analysis()``  — per-device bytes (fits 16 GB/chip?)
  * ``compiled.cost_analysis()``    — XLA's per-device FLOPs/bytes
  * ``roofline.hlo_parse``          — scan-aware FLOPs / HBM bytes /
                                      collective bytes for §Roofline

This module holds the logic; ``dryrun.py`` is the entrypoint that pins the
fake-device count BEFORE jax initialises (and is the only place that does).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.config import TrainConfig
from repro.configs import LM_ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, input_specs, shape_applicable
from repro.distributed import partitioning as pt
from repro.distributed.steps import (
    batch_axes,
    cache_axes_and_shapes,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    train_state_axes,
    train_state_shapes,
)
from repro.launch.mesh import make_production_mesh
from repro.roofline.hlo_parse import parse_hlo

__all__ = ["run_cell", "run_all", "DEFAULT_OUT_DIR"]

DEFAULT_OUT_DIR = "experiments/dryrun"


def _train_tcfg(cfg) -> TrainConfig:
    # bf16 moments for the >=200B archs so state fits (DESIGN.md §6);
    # gradient accumulation halves per-microbatch activation memory.
    mdt = "bfloat16" if cfg.fsdp else "float32"
    mb = int(os.environ.get("REPRO_MICROBATCHES", "1"))  # §Perf: mb=1 minimises
    # FSDP weight-gather traffic (measured 1340 vs 2148 GB/step at mb=4)
    return TrainConfig(optimizer_dtype=mdt, microbatches=mb)


def pick_rules(cfg, shape_name: str):
    rules = dict(pt.BASE_RULES)
    # ZeRO-3 weight sharding pays a per-microbatch all-gather; it is only
    # warranted while optimizer state exists. Serve cells shard weights via
    # TP axes (expert/heads/head_dim/mlp) instead. (§Perf iteration 2)
    if SHAPES[shape_name].kind != "train":
        rules = pt.serve_rules(rules)
    if cfg.fsdp and SHAPES[shape_name].kind == "train":
        rules = pt.fsdp_rules(rules)
    if shape_name == "long_500k":
        rules = pt.long_context_rules(rules)
    return rules


def _mem_dict(ma) -> Dict[str, Any]:
    return {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "code_bytes": ma.generated_code_size_in_bytes,
        "peak_estimate_bytes": ma.argument_size_in_bytes
        + ma.output_size_in_bytes
        + ma.temp_size_in_bytes
        - ma.alias_size_in_bytes,
    }


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    reduced: bool = False,
    mesh=None,
    compile_cell: bool = True,
) -> Dict[str, Any]:
    """Lower+compile one cell; returns a JSON-serialisable record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    if reduced:
        cfg = cfg.reduced()

    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    rec["devices"] = int(mesh.devices.size)
    rules = pick_rules(cfg, shape_name)
    overrides = {}
    if reduced:
        overrides = {"override_batch": min(shape.global_batch, 8),
                     "override_seq": min(shape.seq_len, 128)}
    seq = overrides.get("override_seq", shape.seq_len)
    bsz = overrides.get("override_batch", shape.global_batch)

    try:
        with pt.axis_rules(mesh, rules):
            t0 = time.time()
            if shape.kind == "train":
                tcfg = _train_tcfg(cfg)
                step = make_train_step(cfg, tcfg)
                state_sds = train_state_shapes(cfg, tcfg)
                state_sh = pt.make_shardings(train_state_axes(cfg), state_sds)
                b_sds = input_specs(cfg, shape_name, **overrides)
                b_sh = pt.make_shardings(
                    {k: v for k, v in batch_axes(cfg, "train").items() if k in b_sds},
                    b_sds,
                )
                rep = NamedSharding(mesh, PartitionSpec())
                jitted = jax.jit(
                    step,
                    in_shardings=(state_sh, b_sh),
                    out_shardings=(state_sh, rep),
                    donate_argnums=(0,),
                )
                lowered = jitted.lower(state_sds, b_sds)
            elif shape.kind == "prefill":
                step = make_prefill_step(cfg)
                from repro.layers.params import param_axes, param_shapes
                from repro.models.registry import get_model

                model = get_model(cfg)
                p_sds = param_shapes(model.schema(cfg), cfg.weight_dtype)
                p_sh = pt.make_shardings(param_axes(model.schema(cfg)), p_sds)
                c_axes, c_sds = cache_axes_and_shapes(cfg, bsz, seq)
                c_sh = pt.make_shardings(c_axes, c_sds)
                b_sds = input_specs(cfg, shape_name, **overrides)
                b_sh = pt.make_shardings(
                    {k: v for k, v in batch_axes(cfg, "prefill").items() if k in b_sds},
                    b_sds,
                )
                logits_sh = NamedSharding(mesh, pt.shape_aware_spec(
                    ("batch", "vocab"), (bsz, cfg.vocab_size)))
                jitted = jax.jit(
                    step,
                    in_shardings=(p_sh, b_sh, c_sh),
                    out_shardings=(logits_sh, c_sh),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(p_sds, b_sds, c_sds)
            else:  # decode
                step = make_decode_step(cfg)
                from repro.layers.params import param_axes, param_shapes
                from repro.models.registry import get_model

                model = get_model(cfg)
                p_sds = param_shapes(model.schema(cfg), cfg.weight_dtype)
                p_sh = pt.make_shardings(param_axes(model.schema(cfg)), p_sds)
                c_axes, c_sds = cache_axes_and_shapes(cfg, bsz, seq)
                c_sh = pt.make_shardings(c_axes, c_sds)
                tok_sds = input_specs(cfg, shape_name, **{"override_batch": bsz})
                tok_sh = pt.make_shardings(
                    {"tokens": batch_axes(cfg, "decode")["tokens"]}, tok_sds
                )
                pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
                rep = NamedSharding(mesh, PartitionSpec())
                logits_sh = NamedSharding(mesh, pt.shape_aware_spec(
                    ("batch", "vocab"), (bsz, cfg.vocab_size)))
                jitted = jax.jit(
                    step,
                    in_shardings=(p_sh, tok_sh["tokens"], c_sh, rep),
                    out_shardings=(logits_sh, c_sh),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(p_sds, tok_sds["tokens"], c_sds, pos_sds)
            rec["lower_seconds"] = round(time.time() - t0, 2)

            if not compile_cell:
                rec["status"] = "lowered"
                return rec
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_seconds"] = round(time.time() - t1, 2)

            ma = compiled.memory_analysis()
            if ma is not None:
                rec["memory"] = _mem_dict(ma)
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):  # older jax: one dict per device
                ca = ca[0] if ca else None
            if ca:
                rec["cost_analysis"] = {
                    "flops": float(ca.get("flops", -1.0)),
                    "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
                }
            text = compiled.as_text()
            cost = parse_hlo(text)
            rec["parsed"] = {
                "flops": cost.flops,
                "hbm_bytes": cost.hbm_bytes,
                "collective_bytes": cost.collective_bytes,
                "collective_by_type": cost.collective_by_type,
                "collective_count": cost.collective_count,
                "while_trip_counts": cost.while_trip_counts[:20],
            }
            rec["status"] = "ok"
    except Exception as e:  # record failures as data, not crashes
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def run_all(
    archs=None,
    shapes=None,
    meshes=("single_pod", "multi_pod"),
    out_dir: str = DEFAULT_OUT_DIR,
    reduced: bool = False,
    skip_existing: bool = True,
) -> list:
    archs = archs or LM_ARCH_IDS
    shapes = shapes or list(SHAPES)
    os.makedirs(out_dir, exist_ok=True)
    results = []
    # reuse one mesh object per mesh kind (mesh creation is cheap but tidy)
    mesh_cache = {}
    for mesh_name in meshes:
        multi = mesh_name == "multi_pod"
        for arch in archs:
            for shape_name in shapes:
                path = os.path.join(out_dir, f"{mesh_name}__{arch}__{shape_name}.json")
                if skip_existing and os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                    if rec.get("status") in ("ok", "skipped"):
                        results.append(rec)
                        print(f"[cached] {mesh_name} {arch} {shape_name}: {rec['status']}")
                        continue
                if mesh_name not in mesh_cache:
                    mesh_cache[mesh_name] = make_production_mesh(multi_pod=multi)
                print(f"[run]    {mesh_name} {arch} {shape_name} ...", flush=True)
                rec = run_cell(arch, shape_name, multi_pod=multi, reduced=reduced,
                               mesh=mesh_cache[mesh_name])
                results.append(rec)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" lower={rec['lower_seconds']}s "
                             f"compile={rec['compile_seconds']}s")
                elif status == "error":
                    extra = " " + rec["error"][:120]
                print(f"[done]   {mesh_name} {arch} {shape_name}: {status}{extra}",
                      flush=True)
    return results
