"""Training driver: ``python -m repro.launch.train --arch qwen2-0.5b ...``

Runs the resilient training loop (checkpoint/restart, straggler detection,
prefetching pipeline) on whatever devices are present — one CPU device in
this container, a real mesh in production.  ``--reduced`` shrinks the model
for laptop-scale runs; the full configs are exercised via the dry-run.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.configs import LM_ARCH_IDS, get_config
from repro.data.synthetic import lm_batch
from repro.distributed.steps import init_train_state, make_train_step
from repro.runtime.resilience import resilient_train_loop


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=LM_ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(remat="none")
    tcfg = TrainConfig(
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 5),
        checkpoint_every=args.checkpoint_every, seed=args.seed,
    )
    print(f"arch={cfg.name} reduced={args.reduced} devices={jax.device_count()}")
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state["params"]))
    print(f"params: {n_params/1e6:.2f}M")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    losses = []

    def batch_fn(step):
        b = lm_batch(cfg, step, args.batch, args.seq, args.seed)
        if cfg.family == "vlm":
            key = jax.random.fold_in(jax.random.PRNGKey(99), step)
            b["frontend"] = jax.random.normal(
                key, (args.batch, cfg.frontend_tokens, cfg.d_model))
        if cfg.family == "encdec":
            key = jax.random.fold_in(jax.random.PRNGKey(98), step)
            b["src"] = jax.random.normal(key, (args.batch, args.seq, cfg.d_model))
        return b

    t0 = time.time()

    def on_metrics(step, metrics):
        losses.append(float(metrics["total_loss"]))
        if step % args.log_every == 0:
            dt = (time.time() - t0) / max(len(losses), 1)
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} ({dt:.2f}s/step)")

    state, report = resilient_train_loop(
        init_state=state, train_step=step_fn, batch_fn=batch_fn,
        total_steps=args.steps, ckpt_dir=args.ckpt_dir, cfg=cfg,
        checkpoint_every=args.checkpoint_every, on_metrics=on_metrics,
    )
    half = max(len(losses) // 2, 1)
    first = sum(losses[:half]) / half
    last = sum(losses[-half:]) / half
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"(restarts={report['restarts']}, stragglers={len(report['stragglers'])})")
    # success = training ran to completion without divergence
    return 0 if (last <= first * 1.05 and last == last) else 1


if __name__ == "__main__":
    raise SystemExit(main())
