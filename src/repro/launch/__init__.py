"""repro.launch"""
