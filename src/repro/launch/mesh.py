"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run pins the device count via XLA_FLAGS
before any jax initialisation).

Mesh shapes (TPU v5e pods):
  single pod : (data=16, model=16)           = 256 chips
  multi-pod  : (pod=2, data=16, model=16)    = 512 chips
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = ((16, 16), ("data", "model"))
MULTI_POD = ((2, 16, 16), ("pod", "data", "model"))


def _axis_type_kwargs(n: int) -> dict:
    """``axis_types=Auto`` where supported; older jax (< 0.5) has neither
    ``jax.sharding.AxisType`` nor the kwarg, and Auto is the default."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape, axes = MULTI_POD if multi_pod else SINGLE_POD
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests use small ones, e.g. (2, 4) on 8 host devices)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), **_axis_type_kwargs(len(axes))
    )
