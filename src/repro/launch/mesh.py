"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run pins the device count via XLA_FLAGS
before any jax initialisation).

Mesh shapes (TPU v5e pods):
  single pod : (data=16, model=16)           = 256 chips
  multi-pod  : (pod=2, data=16, model=16)    = 512 chips
"""

from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_mesh",
    "make_sr_mesh",
    "band_submesh",
    "SINGLE_POD",
    "MULTI_POD",
    "SR_REPLICA_AXIS",
    "SR_BAND_AXIS",
]

SINGLE_POD = ((16, 16), ("data", "model"))
MULTI_POD = ((2, 16, 16), ("pod", "data", "model"))

# SR serving mesh axes: ``replica`` is pure data parallelism (whole frames,
# no communication), ``bands`` splits each frame's row bands spatially
# (L-row halo exchange at shard edges).
SR_REPLICA_AXIS = "replica"
SR_BAND_AXIS = "bands"


def _axis_type_kwargs(n: int) -> dict:
    """``axis_types=Auto`` where supported; older jax (< 0.5) has neither
    ``jax.sharding.AxisType`` nor the kwarg, and Auto is the default."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape, axes = MULTI_POD if multi_pod else SINGLE_POD
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests use small ones, e.g. (2, 4) on 8 host devices)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), **_axis_type_kwargs(len(axes))
    )


def make_sr_mesh(replicas: int, band_shards: int) -> jax.sharding.Mesh:
    """The serving mesh: ``(replica=R, bands=S)`` over ``R*S`` devices.

    On CPU, force enough host devices before jax initialises:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    if replicas <= 0 or band_shards <= 0:
        raise ValueError(
            f"mesh axes must be positive, got replicas={replicas} "
            f"band_shards={band_shards}"
        )
    needed = replicas * band_shards
    avail = jax.device_count()
    if needed > avail:
        raise ValueError(
            f"mesh ({replicas}x{band_shards}) needs {needed} devices but "
            f"only {avail} are visible; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N"
        )
    return make_mesh((replicas, band_shards), (SR_REPLICA_AXIS, SR_BAND_AXIS))


def band_submesh(mesh: jax.sharding.Mesh, replica: int) -> jax.sharding.Mesh:
    """One replica's 1-D ``bands`` slice of an SR mesh.

    Each replica compiles and runs its own band-sharded executor over this
    submesh — the ``replica`` axis never appears inside a compiled program
    (replication is pure request routing, handled by ``ReplicaRouter``).
    """
    names = mesh.axis_names
    if names[-1] != SR_BAND_AXIS or SR_REPLICA_AXIS not in names:
        raise ValueError(f"not an SR mesh (axes {names})")
    rep_dim = names.index(SR_REPLICA_AXIS)
    devices = mesh.devices.take(indices=replica, axis=rep_dim)
    return jax.sharding.Mesh(devices, (SR_BAND_AXIS,))
