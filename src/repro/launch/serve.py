"""Serving driver: batched prefill + decode with KV caches.

``python -m repro.launch.serve --arch qwen2-0.5b --batch 4 --prompt-len 64
--gen 32`` serves a reduced model on local devices; the full configs'
serving paths are lowered/compiled by the dry-run (prefill_32k/decode_32k
cells).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import LM_ARCH_IDS, get_config
from repro.distributed.steps import (
    cache_axes_and_shapes,
    make_decode_step,
    make_prefill_step,
)
from repro.layers.params import init_params
from repro.models.registry import get_model


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=LM_ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    model = get_model(cfg)
    params = init_params(model.schema(cfg), jax.random.PRNGKey(args.seed),
                         cfg.weight_dtype)
    B, S = args.batch, args.prompt_len
    extra = cfg.frontend_tokens if cfg.family == "vlm" else 0
    max_len = S + extra + args.gen
    if cfg.family == "encdec":
        cache_schema = model.cache_schema(cfg, B, max_len, enc_len=S)
    else:
        cache_schema = model.cache_schema(cfg, B, max_len)
    cache = init_params(cache_schema, jax.random.PRNGKey(0))

    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size, jnp.int32)}
    if cfg.family == "vlm":
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.frontend_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["src"] = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))

    prefill = jax.jit(make_prefill_step(cfg), donate_argnums=(2,))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tokens]
    t1 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(S + extra + i)
        logits, cache = decode(params, tokens, cache, pos)
        tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.time() - t1
    out = jnp.concatenate(generated, axis=1)
    tok_s = B * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: {tok_s:.1f} tok/s "
          f"({t_decode/max(args.gen-1,1)*1e3:.1f} ms/step)")
    print("sample token ids:", out[0, :12].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
