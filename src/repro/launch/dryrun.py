import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialisation.  Only the dry-run uses 512 placeholder
# devices — smoke tests and benchmarks see the real single CPU device.
# (REPRO_DRYRUN_DEVICES overrides the count for the subprocess-based tests.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

from repro.configs import LM_ARCH_IDS  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch.dryrun_lib import DEFAULT_OUT_DIR, run_all  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Multi-pod dry-run: lower+compile every (arch x shape x mesh)."
    )
    ap.add_argument("--arch", default="all",
                    help=f"arch id or 'all' ({', '.join(LM_ARCH_IDS)})")
    ap.add_argument("--shape", default="all",
                    help=f"shape or 'all' ({', '.join(SHAPES)})")
    ap.add_argument("--mesh", default="both",
                    choices=["single_pod", "multi_pod", "both"])
    ap.add_argument("--out", default=DEFAULT_OUT_DIR)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced configs + tiny shapes (CI smoke)")
    ap.add_argument("--force", action="store_true", help="ignore cached cells")
    args = ap.parse_args(argv)

    archs = LM_ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ("single_pod", "multi_pod") if args.mesh == "both" else (args.mesh,)

    results = run_all(archs=archs, shapes=shapes, meshes=meshes,
                      out_dir=args.out, reduced=args.reduced,
                      skip_existing=not args.force)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"/ {len(results)} cells")
    for r in results:
        if r["status"] == "error":
            print(f"  ERROR {r['mesh']} {r['arch']} {r['shape']}: {r['error']}")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
