"""Analytic per-device HBM traffic (the TPU-side memory roofline term).

The CPU-backend HLO materialises fp32 up-casts and layout copies that the
TPU compiler fuses away, so byte counts parsed from the compiled CPU HLO
over-state HBM traffic by 1-2 orders of magnitude.  This module computes
the standard napkin model instead — weights, optimizer state, KV/SSM cache
and residual-stream carries actually crossing HBM per step — with every
tensor divided by its real shard count (same shape-aware rules the dry-run
uses).  EXPERIMENTS.md reports both numbers; the bottleneck call uses this
one.

Traffic model (per device, per step):

  train   : microbatches * (2 reads + grad write) of params
            + 4x optimizer state (m,v read+write) + 1x param write
            + 2x saved layer carries (write fwd, read bwd) * microbatches
            + logits io (3x) * microbatches + token io
  prefill : 1x params read + 1x cache write + 2x residual stream
  decode  : 1x params read + 1x cache read (the KV/state scan) + epsilon
"""

from __future__ import annotations

import math
from typing import Dict

import jax.numpy as jnp

from repro.distributed import partitioning as pt
from repro.layers.params import ParamSpec, param_axes, param_shapes
from repro.models.registry import get_model

__all__ = ["sharded_bytes", "analytic_hbm_bytes"]


class _StubMesh:
    """Duck-typed mesh for shape_aware_spec without touching jax devices."""

    def __init__(self, sizes: Dict[str, int]):
        import numpy as np

        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()), dtype=object)


def _mesh_sizes(mesh_name: str) -> Dict[str, int]:
    return ({"pod": 2, "data": 16, "model": 16} if mesh_name == "multi_pod"
            else {"data": 16, "model": 16})


def sharded_bytes(schema, rules, mesh_sizes: Dict[str, int],
                  default_dtype=jnp.float32) -> int:
    """Per-device bytes of a ParamSpec tree under the given rules."""
    import jax

    mesh = _StubMesh(mesh_sizes)
    total = 0
    for leaf in jax.tree_util.tree_leaves(
        schema, is_leaf=lambda x: isinstance(x, ParamSpec)
    ):
        spec = pt.shape_aware_spec(leaf.axes, leaf.shape, mesh, rules)
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry,) if isinstance(entry, str) else entry:
                shards *= mesh_sizes[ax]
        n = math.prod(leaf.shape)
        dt = jnp.dtype(leaf.dtype) if leaf.dtype else jnp.dtype(default_dtype)
        total += n * dt.itemsize // shards
    return total


def analytic_hbm_bytes(rec: Dict, cfg, rules) -> float:
    """Per-device HBM bytes for the recorded cell's step."""
    sizes = _mesh_sizes(rec["mesh"])
    model = get_model(cfg)
    schema = model.schema(cfg)
    p_bytes = sharded_bytes(schema, rules, sizes, cfg.weight_dtype)
    devices = math.prod(sizes.values())
    B, S = rec["global_batch"], rec["seq_len"]
    d = cfg.d_model
    act = jnp.dtype(cfg.dtype).itemsize
    dp = max(devices // sizes["model"], 1)
    sp = sizes["model"]  # act_seq sequence-parallel factor

    if rec["kind"] == "train":
        mb = 4 if cfg.fsdp else 1
        mom_bytes = 2 * p_bytes  # m and v, same sharding (dtype ~ param)
        carries = (cfg.num_layers * (B // dp) * S // sp * d * act) // max(mb, 1)
        logits = (B // dp) * S * (cfg.vocab_size // sizes["model"]) * act
        return (
            mb * 2 * p_bytes  # fwd + remat-fwd reads (bwd reuses)
            + p_bytes  # grad write
            + p_bytes + 2 * mom_bytes  # optimizer read+write
            + mb * 2 * carries
            + 3 * logits
        )
    if rec["kind"] == "prefill":
        cache = _cache_bytes(cfg, rec, sizes)
        stream = 2 * cfg.num_layers * (B // dp) * (S // sp) * d * act
        return p_bytes + cache + stream
    # decode
    cache = _cache_bytes(cfg, rec, sizes)
    return p_bytes + cache


def _cache_bytes(cfg, rec, sizes) -> int:
    from repro.distributed.steps import cache_axes_and_shapes

    axes_tree, shapes_tree = cache_axes_and_shapes(
        cfg, rec["global_batch"], rec["seq_len"]
    )
    import jax

    mesh = _StubMesh(sizes)
    # rules for cache include kv_seq sharding on long decode
    rules = dict(pt.BASE_RULES)
    if rec["shape"] == "long_500k":
        rules = pt.long_context_rules(rules)
    total = 0
    for axes, sds in zip(
        jax.tree_util.tree_leaves(axes_tree,
                                  is_leaf=lambda x: isinstance(x, tuple)),
        jax.tree_util.tree_leaves(shapes_tree),
    ):
        spec = pt.shape_aware_spec(axes, sds.shape, mesh, rules)
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry,) if isinstance(entry, str) else entry:
                shards *= sizes[ax]
        total += math.prod(sds.shape) * sds.dtype.itemsize // shards
    return total
