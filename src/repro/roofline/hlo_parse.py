"""Roofline terms from compiled (post-SPMD, per-device) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any model
scanned over layers under-reports FLOPs/bytes by ~num_layers.  This parser
walks the HLO call graph (entry -> fusions/calls/whiles), multiplies loop
bodies by their trip counts (recovered from the loop-condition constant),
and accumulates three per-device quantities:

  * flops            — 2*M*N*K for every dot, window*Ci*out for convs
  * hbm_bytes        — operands+outputs of top-level ops only (internal ops
                       of a fusion stay in registers/VMEM, they never touch
                       HBM — fusions are charged at their boundary)
  * collective_bytes — operand sizes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute
                       (also split per collective type)

Validated against ``cost_analysis`` on unrolled toy programs in
``tests/test_roofline.py``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["parse_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_ARRAY_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(\S+?)\s*=\s*(.*?)\s+([a-z][a-z0-9\-]*)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?([%\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)=\{?([^,}\s]+(?:,\s*[^,}\s]+)*)\}?")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_WINDOW_RE = re.compile(r"window=\{[^}]*size=([0-9x]+)")
_KERNEL_LABEL_RE = re.compile(r"dim_labels=[^_]+_([0-9a-z]+)->")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")


def _arrays(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, shape in _arrays(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # args + attributes (rest of line)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_type: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    while_trip_counts: List[int] = dataclasses.field(default_factory=list)

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k,
            self.hbm_bytes * k,
            self.collective_bytes * k,
            {t: b * k for t, b in self.collective_by_type.items()},
            int(self.collective_count * k),
            list(self.while_trip_counts),
        )

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.collective_bytes += other.collective_bytes
        for t, b in other.collective_by_type.items():
            self.collective_by_type[t] = self.collective_by_type.get(t, 0.0) + b
        self.collective_count += other.collective_count
        self.while_trip_counts.extend(other.while_trip_counts)


def _split_computations(text: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    current: Optional[str] = None
    entry_name: Optional[str] = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and "=" not in line.split("(")[0]:
            current = m.group(1).lstrip("%")
            comps[current] = []
            if line.startswith("ENTRY"):
                entry_name = current
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        om = _OP_RE.match(line)
        if om:
            comps[current].append(
                _Op(om.group(1).lstrip("%"), om.group(2), om.group(3), om.group(4))
            )
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _arg_region(args: str) -> str:
    """The operand region of an op line: everything up to the close paren
    that matches the op's open paren (attributes follow after)."""
    depth, end = 0, len(args)
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    return args[:end]


def _split_args(arg_str: str) -> List[str]:
    """Split an operand list on top-level commas only.

    Newer XLA prints bare operand names (``%x, %y``); older versions print
    inline types with layouts (``f32[64,128]{1,0} %x``) whose own commas
    must not split.
    """
    toks, depth, start = [], 0, 0
    for i, ch in enumerate(arg_str):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            toks.append(arg_str[start:i].strip())
            start = i + 1
    toks.append(arg_str[start:].strip())
    return [t for t in toks if t]


def _first_operand_shapes(op: _Op, table: Dict[str, str], n: int = 2):
    """Shapes of the first n operands, resolving names via the symbol table."""
    shapes = []
    for tok in _split_args(_arg_region(op.rest)):
        arrs = _arrays(tok)
        if arrs:  # operand written with inline type
            shapes.append(arrs[0])
        else:
            name = tok.lstrip("%")
            ts = table.get(name)
            if ts is not None:
                arrs = _arrays(ts)
                shapes.append(arrs[0] if arrs else None)
            else:
                shapes.append(None)
        if len(shapes) >= n:
            break
    return shapes


def _dot_flops(op: _Op, table: Dict[str, str]) -> float:
    out_arrays = _arrays(op.type_str)
    if not out_arrays:
        return 0.0
    out_elems = _prod(out_arrays[0][1])
    m = _DIMS_RE.search(op.rest)
    lhs = _first_operand_shapes(op, table, 1)
    contract = 1
    if m and lhs and lhs[0] is not None:
        dims = [int(d) for d in m.group(1).split(",") if d]
        shape = lhs[0][1]
        for d in dims:
            if d < len(shape):
                contract *= shape[d]
    return 2.0 * out_elems * contract


def _conv_flops(op: _Op, table: Dict[str, str]) -> float:
    out_arrays = _arrays(op.type_str)
    if not out_arrays:
        return 0.0
    out_elems = _prod(out_arrays[0][1])
    wm = _WINDOW_RE.search(op.rest)
    window = 1
    if wm:
        for d in wm.group(1).split("x"):
            window *= int(d)
    # input-feature size from the kernel operand + dim_labels (e.g. 01io)
    ci = 1
    km = _KERNEL_LABEL_RE.search(op.rest)
    ops = _first_operand_shapes(op, table, 2)
    if km and len(ops) > 1 and ops[1] is not None:
        labels = km.group(1)
        if "i" in labels:
            idx = labels.index("i")
            kshape = ops[1][1]
            if idx < len(kshape):
                ci = kshape[idx]
    return 2.0 * out_elems * window * ci


_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # containers: their bodies are charged, the op line is plumbing
    "while", "conditional", "call",
}


def _operand_names(op: _Op) -> List[str]:
    return _split_args(_arg_region(op.rest))


def _sliced_param_bytes(comps, called: str) -> Dict[int, float]:
    """Fusion params consumed ONLY via dynamic-slice: charge the slice size.

    This is what makes scanned weight stacks cost one layer's bytes per
    iteration instead of the whole (L, ...) stack.
    Returns {operand_index: effective_bytes}.
    """
    ops = comps.get(called, [])
    params: Dict[str, int] = {}
    for o in ops:
        if o.opcode == "parameter":
            m = re.match(r"(\d+)", o.rest)
            if m:
                params[o.name] = int(m.group(1))
    out: Dict[int, float] = {}
    for pname, pidx in params.items():
        consumers = [o for o in ops if o.opcode != "parameter"
                     and re.search(rf"%{re.escape(pname)}\b", o.rest)]
        if consumers and all(o.opcode == "dynamic-slice" for o in consumers):
            out[pidx] = float(sum(_nbytes(o.type_str) for o in consumers))
    return out


def _op_hbm_bytes(op: _Op, table: Dict[str, str], comps=None) -> float:
    if op.opcode in _SKIP_BYTES:
        return 0.0
    total = float(_nbytes(op.type_str))  # outputs
    sliced: Dict[int, float] = {}
    if op.opcode == "fusion" and comps is not None:
        for sub in _called(op):
            if sub in comps:
                sliced = _sliced_param_bytes(comps, sub)
                break
    for i, tok in enumerate(_operand_names(op)):
        if i in sliced:
            total += sliced[i]
            continue
        tok = tok.lstrip("%")
        arrs = _arrays(tok)
        if arrs:
            total += sum(_prod(s) * _DTYPE_BYTES[d] for d, s in arrs)
        else:
            ts = table.get(tok)
            if ts:
                total += _nbytes(ts)
    return total


def _trip_count(cond_ops: List[_Op]) -> int:
    """Largest integer constant in the loop condition (scan bound)."""
    best = 1
    for op in cond_ops:
        if op.opcode == "constant":
            m = re.match(r"(\d+)\)?", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _called(op: _Op) -> List[str]:
    names = []
    for m in _CALL_ATTR_RE.finditer(op.rest):
        for n in m.group(1).split(","):
            names.append(n.strip().lstrip("%"))
    return names


def parse_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found in HLO text")
    memo: Dict[str, HloCost] = {}

    def comp_cost(name: str, top_level: bool) -> HloCost:
        key = f"{name}|{top_level}"
        if key in memo:
            return memo[key]
        memo[key] = HloCost()  # cycle guard
        ops = comps.get(name, [])
        table = {o.name: o.type_str for o in ops}
        cost = HloCost()
        for op in ops:
            if op.opcode == "dot":
                cost.flops += _dot_flops(op, table)
            elif op.opcode == "convolution":
                cost.flops += _conv_flops(op, table)
            if top_level:
                cost.hbm_bytes += _op_hbm_bytes(op, table, comps)
            if op.opcode in _COLLECTIVES:
                b = float(_nbytes(op.type_str))
                cost.collective_bytes += b
                cost.collective_by_type[op.opcode] = (
                    cost.collective_by_type.get(op.opcode, 0.0) + b
                )
                cost.collective_count += 1
            # recurse into called computations
            if op.opcode == "while":
                body, condition = None, None
                for m in re.finditer(r"(body|condition)=%?([\w.\-]+)", op.rest):
                    if m.group(1) == "body":
                        body = m.group(2)
                    else:
                        condition = m.group(2)
                trips = _trip_count(comps.get(condition, [])) if condition else 1
                cost.while_trip_counts.append(trips)
                if body:
                    cost.add(comp_cost(body, top_level).scaled(trips))
            elif op.opcode in ("fusion", "call", "custom-call", "conditional",
                               "reduce", "map", "sort", "scatter", "select-and-scatter"):
                # fusion internals are NOT top-level (no HBM traffic)
                for sub in _called(op):
                    if sub in comps:
                        cost.add(comp_cost(sub, False))
        memo[key] = cost
        return cost

    return comp_cost("__entry__", True)
