"""Analytic MODEL_FLOPS: 6*N*D (dense) / 6*N_active*D (MoE).

The "useful work" yardstick for the §Roofline ratio
``MODEL_FLOPS / HLO_FLOPs`` — anything the compiled program computes above
this is remat recompute, replicated compute (e.g. attention heads that do
not divide the model axis), masked-out attention waste, or padding.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax

from repro.layers.params import ParamSpec
from repro.models.registry import get_model

__all__ = ["active_params", "model_flops"]


def _is_leaf(x):
    return isinstance(x, ParamSpec)


def active_params(cfg) -> Tuple[int, int]:
    """(total, active-per-token) parameter counts from the schema.

    Expert-stacked leaves (axes containing 'expert') contribute
    ``k / E`` of their size to the active count; everything else is fully
    active.  Embedding lookups are counted (they feed the residual stream);
    the unembedding matmul is part of every token's compute.
    """
    model = get_model(cfg)
    schema = model.schema(cfg)
    total = active = 0
    k_over_e = (
        cfg.experts_per_token / cfg.num_experts if cfg.is_moe else 1.0
    )
    for leaf in jax.tree_util.tree_leaves(schema, is_leaf=_is_leaf):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "expert" in leaf.axes:
            active += int(n * k_over_e)
        else:
            active += n
    return total, active


def model_flops(cfg, kind: str, global_batch: int, seq_len: int) -> float:
    """GLOBAL useful FLOPs for one step of the given kind.

    train   : 6 * N_active * (B*S)   (fwd 2ND + bwd 4ND, the MFU convention)
    prefill : 2 * N_active * (B*S)
    decode  : 2 * N_active * B       (one token per sequence)

    Attention's O(S^2) score FLOPs are intentionally excluded (standard
    6ND accounting) — they surface in the ratio as "non-model" compute.
    """
    _, n_active = active_params(cfg)
    if kind == "train":
        return 6.0 * n_active * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * n_active * global_batch * seq_len
    if kind == "decode":
        return 2.0 * n_active * global_batch
    raise ValueError(kind)
