"""§Roofline report generation from the dry-run artifacts.

Reads ``experiments/dryrun/*.json`` and emits the EXPERIMENTS.md tables:

  compute    = HLO_FLOPs / peak_FLOPs            (per chip, parsed w/ trips)
  memory     = HLO_bytes / HBM_bw                (per chip)
  collective = collective_bytes / ICI link bw    (per chip)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI,
16 GB HBM.  The roofline table is single-pod (256 chips); the multi-pod
pass appears in §Dry-run as compile evidence.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.roofline.model_flops import model_flops

__all__ = ["load_records", "roofline_row", "dryrun_table", "roofline_table"]

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s/link

# one-sentence improvement notes keyed by (dominant term, predicate)
def _note(arch: str, shape: str, dom: str, ratio: float) -> str:
    cfg = get_config(arch)
    heads_div = cfg.num_heads and cfg.num_heads % 16 == 0
    if dom == "collective":
        if cfg.is_moe:
            return ("MoE dispatch/combine einsums dominate the wire; a sorted "
                    "all-to-all (dropless) dispatch would cut collective bytes "
                    "several-fold.")
        return ("gradient/activation all-reduces dominate; int8-EF gradient "
                "compression (distributed.grad_sync) or wider microbatching "
                "amortises them.")
    if dom == "memory":
        if shape.startswith("decode") or shape.startswith("long"):
            return ("decode is KV/state-cache bandwidth bound (as expected at "
                    "batch 1-128); quantised (int8) cache or more model-axis "
                    "cache sharding moves it down.")
        return ("HBM-bound: fuse/limit fp32 materialisation and increase "
                "arithmetic intensity per pass (larger microbatch per chip).")
    # compute
    if not heads_div and cfg.uses_attention and cfg.attention != "mla":
        return (f"compute-bound with {cfg.num_heads} q-heads not divisible by "
                "the 16-way model axis -> attention runs replicated; padding "
                "heads to a multiple of 16 removes the replicated FLOPs "
                "(ratio {:.2f} shows the waste).".format(ratio))
    if ratio < 0.5:
        return ("compute-bound with low useful-FLOP ratio: remat recompute + "
                "causal-masked flash waste; block-sparse causal iteration and "
                "a lighter remat policy raise the ratio.")
    return ("compute-bound near the useful-FLOP budget; next wins are MXU "
            "alignment (pad small dims to 128) and collective overlap.")


def load_records(out_dir: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    from repro.launch.dryrun_lib import pick_rules
    from repro.roofline.analytic import analytic_hbm_bytes

    parsed = rec["parsed"]
    devices = rec["devices"]
    cfg = get_config(rec["arch"])
    t_compute = parsed["flops"] / PEAK_FLOPS
    # CPU-compiled HLO materialises converts/copies TPU fusion removes;
    # report the parsed number as an upper bound but judge the bottleneck
    # on the analytic (TPU-side) traffic model.
    hbm_analytic = analytic_hbm_bytes(rec, cfg, pick_rules(cfg, rec["shape"]))
    t_memory = hbm_analytic / HBM_BW
    t_memory_upper = parsed["hbm_bytes"] / HBM_BW
    t_coll = parsed["collective_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf_global = model_flops(cfg, rec["kind"], rec["global_batch"], rec["seq_len"])
    mf_dev = mf_global / devices
    ratio = mf_dev / parsed["flops"] if parsed["flops"] else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model time over the bound the chip actually hits
    frac = (mf_dev / PEAK_FLOPS) / bound if bound else 0.0
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "kind")},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_upper_s": t_memory_upper,
        "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops_per_dev": mf_dev,
        "hlo_flops_per_dev": parsed["flops"],
        "useful_ratio": ratio,
        "roofline_fraction": frac,
        "note": _note(rec["arch"], rec["shape"], dom, ratio),
    }


def _fmt_bytes(b: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if b >= div:
            return f"{b / div:.2f} {unit}"
    return f"{b:.0f} B"


def _fmt_t(t: float) -> str:
    if t >= 1:
        return f"{t:.2f} s"
    if t >= 1e-3:
        return f"{t * 1e3:.2f} ms"
    return f"{t * 1e6:.1f} us"


def dryrun_table(recs: List[Dict]) -> str:
    lines = [
        "| mesh | arch | shape | status | lower | compile | peak mem/dev | "
        "HLO flops/dev | coll bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "ok":
            mem = _fmt_bytes(r["memory"]["peak_estimate_bytes"])
            lines.append(
                f"| {r['mesh']} | {r['arch']} | {r['shape']} | ok | "
                f"{r['lower_seconds']}s | {r['compile_seconds']}s | {mem} | "
                f"{r['parsed']['flops']:.3g} | "
                f"{_fmt_bytes(r['parsed']['collective_bytes'])} |"
            )
        elif r["status"] == "skipped":
            lines.append(
                f"| {r['mesh']} | {r['arch']} | {r['shape']} | SKIP | - | - | "
                f"- | - | - |"
            )
        else:
            lines.append(
                f"| {r['mesh']} | {r['arch']} | {r['shape']} | ERROR | - | - |"
                f" - | - | {r.get('error', '')[:60]} |"
            )
    return "\n".join(lines)


def roofline_table(recs: List[Dict], mesh: str = "single_pod") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "MODEL/HLO flops | roofline frac | what would move it |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        row = roofline_row(r)
        if row is None:
            continue
        lines.append(
            f"| {row['arch']} | {row['shape']} | {_fmt_t(row['t_compute_s'])} | "
            f"{_fmt_t(row['t_memory_s'])} | {_fmt_t(row['t_collective_s'])} | "
            f"**{row['dominant']}** | {row['useful_ratio']:.3f} | "
            f"{row['roofline_fraction']:.3f} | {row['note']} |"
        )
    return "\n".join(lines)


def main():
    recs = load_records()
    print(dryrun_table(recs))
    print()
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
