"""repro.roofline"""
