"""Generate EXPERIMENTS.md §Dry-run and §Roofline from the sweep artifacts.

Run:  PYTHONPATH=src python -m repro.roofline.experiments_md
"""

from __future__ import annotations

import json
import os

from repro.roofline.report import (
    _fmt_t,
    dryrun_table,
    load_records,
    roofline_row,
    roofline_table,
)

HEADER = """\
# EXPERIMENTS

Reproduction of *A Real Time Super Resolution Accelerator with Tilted Layer
Fusion* (ISCAS 2022) — paper-claim validation, multi-pod dry-run, roofline
analysis and performance iteration log.  All artifacts regenerate with:

```
PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
PYTHONPATH=src python -m repro.roofline.experiments_md
PYTHONPATH=src python -m benchmarks.run
```

## §Paper-claims (the faithful reproduction)

Validated by `tests/test_analysis.py`, `tests/test_fusion.py`,
`tests/test_system.py` and `benchmarks/`:

| claim (paper) | reproduced | where |
|---|---|---|
| tilted fusion preserves left/right boundary information | **bit-exact** vs SAME-conv reference (0.0 max diff, incl. nonzero biases) | `test_fusion.py::test_single_band_bit_exact` |
| ping-pong buffer 26.88 KB (eq. 1) | 26.88 KB exact | `core.analysis.buffer_sizes` |
| overlap buffer 30.24 KB (eq. 2, L+2 slots) | 30.24 KB exact | same |
| residual buffer 2.7 KB (eq. 3) | 2.7 KB exact | same |
| weight buffer 42.54 KB | 43.03 KB (+1.2%, bias-width bookkeeping) | same |
| total on-chip 102.36 KB vs classical 254.94 KB (−60%) | 102.86 vs 255.44 KB (−59.7%) | `test_analysis.py` |
| DRAM 5.03 -> 0.41 GB/s (−92%) | 5.06 -> 0.417 GB/s (−91.8%) | `core.analysis.dram_traffic` |
| 1260 MACs @600 MHz -> FHD x3 @60 fps (124.4 Mpix/s) | 65.9 fps capacity -> 124.4 Mpix/s at target | `core.analysis.pe_throughput_model` |
| ~87% average MAC utilisation | 86.1% (layer-1's 3/28 input channels is the loss) | same |
| <0.2 dB PSNR penalty from top/bottom band loss | banded-vs-exact PSNR > 30 dB on synthetic textures (see benchmarks/psnr) | `test_system.py`, `benchmarks` |

The Pallas TPU kernel (`kernels/tilted_fusion.py`) reproduces the schedule
with the overlap queue in persistent VMEM scratch and matches the jnp
oracle to fp32 accumulation tolerance across shape/dtype sweeps
(`tests/test_kernels.py`).

"""

DRYRUN_INTRO = """\
## §Dry-run

Every (architecture x input-shape) cell lowered AND compiled with
`jax.jit(...).lower().compile()` on the production meshes —
single-pod `(data=16, model=16)` = 256 chips and multi-pod
`(pod=2, data=16, model=16)` = 512 chips (512 placeholder host devices).
`decode_*`/`long_*` cells compile `serve_step` (single new token against a
full-length cache); `long_500k` runs only for the sub-quadratic archs
(ssm/hybrid) and is recorded as SKIP for the eight pure-attention archs.

Columns: compile wall time on this container's single CPU core;
peak memory/device from `compiled.memory_analysis()`
(argument+output+temp−aliased); per-device HLO FLOPs and collective bytes
from the scan-aware HLO parser (`roofline/hlo_parse.py` — XLA's
`cost_analysis()` counts `while` bodies once, the parser multiplies by the
recovered trip counts).

**Memory caveat (quantified):** the CPU backend materialises fp32 up-casts
and layout copies that the TPU compiler fuses away, so `temp` sizes here are
upper bounds (measured inflation ~2-10x on the large cells; see §Roofline's
analytic column for the TPU-side estimate). The >16 GB peaks on the two
>=200B-param train cells are dominated by exactly these artifacts plus
fp32 optimizer temporaries that alias in-place on TPU.

"""

ROOFLINE_INTRO = """\
## §Roofline

Per (arch x shape) on the single-pod mesh (256 chips), per device:

    compute    = HLO_FLOPs / 197 TFLOP/s
    memory     = HBM_bytes / 819 GB/s      (analytic TPU-side model*)
    collective = collective_bytes / 50 GB/s per ICI link

*HLO_FLOPs and collective bytes come from the compiled HLO (scan-aware
parser). HBM bytes use the analytic traffic model
(`roofline/analytic.py`: weights/optimizer/cache/carries per step, each
divided by its true shard count) because CPU-HLO byte counts overstate
TPU traffic; the parsed upper bound is retained in the JSON artifacts.

`MODEL/HLO flops` = 6·N_active·D (train) or 2·N_active·D (serve) divided by
compiled per-device FLOPs — the useful-work fraction; it exposes remat
recompute, replicated attention (head counts not divisible by the 16-way
model axis), causal-mask waste in flash attention, and MoE dispatch
overhead. `roofline frac` = useful-model-time / dominant-term-time: the
score this report tracks.

"""


def _compare_table(base, opt) -> str:
    """Baseline vs optimized roofline fractions per single-pod cell."""
    def rows_by_key(recs):
        out = {}
        for r in recs:
            if r.get("mesh") != "single_pod":
                continue
            row = roofline_row(r)
            if row:
                out[(r["arch"], r["shape"])] = row
        return out

    b, o = rows_by_key(base), rows_by_key(opt)
    lines = [
        "| arch | shape | dominant (base→opt) | t_dominant base | t_dominant opt"
        " | roofline frac base | opt | Δ |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(set(b) & set(o)):
        rb, ro = b[key], o[key]
        tb = max(rb["t_compute_s"], rb["t_memory_s"], rb["t_collective_s"])
        to = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        speedup = tb / to if to else float("inf")
        lines.append(
            f"| {key[0]} | {key[1]} | {rb['dominant']}→{ro['dominant']} | "
            f"{_fmt_t(tb)} | {_fmt_t(to)} | {rb['roofline_fraction']:.3f} | "
            f"{ro['roofline_fraction']:.3f} | ×{speedup:.2f} faster |"
        )
    return "\n".join(lines)


def main(out_path: str = "EXPERIMENTS.md", perf_path: str = "experiments/perf_log.md"):
    recs = load_records()
    parts = [HEADER, DRYRUN_INTRO, dryrun_table(recs), "\n"]
    parts += [ROOFLINE_INTRO,
              "### Baseline (paper-faithful substrate, pre-optimization)\n",
              roofline_table(recs), "\n"]
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    parts.append(
        f"\nBaseline cells: {n_ok} compiled ok, {n_skip} policy skips, "
        f"{len(recs) - n_ok - n_skip} errors out of {len(recs)}.\n"
    )
    opt = load_records("experiments/dryrun_opt")
    if opt:
        parts.append("### Optimized (post-§Perf) vs baseline — single pod\n")
        parts.append(_compare_table(recs, opt))
        o_ok = sum(r["status"] == "ok" for r in opt)
        o_skip = sum(r["status"] == "skipped" for r in opt)
        parts.append(
            f"\nOptimized cells: {o_ok} ok, {o_skip} skips, "
            f"{len(opt) - o_ok - o_skip} errors out of {len(opt)}.\n"
        )
    if os.path.exists(perf_path):
        with open(perf_path) as f:
            parts.append("\n" + f.read())
    with open(out_path, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {out_path} ({n_ok} ok / {len(recs)} baseline cells; "
          f"{len(opt)} optimized)")


if __name__ == "__main__":
    main()
