"""Data-parallel gradient synchronisation with int8 error-feedback
compression (the distributed-optimization trick for bandwidth-bound DP).

Inside ``shard_map`` over the data axis each replica holds its local
gradient.  The compressed all-reduce:

  1. adds the carried error-feedback residual to the local gradient,
  2. agrees on a shared scale via a max-abs ``psum`` (scalars only),
  3. quantises to int8 and ``psum``s the int8 payload as int32,
  4. dequantises the mean and stores the local quantisation error as the
     next step's residual.

Wire traffic per step drops 4x (fp32) / 2x (bf16) to 1 byte/param plus one
scalar per leaf; error feedback keeps SGD/Adam convergence (tested on a
quadratic and a tiny LM in ``tests/test_grad_sync.py``).

This is the same int8 primitive the paper's accelerator uses for weights
(``core.quant``), applied to the DP axis — bandwidth economy at two scales.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["int8_ef_allreduce", "make_dp_grad_fn", "init_ef_state"]


def init_ef_state(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def int8_ef_allreduce(grads, ef, axis_name: str):
    """Per-leaf int8 error-feedback mean-all-reduce (inside shard_map)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        mean = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
        out = mean * (scale / n)
        new_e = gf - q.astype(jnp.float32) * scale  # local quantisation error
        return out.astype(g.dtype), new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs]),
        jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs]),
    )


def make_dp_grad_fn(loss_fn, mesh: Mesh, data_axis: str = "data",
                    compression: str = "int8_ef"):
    """Build grads(params, batch, ef) -> (loss, grads, ef') with explicit
    DP synchronisation under shard_map.

    ``loss_fn(params, batch) -> scalar`` is evaluated per data shard
    (params replicated, batch sharded on dim 0); gradients cross the data
    axis compressed (int8+EF) or raw (psum) for comparison.
    """
    if compression not in ("int8_ef", "none"):
        raise ValueError(compression)

    def local(params, batch, ef):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, data_axis)
        if compression == "int8_ef":
            grads, ef = int8_ef_allreduce(grads, ef, data_axis)
        else:
            grads = jax.lax.pmean(grads, data_axis)
        return loss, grads, ef

    def specs_like(tree, spec):
        return jax.tree_util.tree_map(lambda _: spec, tree)

    def fn(params, batch, ef):
        rep = P()
        in_specs = (
            specs_like(params, rep),
            specs_like(batch, P(data_axis)),
            specs_like(ef, rep),
        )
        out_specs = (rep, specs_like(params, rep), specs_like(ef, rep))
        return shard_map(
            local, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )(params, batch, ef)

    return fn
