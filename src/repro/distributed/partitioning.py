"""Logical-axis partitioning (MaxText-style rules, pjit/GSPMD execution).

Model code never names mesh axes; it tags tensors with *logical* axes
(``'batch'``, ``'embed'``, ``'heads'``, ``'expert'``, ...).  A rule table
maps logical axes onto the physical mesh:

    single pod : (data=16, model=16)
    multi-pod  : (pod=2, data=16, model=16)

``pshard`` inserts ``with_sharding_constraint`` when a mesh context is
active and is an identity otherwise — the same model code runs on one CPU
device (smoke tests) and lowers on 512 fake devices (dry-run).

Rule sets:
  * BASE_RULES      — DP over (pod, data); TP over model (heads/mlp/vocab/
                      experts); everything else replicated.
  * FSDP extension  — ``'embed' -> 'data'`` so large-arch weights and
                      optimizer state are ZeRO-3 sharded across the data
                      axis as well (required for the ≥200B configs to fit
                      16 GB/chip); enabled per-config via ``fsdp=True``.
  * ``'kv_seq' -> 'data'`` — sequence-sharded KV caches for long-context
    decode (flash-decode style; XLA inserts the partial-softmax collectives).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "BASE_RULES",
    "SR_RULES",
    "fsdp_rules",
    "sr_rules",
    "axis_rules",
    "current_mesh",
    "logical_to_spec",
    "pshard",
    "make_shardings",
]

MeshAxes = Union[None, str, Tuple[str, ...]]

# Logical axis -> mesh axes. 'pod' exists only in the multi-pod mesh; rules
# referencing missing mesh axes are filtered per-mesh in logical_to_spec.
BASE_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),  # DP: global batch over pods x data
    "vocab": "model",  # TP: embedding/logit vocab dim
    "heads": "model",  # TP: attention query heads
    "kv_heads": "model",  # TP: KV heads (GSPMD pads when |kv| < |model|)
    "mlp": "model",  # TP: FFN hidden
    "expert": "model",  # EP: MoE experts
    "expert_mlp": "model",  # expert hidden dim; -> 'data' in serve rules so
                            # expert weights shard /256 with no FSDP gathers
    "ssm_heads": "model",  # TP: SSM heads
    "ssm_pdim": "model",  # SSD per-head dim fallback (when heads % axis != 0)
    "embed": None,  # replicated unless FSDP
    "kv_lora": None,  # MLA compressed dim (small; replicated)
    "seq": None,  # activations: sequence (SP only where explicit)
    "act_seq": "model",  # residual stream between blocks: Megatron-style SP
                         # (saved scan carries shrink by the model-axis size)
    "kv_seq": None,  # KV-cache sequence (set to 'data' for long decode)
    "layers": None,  # scan axis (PP would map this)
    "head_dim": "model",  # fallback TP: weights/KV-caches shard the per-head
                          # dim when head counts don't divide the axis (the
                          # used-set makes this a no-op when heads sharded)
    "norm": None,
    "frontend": None,
}


def fsdp_rules(base: Optional[Dict[str, MeshAxes]] = None) -> Dict[str, MeshAxes]:
    """ZeRO-3: shard the weight 'embed' dim across the data axis too."""
    rules = dict(base or BASE_RULES)
    rules["embed"] = "data"
    return rules


def serve_rules(base: Optional[Dict[str, MeshAxes]] = None) -> Dict[str, MeshAxes]:
    """Inference: no optimizer state -> no FSDP; expert weights shard their
    hidden dim across 'data' instead (256-way residency, zero weight
    gathers — §Perf iteration 3, arctic-480b x decode_32k)."""
    rules = dict(base or BASE_RULES)
    rules["expert_mlp"] = "data"
    return rules


def long_context_rules(base: Optional[Dict[str, MeshAxes]] = None) -> Dict[str, MeshAxes]:
    """Sequence-shard KV caches across 'data' (long_500k decode, batch=1)."""
    rules = dict(base or BASE_RULES)
    rules["kv_seq"] = "data"
    return rules


# SR serving mesh (engine.sharding): frame batches are (N, H, W, C).  The
# batch dim rides the 'replica' axis only at the routing layer (ReplicaRouter
# dispatches whole micro-batches to one replica; compiled programs never see
# it), and row bands shard over 'bands'.  Width/channels stay replicated —
# the paper's tilted decomposition is row-wise, so the halo is row-only.
SR_RULES: Dict[str, MeshAxes] = {
    "sr_batch": "replica",
    "sr_rows": "bands",
    "sr_cols": None,
    "sr_chan": None,
}


def sr_rules() -> Dict[str, MeshAxes]:
    """Rule table for the SR serving mesh (fresh copy, safe to mutate)."""
    return dict(SR_RULES)


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, MeshAxes]] = None


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[Dict[str, MeshAxes]] = None):
    """Activate a mesh + rule table for pshard/make_shardings."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, dict(rules or BASE_RULES)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def logical_to_spec(
    axes: Sequence[Optional[str]],
    mesh: Optional[Mesh] = None,
    rules: Optional[Dict[str, MeshAxes]] = None,
) -> PartitionSpec:
    """Resolve logical axes to a PartitionSpec valid for the given mesh.

    Mesh axes not present in the mesh (e.g. 'pod' on the single-pod mesh)
    are dropped; a mesh axis may appear at most once, first logical axis
    wins (later claims fall back to replication).
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules or BASE_RULES
    mesh_axis_names = set(mesh.axis_names) if mesh is not None else set()
    used = set()
    spec = []
    for ax in axes:
        entry = rules.get(ax) if ax is not None else None
        if entry is None:
            spec.append(None)
            continue
        cand = (entry,) if isinstance(entry, str) else tuple(entry)
        cand = tuple(a for a in cand if a in mesh_axis_names and a not in used)
        used.update(cand)
        if not cand:
            spec.append(None)
        elif len(cand) == 1:
            spec.append(cand[0])
        else:
            spec.append(cand)
    return PartitionSpec(*spec)


def shape_aware_spec(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Optional[Mesh] = None,
    rules: Optional[Dict[str, MeshAxes]] = None,
) -> PartitionSpec:
    """Like :func:`logical_to_spec` but drops mesh axes that do not divide
    the corresponding dimension (e.g. 8 KV heads on a 16-way model axis ->
    replicated).  This keeps the BASELINE sharding valid everywhere; the
    §Perf pass measures what head-padding etc. buys back.
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules or BASE_RULES
    mesh_axis_names = set(mesh.axis_names) if mesh is not None else set()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else {}
    used = set()
    spec = []
    for ax, dim in zip(axes, shape):
        entry = rules.get(ax) if ax is not None else None
        if entry is None:
            spec.append(None)
            continue
        cand = (entry,) if isinstance(entry, str) else tuple(entry)
        kept, prod = [], 1
        for a in cand:
            if a in mesh_axis_names and a not in used and dim % (prod * sizes[a]) == 0:
                kept.append(a)
                prod *= sizes[a]
        used.update(kept)
        if not kept:
            spec.append(None)
        elif len(kept) == 1:
            spec.append(kept[0])
        else:
            spec.append(tuple(kept))
    return PartitionSpec(*spec)


def pshard(x, *axes: Optional[str]):
    """Tag intermediate activations with logical axes (identity off-mesh)."""
    if _CTX.mesh is None:
        return x
    spec = shape_aware_spec(axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec)
    )


def make_shardings(axes_tree, shapes_tree, mesh: Optional[Mesh] = None, rules=None):
    """(logical axes, ShapeDtypeStruct) pytrees -> NamedSharding pytree."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        raise ValueError("make_shardings requires a mesh (context or argument)")

    def one(axes, sds):
        return NamedSharding(mesh, shape_aware_spec(axes, sds.shape, mesh, rules))

    return jax.tree_util.tree_map(
        one, axes_tree, shapes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )
