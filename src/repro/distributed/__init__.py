"""Distribution: partitioning rules, step functions, gradient sync."""
