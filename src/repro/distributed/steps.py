"""Step-function factories: train / prefill / decode, plus their
logical-axis trees (the single source of truth for in/out_shardings).

All factories return closures free of Python-level dynamism so that
``jax.jit(...).lower(...)`` produces stable HLO for the dry-run, and the
same closures execute eagerly in smoke tests.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES
from repro.layers.params import param_axes, param_shapes
from repro.models.registry import get_model
from repro.optim.adamw import adamw_update, init_opt_state

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "train_state_axes",
    "train_state_shapes",
    "batch_axes",
    "cache_axes_and_shapes",
]


# ----------------------------------------------------------------------
# Train
# ----------------------------------------------------------------------
def make_train_step(cfg, tcfg):
    """(state, batch) -> (state, metrics). state = {params, opt}."""
    model = get_model(cfg)

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, cfg, batch
        )
        return loss, metrics, grads

    def train_step(state, batch):
        if tcfg.microbatches > 1:
            # gradient accumulation over the leading batch dim
            mb = tcfg.microbatches

            def split(x):
                b = x.shape[0]
                return x.reshape((mb, b // mb) + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mbatch):
                gsum = carry
                _, metrics, grads = compute_grads(state["params"], mbatch)
                gsum = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads
                )
                return gsum, metrics

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
            )
            gsum, metrics = jax.lax.scan(acc_body, zeros, micro)
            grads = jax.tree_util.tree_map(lambda g: g / mb, gsum)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        else:
            _, metrics, grads = compute_grads(state["params"], batch)

        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], state["params"], tcfg
        )
        metrics.update(opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def train_state_shapes(cfg, tcfg):
    model = get_model(cfg)
    p_shapes = param_shapes(model.schema(cfg), cfg.weight_dtype)
    mdt = jnp.dtype(tcfg.optimizer_dtype)
    mom = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p_shapes
    )
    return {
        "params": p_shapes,
        "opt": {"m": mom, "v": mom, "step": jax.ShapeDtypeStruct((), jnp.int32)},
    }


def train_state_axes(cfg):
    model = get_model(cfg)
    axes = param_axes(model.schema(cfg))
    return {
        "params": axes,
        "opt": {"m": axes, "v": axes, "step": ()},
    }


def init_train_state(cfg, tcfg, key):
    from repro.layers.params import init_params

    model = get_model(cfg)
    params = init_params(model.schema(cfg), key, cfg.weight_dtype)
    return {"params": params, "opt": init_opt_state(params, jnp.dtype(tcfg.optimizer_dtype))}


def batch_axes(cfg, shape_kind: str) -> Dict[str, Tuple]:
    """Logical axes for each batch entry (mirrors shapes.batch_specs)."""
    tok = ("batch", None)
    out: Dict[str, Tuple] = {}
    if shape_kind == "train":
        out = {"tokens": tok, "targets": tok, "mask": tok}
        if cfg.family == "vlm":
            out["frontend"] = ("batch", None, "embed")
        if cfg.family == "encdec":
            out["src"] = ("batch", None, "embed")
    elif shape_kind == "prefill":
        out = {"tokens": tok}
        if cfg.family == "vlm":
            out["frontend"] = ("batch", None, "embed")
        if cfg.family == "encdec":
            out["src"] = ("batch", None, "embed")
    elif shape_kind == "decode":
        out = {"tokens": tok}
    else:
        raise ValueError(shape_kind)
    return out


# ----------------------------------------------------------------------
# Serve
# ----------------------------------------------------------------------
def make_prefill_step(cfg):
    model = get_model(cfg)

    def prefill_step(params, batch, cache):
        return model.prefill(params, cfg, batch, cache)

    return prefill_step


def make_decode_step(cfg):
    model = get_model(cfg)

    def decode_step(params, tokens, cache, pos):
        return model.decode_step(params, cfg, tokens, cache, pos)

    return decode_step


def cache_axes_and_shapes(cfg, batch: int, max_len: int):
    model = get_model(cfg)
    if cfg.family == "encdec":
        cs = model.cache_schema(cfg, batch, max_len, enc_len=max_len)
    else:
        cs = model.cache_schema(cfg, batch, max_len)
    return param_axes(cs), param_shapes(cs, cfg.activation_dtype)
