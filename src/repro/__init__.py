"""repro — Tilted Layer Fusion (ISCAS 2022) as a JAX/TPU framework.

Reproduction + beyond of "A Real Time Super Resolution Accelerator with
Tilted Layer Fusion" (Huang, Hsu, Chang): the tilted layer-fusion dataflow
as a composable JAX module and Pallas TPU kernel, embedded in a multi-pod
training/serving framework with 10 assigned LM-family architectures.

Layout:
  repro.core         — the paper's contribution (tiling, fusion, analysis)
  repro.kernels      — Pallas TPU kernels + jnp oracles
  repro.models       — ABPN + transformer/MoE/SSM/enc-dec/VLM model zoo
  repro.layers       — shared NN layers
  repro.configs      — assigned architecture configs (``get_config``)
  repro.distributed  — partitioning rules, step functions, grad sync
  repro.data / repro.optim / repro.runtime — substrate
  repro.launch       — mesh, dry-run, train/serve CLIs
  repro.roofline     — compiled-HLO roofline analysis
"""

__version__ = "1.0.0"
