"""Rotary position embeddings (GPT-NeoX half-split convention)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["apply_rope"]


def _angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions (B, S) -> (B, S, dim/2) fp32 angles."""
    inv_freq = 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )  # (dim/2,)
    return positions.astype(jnp.float32)[..., None] * inv_freq


def apply_rope(
    x: jax.Array,  # (B, S, H, D) or (B, S, D)
    positions: jax.Array,  # (B, S)
    theta: float = 1e6,
) -> jax.Array:
    """Rotate the last dim; fp32 trig, output in x.dtype."""
    squeeze = x.ndim == 3
    if squeeze:
        x = x[:, :, None, :]
    d = x.shape[-1]
    ang = _angles(positions, d, theta)[:, :, None, :]  # (B, S, 1, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = out.astype(x.dtype)
    return out[:, :, 0, :] if squeeze else out
