"""Shared NN layers (functional, schema-declared parameters)."""
