"""Declarative parameter schemas.

Models describe parameters once — shape, *logical* sharding axes, and
initialiser — as a nested dict of :class:`ParamSpec`.  From that single
schema we derive:

* ``init_params``     — materialised arrays (CPU smoke tests, real training)
* ``param_shapes``    — ``ShapeDtypeStruct`` pytree (the dry-run never
                        allocates a single weight)
* ``param_axes``      — logical-axis pytree consumed by
                        ``distributed.partitioning`` to build NamedShardings

This is what lets the same model code run on 1 CPU device and lower on a
512-chip mesh without modification.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ParamSpec", "init_params", "param_shapes", "param_axes", "count_params", "stack_schema"]

Schema = Dict[str, Any]  # nested dict of ParamSpec


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones | embed
    scale: Optional[float] = None  # overrides the default fan-in scale
    dtype: Optional[str] = None  # overrides the model param dtype

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")

    def initializer(self, key: jax.Array, dtype) -> jax.Array:
        dtype = jnp.dtype(self.dtype) if self.dtype else dtype
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "embed":
            scale = self.scale if self.scale is not None else 1.0
            return (jax.random.normal(key, self.shape) * scale).astype(dtype)
        if self.init == "normal":
            # fan-in scaled: contract dims = all but the last, excluding
            # stacking dims ('layers' for scan, 'expert' for MoE) which are
            # batch-like, not contracting.
            fan_in = 1
            for dim, ax in zip(self.shape[:-1], self.axes[:-1]):
                if ax not in ("layers", "expert"):
                    fan_in *= dim
            fan_in = fan_in or 1
            scale = self.scale if self.scale is not None else 1.0 / math.sqrt(fan_in)
            return (jax.random.normal(key, self.shape) * scale).astype(dtype)
        raise ValueError(f"unknown init {self.init!r}")


def _is_leaf(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(schema: Schema, key: jax.Array, dtype=jnp.float32):
    """Materialise a schema into arrays with per-leaf folded keys."""
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=_is_leaf)
    out = []
    for i, spec in enumerate(leaves):
        out.append(spec.initializer(jax.random.fold_in(key, i), dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shapes(schema: Schema, dtype=jnp.float32):
    """ShapeDtypeStruct pytree — used by the multi-pod dry-run."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.dtype(s.dtype) if s.dtype else dtype
        ),
        schema,
        is_leaf=_is_leaf,
    )


def param_axes(schema: Schema):
    """Logical-axis pytree (tuples), same structure as the params."""
    return jax.tree_util.tree_map(lambda s: s.axes, schema, is_leaf=_is_leaf)


def count_params(schema: Schema) -> int:
    leaves = jax.tree_util.tree_leaves(schema, is_leaf=_is_leaf)
    return sum(math.prod(s.shape) for s in leaves)


def stack_schema(schema: Schema, num: int, axis_name: str = "layers") -> Schema:
    """Prepend a stacking dim to every leaf (for lax.scan over layers)."""
    return jax.tree_util.tree_map(
        lambda s: dataclasses.replace(
            s, shape=(num,) + s.shape, axes=(axis_name,) + s.axes
        ),
        schema,
        is_leaf=_is_leaf,
    )
