"""Mixture-of-Experts: top-k router + capacity-based GShard dispatch.

Supports the two assigned MoE archs:
  * arctic-480b   — 128 experts, top-2, plus a *dense residual* MLP in
                    parallel with the MoE output (added, not routed);
  * deepseek-v2   — 160 routed experts top-6 plus 2 *shared* experts that
                    process every token; first layer dense.

Dispatch is the einsum/capacity formulation: per sequence, each expert
accepts at most ``capacity = ceil(S * k / E * capacity_factor)`` tokens;
overflow tokens are dropped (their contribution is the identity residual).
Experts are sharded over the ``model`` axis (EP); the dispatch einsums
produce the token shuffles as GSPMD collectives.  A sorted all-to-all
("dropless") path is a §Perf follow-up — see EXPERIMENTS.md.

Router numerics: fp32 logits, softmax-then-top-k, gates renormalised over
the selected experts. Aux losses: Switch-style load-balance + router
z-loss, both returned as metrics for the train step to weight in.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import pshard
from repro.layers.common import act_fn
from repro.layers.params import ParamSpec

__all__ = ["moe_schema", "moe_block", "capacity"]


def moe_schema(cfg) -> dict:
    d, e = cfg.d_model, cfg.num_experts
    f = cfg.moe_d_ff or cfg.d_ff
    s = {
        "router": ParamSpec((d, e), ("embed", "expert"), dtype="float32"),
        "wi": ParamSpec((e, d, f), ("expert", "embed", "expert_mlp")),
        "wg": ParamSpec((e, d, f), ("expert", "embed", "expert_mlp")),
        "wo": ParamSpec((e, f, d), ("expert", "expert_mlp", "embed")),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        s["shared"] = {
            "wi": ParamSpec((d, fs), ("embed", "mlp")),
            "wg": ParamSpec((d, fs), ("embed", "mlp")),
            "wo": ParamSpec((fs, d), ("mlp", "embed")),
        }
    return s


def capacity(cfg, seq_len: int) -> int:
    cap = math.ceil(seq_len * cfg.experts_per_token / cfg.num_experts
                    * cfg.capacity_factor)
    return max(cap, cfg.experts_per_token)


def _router(p, cfg, x) -> Tuple[jax.Array, jax.Array, dict]:
    """-> (probs (B,S,E) fp32, top-k (gates, idx), aux metrics)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)  # (B,S,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch load-balance loss: E * sum_e f_e * P_e, f normalised by k so
    # perfectly balanced routing scores exactly 1.0
    e = cfg.num_experts
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (B,S,k,E)
    f_e = onehot.sum(axis=2).mean(axis=(0, 1)) / cfg.experts_per_token
    p_e = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    metrics = {"moe_aux_loss": aux, "moe_z_loss": z,
               "moe_expert_frac_max": f_e.max()}
    return probs, (gates, idx, onehot), metrics


def _moe_decode_dense(p, cfg, x, gates, onehot):
    """Decode-time (S==1) path: masked dense expert compute.

    §Perf change (arctic-480b x decode_32k): the capacity-dispatch einsums
    reshard (tokens x experts) layouts through multi-GB collectives to move
    ONE token per sequence.  At S==1 it is far cheaper for every expert
    shard to run its local experts over the whole (tiny) token batch and
    weight the results by the routing gates — the only cross-shard traffic
    left is the (B, 1, d)-sized partial-sum reduction GSPMD inserts at the
    output.  Dropless by construction (no capacity buffers).
    """
    act = act_fn(cfg.mlp_act)
    # (B, S, E) combined gate per expert (0 for unrouted experts)
    gate_map = (onehot * gates.astype(onehot.dtype)[..., None]).sum(axis=2)
    gate_map = gate_map.astype(x.dtype)
    # Replicate the (tiny: B x d) token batch so the experts' data-sharded
    # hidden dim ('expert_mlp' -> 'data' under serve_rules) never conflicts
    # with a data-sharded batch — otherwise GSPMD re-gathers the expert
    # WEIGHTS every layer (measured: 117 GB/step; iteration-3 refutation).
    x = pshard(x, None, None, None)
    h = act(jnp.einsum("bsd,edf->ebsf", x, p["wg"].astype(x.dtype))) * jnp.einsum(
        "bsd,edf->ebsf", x, p["wi"].astype(x.dtype)
    )
    h = pshard(h, "expert", None, None, "expert_mlp")
    out = jnp.einsum("ebsf,efd->ebsd", h, p["wo"].astype(x.dtype))
    out = pshard(out, "expert", None, None, None)
    y = jnp.einsum("ebsd,bse->bsd", out, pshard(gate_map, None, None, None))
    return pshard(y, "batch", None, None)


def moe_block(p: dict, cfg, x: jax.Array) -> Tuple[jax.Array, dict]:
    """x (B,S,d) -> (y (B,S,d), aux metrics)."""
    B, S, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = capacity(cfg, S)
    act = act_fn(cfg.mlp_act)

    _, (gates, idx, onehot), metrics = _router(p, cfg, x)

    if S == 1:  # decode: masked dense path (see _moe_decode_dense)
        y = _moe_decode_dense(p, cfg, x, gates, onehot)
        if cfg.num_shared_experts:
            sp = p["shared"]
            g = act(jnp.einsum("bsd,df->bsf", x, sp["wg"].astype(x.dtype)))
            hs = g * jnp.einsum("bsd,df->bsf", x, sp["wi"].astype(x.dtype))
            y = y + jnp.einsum("bsf,fd->bsd", hs, sp["wo"].astype(x.dtype))
        metrics["moe_dropped_frac"] = jnp.zeros(())
        return pshard(y, "batch", "seq", "embed"), metrics

    # Position of each (token, choice) in its expert's buffer; drop overflow.
    # pos[b,s,j] = number of earlier claims on expert idx[b,s,j] in sequence b
    claims = onehot.reshape(B, S * k, e)
    pos = (jnp.cumsum(claims, axis=1) - claims).reshape(B, S, k, e)
    pos = (pos * onehot).sum(-1)  # (B,S,k) buffer slot for the chosen expert
    keep = pos < cap
    gates = gates * keep

    # combine[b,s,e,c]: gate if token (b,s) occupies slot c of expert e.
    # Contract k FIRST: einsum('bske,bskc->bsec') is a batched (E x k)@(k x C)
    # matmul — a 3-operand einsum here materialises a (B,S,k,E,C) intermediate
    # (tens of GB/device at deepseek scale).
    slot = jax.nn.one_hot(pos, cap, dtype=x.dtype) * keep[..., None]
    gated = onehot.astype(x.dtype) * gates.astype(x.dtype)[..., None]
    combine = jnp.einsum("bske,bskc->bsec", gated, slot)
    combine = pshard(combine, "batch", "seq", "expert", None)
    dispatch = (combine > 0).astype(x.dtype)

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch, x)  # (E,B,cap,d)
    xin = pshard(xin, "expert", "batch", None, None)
    h = act(jnp.einsum("ebcd,edf->ebcf", xin, p["wg"].astype(x.dtype))) * jnp.einsum(
        "ebcd,edf->ebcf", xin, p["wi"].astype(x.dtype)
    )
    h = pshard(h, "expert", "batch", None, "mlp")
    xout = jnp.einsum("ebcf,efd->ebcd", h, p["wo"].astype(x.dtype))
    xout = pshard(xout, "expert", "batch", None, None)
    y = jnp.einsum("bsec,ebcd->bsd", combine, xout)

    if cfg.num_shared_experts:
        sp = p["shared"]
        g = act(jnp.einsum("bsd,df->bsf", x, sp["wg"].astype(x.dtype)))
        h = g * jnp.einsum("bsd,df->bsf", x, sp["wi"].astype(x.dtype))
        y = y + jnp.einsum("bsf,fd->bsd", h, sp["wo"].astype(x.dtype))

    metrics["moe_dropped_frac"] = 1.0 - keep.mean()
    return pshard(y, "batch", "act_seq", "embed"), metrics
