"""Dense MLP blocks: gated SwiGLU (llama/qwen style) or plain 2-layer."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import pshard
from repro.layers.common import act_fn
from repro.layers.params import ParamSpec

__all__ = ["mlp_schema", "mlp_block"]


def mlp_schema(cfg, d_ff=None, gated=None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    gated = cfg.mlp_act == "silu" if gated is None else gated
    s = {
        "wi": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }
    if gated:
        s["wg"] = ParamSpec((d, f), ("embed", "mlp"))
    return s


def mlp_block(p: dict, cfg, x: jax.Array) -> jax.Array:
    act = act_fn(cfg.mlp_act)
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    if "wg" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    h = pshard(h, "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
    return pshard(y, "batch", "act_seq", "embed")
