"""Shared NN primitives (norms, embeddings, losses) — functional style."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm", "layernorm", "embed_lookup", "cross_entropy", "silu", "act_fn"]


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 statistics (matches HF Qwen/DeepSeek numerics)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * scale.astype(jnp.float32)).astype(dt)


def layernorm(
    x: jax.Array, scale: jax.Array, bias: Optional[jax.Array], eps: float = 1e-6
) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def embed_lookup(embedding: jax.Array, ids: jax.Array, dtype=None) -> jax.Array:
    out = jnp.take(embedding, ids, axis=0)
    return out.astype(dtype) if dtype is not None else out


def silu(x):
    return x * jax.nn.sigmoid(x)


def act_fn(name: str):
    return {"silu": silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def cross_entropy(
    logits: jax.Array,  # (B, S, V)
    targets: jax.Array,  # (B, S) int32
    mask: Optional[jax.Array] = None,  # (B, S) {0,1}
):
    """Masked mean token cross-entropy with fp32 log-softmax.

    Returns (loss, metrics) where metrics carries token counts and z-stats.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / total
    metrics = {
        "loss": loss,
        "tokens": total,
        "z_mean": (logz * mask).sum() / total,
    }
    return loss, metrics
