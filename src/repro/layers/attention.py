"""Attention: GQA with flash-style chunked softmax, plus cached decode.

Design notes:

* GQA is computed in *grouped* layout — q ``(B, S, Kh, G, D)`` against
  un-replicated kv ``(B, S, Kh, D)`` — KV heads are never materially
  repeated.
* Long sequences use an online-softmax over KV chunks (``lax.scan`` carry =
  running max / normaliser / accumulator).  This keeps activation memory
  O(S · chunk) instead of O(S^2) — required for the ``prefill_32k`` cells —
  and is itself an instance of the paper's streaming-with-carried-state
  pattern (DESIGN.md §5).  Causality is enforced by masking; chunks fully
  in the future contribute -inf scores and wash out of the online softmax.
* Decode attends one query position against a (possibly sequence-sharded)
  KV cache; with the ``kv_seq -> data`` rule this becomes flash-decode:
  GSPMD turns the softmax reductions into cross-shard collectives.
* V head dim may differ from QK head dim (MLA reuses this kernel with
  D_qk=192, D_v=128).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import pshard
from repro.layers.common import rmsnorm
from repro.layers.params import ParamSpec
from repro.layers.rope import apply_rope

__all__ = [
    "gqa_schema",
    "flash_attention",
    "decode_attention",
    "attention_block",
    "init_kv_cache_spec",
]

NEG_INF = -1e30


# ----------------------------------------------------------------------
# Parameter schema
# ----------------------------------------------------------------------
def gqa_schema(cfg) -> dict:
    d, h, kh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = {
        "wq": ParamSpec((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kh, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kh, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((h, dh), ("heads", "head_dim"), init="zeros")
        s["bk"] = ParamSpec((kh, dh), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = ParamSpec((kh, dh), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((dh,), ("norm",), init="ones")
        s["k_norm"] = ParamSpec((dh,), ("norm",), init="ones")
    return s


# ----------------------------------------------------------------------
# Flash-style chunked attention (training / prefill)
#
# custom_vjp with recompute-in-backward: the forward saves only
# (q, k, v, out, m, l) — O(S*d) — and the backward re-materialises each
# KV chunk's probabilities from the saved softmax statistics.  Without
# this, scan residuals store every chunk's p-matrix and activation memory
# degenerates to O(S^2) (observed: 870 GB/device on qwen2-0.5b train_4k).
# ----------------------------------------------------------------------
def _chunk_mask(q_pos, ki, ck, Sk, causal):
    k_pos = ki * ck + jnp.arange(ck, dtype=q_pos.dtype)
    mask = k_pos[None, :] < Sk  # real (un-padded) KV positions
    if causal:
        mask = mask & (q_pos[:, None] >= k_pos[None, :])
    return mask  # (Sq, ck)


def _flash_fwd_core(q, k, v, q_pos, causal, chunk):
    B, Sq, Kh, G, Dqk = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]
    scale = 1.0 / jnp.sqrt(Dqk).astype(jnp.float32)
    ck = min(chunk, Sk)
    pad = (-Sk) % ck
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = (Sk + pad) // ck
    kc = k.reshape(B, nk, ck, Kh, Dqk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, ck, Kh, Dv).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32) * scale

    def kv_step(carry, inputs):
        m, l, acc = carry
        ki, k_blk, v_blk = inputs
        s = jnp.einsum("bqkgd,bskd->bqkgs", qf, k_blk.astype(jnp.float32))
        mask = _chunk_mask(q_pos, ki, ck, Sk, causal)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgs,bskd->bqkgd", p, v_blk.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, Sq, Kh, G), NEG_INF, jnp.float32),
        jnp.zeros((B, Sq, Kh, G), jnp.float32),
        jnp.zeros((B, Sq, Kh, G, Dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(kv_step, init, (jnp.arange(nk), kc, vc))
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return out.astype(q.dtype), m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _flash(q, k, v, q_pos, causal, chunk):
    out, _, _ = _flash_fwd_core(q, k, v, q_pos, causal, chunk)
    return out


def _flash_fwd(q, k, v, q_pos, causal, chunk):
    out, m, l = _flash_fwd_core(q, k, v, q_pos, causal, chunk)
    return out, (q, k, v, q_pos, out, m, l)


def _flash_bwd(causal, chunk, res, g):
    q, k, v, q_pos, out, m, l = res
    B, Sq, Kh, G, Dqk = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]
    scale = 1.0 / jnp.sqrt(Dqk).astype(jnp.float32)
    ck = min(chunk, Sk)
    pad = (-Sk) % ck
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    nk = (Sk + pad) // ck
    kc = kp.reshape(B, nk, ck, Kh, Dqk).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, nk, ck, Kh, Dv).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32) * scale
    gf = g.astype(jnp.float32)
    l_safe = jnp.maximum(l, 1e-37)
    delta = jnp.sum(gf * out.astype(jnp.float32), axis=-1)  # (B,Sq,Kh,G)

    def kv_step(dq_acc, inputs):
        ki, k_blk, v_blk = inputs
        kb = k_blk.astype(jnp.float32)
        vb = v_blk.astype(jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qf, kb)
        mask = _chunk_mask(q_pos, ki, ck, Sk, causal)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - m[..., None]) / l_safe[..., None]
        dv_blk = jnp.einsum("bqkgs,bqkgd->bskd", p, gf)
        dp = jnp.einsum("bqkgd,bskd->bqkgs", gf, vb)
        ds = p * (dp - delta[..., None])
        dq_acc = dq_acc + jnp.einsum("bqkgs,bskd->bqkgd", ds, kb) * scale
        dk_blk = jnp.einsum("bqkgs,bqkgd->bskd", ds, qf)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, Sq, Kh, G, Dqk), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(kv_step, dq0, (jnp.arange(nk), kc, vc))
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(B, nk * ck, Kh, Dqk)[:, :Sk]
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(B, nk * ck, Kh, Dv)[:, :Sk]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            jnp.zeros_like(q_pos))


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,  # (B, Sq, Kh, G, Dqk)
    k: jax.Array,  # (B, Sk, Kh, Dqk)
    v: jax.Array,  # (B, Sk, Kh, Dv)
    *,
    causal: bool = True,
    q_offset: int = 0,
    chunk: int = 1024,
    q_chunk: int = 512,
) -> jax.Array:  # (B, Sq, Kh, G, Dv)
    """2-D tiled flash attention: KV chunks inside, Q chunks outside.

    The Q tiling (lax.scan over query blocks) bounds every score block to
    (B, q_chunk, H, kv_chunk) fp32; cotangents for the closed-over K/V are
    summed across Q blocks by scan's transpose rule automatically.
    Query positions travel as an fp32 array (exact for positions < 2^24)
    so the custom VJP needs no traced static arguments.
    """
    B, Sq, Kh, G, Dqk = q.shape
    Sk = k.shape[1]
    kv_chunk = min(chunk, Sk)
    q_pos_all = (q_offset + jnp.arange(Sq)).astype(jnp.float32)
    cq = min(q_chunk, Sq)
    if Sq % cq:  # pad Q; padded rows attend to position 0 only, then dropped
        padq = (-Sq) % cq
        q = jnp.pad(q, ((0, 0), (0, padq), (0, 0), (0, 0), (0, 0)))
        q_pos_all = jnp.pad(q_pos_all, (0, padq))
        Sq_p = Sq + padq
    else:
        Sq_p = Sq
    nq = Sq_p // cq
    if nq == 1:
        return _flash(q, k, v, q_pos_all, causal, kv_chunk)[:, :Sq]
    qb = q.reshape(B, nq, cq, Kh, G, Dqk).transpose(1, 0, 2, 3, 4, 5)
    pb = q_pos_all.reshape(nq, cq)

    def q_step(_, inp):
        q_blk, pos_blk = inp
        return None, _flash(q_blk, k, v, pos_blk, causal, kv_chunk)

    _, ob = jax.lax.scan(q_step, None, (qb, pb))
    out = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq_p, Kh, G, v.shape[-1])
    return out[:, :Sq]


# ----------------------------------------------------------------------
# Cached decode attention (one query position)
# ----------------------------------------------------------------------
def decode_attention(
    q: jax.Array,  # (B, 1, Kh, G, Dqk)
    k_cache: jax.Array,  # (B, Smax, Kh, Dqk)
    v_cache: jax.Array,  # (B, Smax, Kh, Dv)
    pos: jax.Array,  # scalar: current position (cache filled through pos)
) -> jax.Array:  # (B, 1, Kh, G, Dv)
    Dqk = q.shape[-1]
    Smax = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(Dqk).astype(jnp.float32)
    s = jnp.einsum(
        "bqkgd,bskd->bqkgs", q.astype(jnp.float32) * scale, k_cache.astype(jnp.float32)
    )
    valid = jnp.arange(Smax) <= pos
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# ----------------------------------------------------------------------
# Full block (projections + rope + norm + cache plumbing)
# ----------------------------------------------------------------------
def _project_qkv(p, cfg, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def init_kv_cache_spec(cfg, batch: int, max_len: int):
    """(shape, dtype, logical axes) for one layer's K and V caches."""
    kh, dh = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, max_len, kh, dh)
    axes = ("batch", "kv_seq", "kv_heads", "head_dim")
    return shape, cfg.activation_dtype, axes


def attention_block(
    p: dict,
    cfg,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (B, S)
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,
    mode: str = "train",
):
    """Returns (y, new_cache). Modes: train | prefill | decode."""
    B, S, d = x.shape
    h, kh = cfg.num_heads, cfg.num_kv_heads
    G = h // kh
    q, k, v = _project_qkv(p, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = pshard(q.reshape(B, S, kh, G, cfg.head_dim), "batch", "seq", "kv_heads", None, None)
    k = pshard(k, "batch", "seq", "kv_heads", None)  # in-flight: Dh replicated
    v = pshard(v, "batch", "seq", "kv_heads", None)

    new_cache = None
    if mode in ("train", "prefill"):
        out = flash_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        if mode == "prefill":
            kc, vc = cache  # pre-allocated (B, Smax, Kh, Dh)
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, 0, 0, 0))
            new_cache = (pshard(kc, "batch", "kv_seq", "kv_heads", "head_dim"),
                         pshard(vc, "batch", "kv_seq", "kv_heads", "head_dim"))
    elif mode == "decode":
        kc, vc = cache
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, cache_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, cache_pos, 0, 0))
        kc = pshard(kc, "batch", "kv_seq", "kv_heads", "head_dim")
        vc = pshard(vc, "batch", "kv_seq", "kv_heads", "head_dim")
        out = decode_attention(q, kc, vc, cache_pos)
        new_cache = (kc, vc)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    out = out.reshape(B, S, h, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return pshard(y, "batch", "act_seq", "embed"), new_cache
