"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Queries and KV are low-rank compressed; only the compressed KV latent
(``kv_lora_rank`` = 512) plus a small decoupled-RoPE key (64 dims, shared
across heads) are cached.  Per-head dims: 128 "nope" + 64 rope for QK,
128 for V.

Two execution forms, numerically identical (tested):
  * train/prefill — decompress K/V to per-head form, run the shared
    flash-attention kernel with D_qk = nope+rope = 192, D_v = 128;
  * decode        — *absorbed* form: W_uk is folded into the query and W_uv
    into the output so attention runs directly in the 512-dim compressed
    space; per-token cache traffic is 576 bytes·dtype instead of
    2·128·128·2 — the reason MLA serves long contexts cheaply.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import pshard
from repro.layers.attention import flash_attention
from repro.layers.common import rmsnorm
from repro.layers.params import ParamSpec
from repro.layers.rope import apply_rope

__all__ = ["mla_schema", "mla_block", "init_mla_cache_spec"]

NEG_INF = -1e30


def mla_schema(cfg) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": ParamSpec((d, r_q), ("embed", None)),
        "q_norm": ParamSpec((r_q,), ("norm",), init="ones"),
        "wq_b": ParamSpec((r_q, h, dn + dr), (None, "heads", "head_dim")),
        "wkv_a": ParamSpec((d, r_kv + dr), ("embed", "kv_lora")),
        "kv_norm": ParamSpec((r_kv,), ("norm",), init="ones"),
        "wk_b": ParamSpec((r_kv, h, dn), ("kv_lora", "heads", "head_dim")),
        "wv_b": ParamSpec((r_kv, h, dv), ("kv_lora", "heads", "head_dim")),
        "wo": ParamSpec((h, dv, d), ("heads", "head_dim", "embed")),
    }


def init_mla_cache_spec(cfg, batch: int, max_len: int):
    """Cache = compressed latent (r_kv) ++ rope key (dr) per position."""
    shape = (batch, max_len, cfg.kv_lora_rank + cfg.rope_head_dim)
    axes = ("batch", "kv_seq", "kv_lora")
    return shape, cfg.activation_dtype, axes


def _compress(p, cfg, x, positions):
    """-> (q_nope (B,S,H,dn), q_rope (B,S,H,dr), c_kv (B,S,r), k_rope (B,S,dr))."""
    dn, dr = cfg.head_dim, cfg.rope_head_dim
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype)),
                 p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    c_kv = rmsnorm(ckv[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(ckv[..., cfg.kv_lora_rank :], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_block(
    p: dict,
    cfg,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,
    cache: Optional[jax.Array] = None,  # (B, Smax, r_kv + dr)
    cache_pos: Optional[jax.Array] = None,
    mode: str = "train",
):
    B, S, d = x.shape
    h, dn, dr, dv, r = (cfg.num_heads, cfg.head_dim, cfg.rope_head_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    q_nope, q_rope, c_kv, k_rope = _compress(p, cfg, x, positions)
    new_cache = None

    if mode in ("train", "prefill"):
        # Decompressed form: concat nope+rope into a 192-dim QK space.
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"].astype(x.dtype))
        v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"].astype(x.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, h, dr))], -1
        )
        q = jnp.concatenate([q_nope, q_rope], -1)  # (B,S,h,dn+dr)
        q = pshard(q[:, :, :, None, :], "batch", "seq", "heads", None, None)
        k = pshard(k, "batch", "seq", "heads", "head_dim")
        v = pshard(v, "batch", "seq", "heads", "head_dim")
        out = flash_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        out = out[:, :, :, 0, :]  # (B,S,h,dv)
        if mode == "prefill":
            packed = jnp.concatenate([c_kv, k_rope], -1).astype(cache.dtype)
            new_cache = jax.lax.dynamic_update_slice(cache, packed, (0, 0, 0))
            new_cache = pshard(new_cache, "batch", "kv_seq", "kv_lora")
    elif mode == "decode":
        # Absorbed form: attention entirely in the compressed space.
        packed = jnp.concatenate([c_kv, k_rope], -1).astype(cache.dtype)
        cache = jax.lax.dynamic_update_slice(cache, packed, (0, cache_pos, 0))
        cache = pshard(cache, "batch", "kv_seq", "kv_lora")
        new_cache = cache
        ckv_cache, krope_cache = cache[..., :r], cache[..., r:]
        # fold W_uk into q:   q_eff = q_nope @ W_uk  -> (B,1,h,r)
        q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"].astype(x.dtype))
        scale = 1.0 / jnp.sqrt(dn + dr).astype(jnp.float32)
        s = (
            jnp.einsum("bshr,btr->bhst", q_eff.astype(jnp.float32),
                       ckv_cache.astype(jnp.float32))
            + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                         krope_cache.astype(jnp.float32))
        ) * scale
        valid = jnp.arange(cache.shape[1]) <= cache_pos
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        attn = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", attn, ckv_cache.astype(jnp.float32))
        # fold W_uv into the output
        out = jnp.einsum("bshr,rhk->bshk", ctx.astype(x.dtype),
                         p["wv_b"].astype(x.dtype))
    else:
        raise ValueError(f"unknown mode {mode!r}")

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return pshard(y, "batch", "act_seq", "embed"), new_cache
