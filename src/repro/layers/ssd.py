"""Mamba2 block via SSD — state-space duality (arXiv:2405.21060).

The chunked SSD algorithm is the sequence-axis instance of the paper's
tilted-fusion insight (DESIGN.md §5): the sequence is cut into chunks
("column tiles"); within a chunk the quadratic dual form runs entirely
in fast memory; the only thing carried between chunks is the per-head
state ``(P, N)`` — the overlap buffer of this dataflow.

Layers:
  * :func:`ssd_chunked`    — training/prefill: intra-chunk dual form +
                             inter-chunk state scan; returns final state.
  * :func:`ssd_reference`  — naive recurrence (the numerical oracle).
  * :func:`ssd_decode_step`— O(1) cached decode.
  * :func:`mamba_block`    — full block: projections, causal conv, gating.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import pshard
from repro.layers.common import rmsnorm, silu
from repro.layers.params import ParamSpec

__all__ = [
    "ssd_chunked",
    "ssd_reference",
    "ssd_decode_step",
    "mamba_schema",
    "mamba_block",
    "init_ssm_cache_spec",
]


# ----------------------------------------------------------------------
# SSD core
# ----------------------------------------------------------------------
def _segsum(x: jax.Array) -> jax.Array:
    """(..., Q) -> (..., Q, Q) with [t, s] = sum_{r in (s, t]} x_r (t >= s)."""
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    q = x.shape[-1]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)   (already softplus'd)
    A: jax.Array,  # (H,)        (negative)
    Bm: jax.Array,  # (B, S, H, N)  (groups pre-broadcast to heads)
    Cm: jax.Array,  # (B, S, H, N)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:  # dt=0 padding steps are identity transitions (decay 1, input 0)
        zp = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, Bm, Cm = zp(x), zp(dt), zp(Bm), zp(Cm)
        S_out, S = S, S + pad
    else:
        S_out = S
    nc = S // Q
    f32 = jnp.float32

    def r(t):  # (B,S,...) -> (B,nc,Q,...)
        return t.reshape((Bsz, nc, Q) + t.shape[2:])

    xb = (x * dt[..., None]).astype(f32)  # discretised input
    xc, dtc = r(xb), r(dt.astype(f32))
    Bc, Cc = r(Bm.astype(f32)), r(Cm.astype(f32))
    dA = dtc * A.astype(f32)  # (B,nc,Q,H)
    cs = jnp.cumsum(dA, axis=2)  # (B,nc,Q,H)

    # ---- intra-chunk (dual / attention-like form) ----
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bcshn->bchqs", Cc, Bc) * L
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", scores, xc)

    # ---- chunk-boundary states ----
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)  # (B,nc,Q,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn", Bc, decay_to_end, xc)

    # ---- inter-chunk recurrence (the "overlap buffer" carry) ----
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), f32)
    else:
        h0 = h0.astype(f32)

    def step(h, inp):
        dec, st = inp  # (B,H), (B,H,P,N)
        h_new = h * dec[..., None, None] + st
        return h_new, h  # emit the state at chunk START

    hT, h_starts = jax.lax.scan(
        step, h0, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4))
    )
    h_starts = h_starts.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Cc, h_starts) * jnp.exp(cs)[
        ..., None
    ].transpose(0, 1, 2, 3, 4)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)[:, :S_out]
    return y.astype(x.dtype), hT


def ssd_reference(x, dt, A, Bm, Cm, h0=None):
    """Naive per-step recurrence — oracle for ssd_chunked/decode."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), f32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        dec = jnp.exp(dtt * A.astype(f32))
        h = h * dec[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dtt, Bt.astype(f32), xt.astype(f32)
        )
        y = jnp.einsum("bhn,bhpn->bhp", Ct.astype(f32), h)
        return h, y

    hT, ys = jax.lax.scan(
        step,
        h0.astype(f32),
        (
            x.transpose(1, 0, 2, 3).astype(f32),
            dt.transpose(1, 0, 2).astype(f32),
            Bm.transpose(1, 0, 2, 3),
            Cm.transpose(1, 0, 2, 3),
        ),
    )
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hT


def ssd_decode_step(h, x, dt, A, Bm, Cm):
    """One token: h (B,H,P,N), x (B,H,P), dt (B,H), Bm/Cm (B,H,N)."""
    f32 = jnp.float32
    dec = jnp.exp(dt.astype(f32) * A.astype(f32))
    h = h * dec[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt.astype(f32), Bm.astype(f32), x.astype(f32)
    )
    y = jnp.einsum("bhn,bhpn->bhp", Cm.astype(f32), h)
    return h, y.astype(x.dtype)


# ----------------------------------------------------------------------
# Full Mamba2 block
# ----------------------------------------------------------------------
def mamba_schema(cfg) -> dict:
    d = cfg.d_model
    din = cfg.ssm_d_inner
    H, N, G, W = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_conv_width
    conv_dim = din + 2 * G * N
    return {
        "wz": ParamSpec((d, din), ("embed", "mlp")),
        "wx": ParamSpec((d, din), ("embed", "mlp")),
        "wbc": ParamSpec((d, 2 * G * N), ("embed", None)),
        "wdt": ParamSpec((d, H), ("embed", "ssm_heads")),
        "conv_w": ParamSpec((W, conv_dim), (None, "mlp")),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), init="zeros"),
        "A_log": ParamSpec((H,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((H,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), init="zeros"),
        "norm": ParamSpec((din,), ("norm",), init="ones"),
        "wo": ParamSpec((din, d), ("mlp", "embed")),
    }


def init_ssm_cache_spec(cfg, batch: int):
    """Two caches per layer: conv window and SSM state."""
    din = cfg.ssm_d_inner
    G, N, W = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv_width
    conv_dim = din + 2 * G * N
    conv = ((batch, W - 1, conv_dim), ("batch", None, "mlp"))
    ssm = (
        (batch, cfg.ssm_heads, cfg.ssm_headdim, N),
        ("batch", "ssm_heads", None, None),
    )
    return conv, ssm


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 window: Optional[jax.Array] = None):
    """Depthwise causal conv1d. xbc (B,S,C), w (W,C). Returns (y, new_window)."""
    W = w.shape[0]
    if window is None:
        window = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    ext = jnp.concatenate([window, xbc], axis=1)  # (B, S+W-1, C)
    y = sum(
        ext[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(W)
    ) + b
    new_window = ext[:, -(W - 1) :, :] if W > 1 else window
    return y, new_window


def mamba_block(
    p: dict,
    cfg,
    x: jax.Array,  # (B, S, d)
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (conv, ssm)
    mode: str = "train",
):
    """Returns (y (B,S,d), new_cache)."""
    B, S, d = x.shape
    din, H, P = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_headdim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype))
    z = jnp.einsum("bsd,df->bsf", x, p["wz"].astype(x.dtype))
    xs = jnp.einsum("bsd,df->bsf", x, p["wx"].astype(x.dtype))
    bc = jnp.einsum("bsd,df->bsf", x, p["wbc"].astype(x.dtype))
    xbc = jnp.concatenate([xs, bc], axis=-1)
    xbc = pshard(xbc, "batch", "seq", "mlp")

    conv_win = cache[0] if cache is not None else None
    if mode in ("train", "prefill"):
        xbc_c, new_conv = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                       p["conv_b"].astype(x.dtype),
                                       None if mode == "train" else conv_win)
    else:  # decode: S == 1
        xbc_c, new_conv = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                       p["conv_b"].astype(x.dtype), conv_win)
    xbc_c = silu(xbc_c)
    xs_c = xbc_c[..., :din].reshape(B, S, H, P)
    Bm = xbc_c[..., din : din + G * N].reshape(B, S, G, N)
    Cm = xbc_c[..., din + G * N :].reshape(B, S, G, N)
    # broadcast groups to heads
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=2)
    Cm = jnp.repeat(Cm, rep, axis=2)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    h0 = cache[1] if (cache is not None and mode != "train") else None
    if mode in ("train", "prefill"):
        # §Perf note: sharding the per-head dim P here was tried and REFUTED —
        # it 7x'd collective bytes (per-layer resharding between the
        # mlp-sharded conv layout and a P-sharded head layout). Heads stay
        # the only SSD TP axis; when they don't divide, compute replicates.
        xs_c = pshard(xs_c, "batch", "seq", "ssm_heads", None)
        y, hT = ssd_chunked(xs_c, dt, A, Bm, Cm, cfg.ssm_chunk, h0)
    else:
        hT, y1 = ssd_decode_step(
            h0, xs_c[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0]
        )
        y = y1[:, None]
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xs_c
    y = y.reshape(B, S, din)
    y = rmsnorm(y * silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsf,fd->bsd", y, p["wo"].astype(x.dtype))
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = (new_conv, hT.astype(jnp.float32))
    return pshard(out, "batch", "act_seq", "embed"), new_cache
