"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (per the repo contract) and a
summary of the roofline artifacts if a dry-run sweep exists.
"""

from __future__ import annotations

import os
import sys

# Allow ``python benchmarks/run.py`` from anywhere: the repo root (parent of
# this file's directory) must be importable for ``from benchmarks import …``.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def main() -> None:
    from benchmarks import (
        bandwidth_reduction,
        engine_throughput,
        kernel_micro,
        psnr_penalty,
        table1_throughput,
        table2_buffers,
    )

    print("name,us_per_call,derived")
    modules = [table1_throughput, table2_buffers, bandwidth_reduction,
               psnr_penalty, kernel_micro, engine_throughput]
    for mod in modules:
        for name, us, derived in mod.rows():
            print(f'{name},{us:.1f},"{derived}"')

    # roofline summary (if the dry-run sweep has been run)
    try:
        from repro.roofline.report import load_records, roofline_row

        recs = [r for r in load_records()
                if r.get("mesh") == "single_pod" and r.get("status") == "ok"]
        rows = [roofline_row(r) for r in recs]
        rows = [r for r in rows if r]
        if rows:
            best = max(rows, key=lambda r: r["roofline_fraction"])
            print(f'roofline.cells_ok,{0.0:.1f},"{len(rows)} single-pod cells"')
            print(f'roofline.best_fraction,{0.0:.1f},'
                  f'"{best["roofline_fraction"]:.3f} ({best["arch"]} x '
                  f'{best["shape"]})"')
    except Exception as e:  # sweep not run yet — benchmarks still valid
        print(f'roofline.summary,0.0,"unavailable: {e}"', file=sys.stderr)


if __name__ == "__main__":
    main()
