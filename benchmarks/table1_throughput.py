"""Paper Table I: throughput / MACs / utilisation of the accelerator.

Derived from the cycle model in ``core.analysis`` (the same tile geometry
the executors run) and compared against the published design point.
"""

from __future__ import annotations

import time

from repro.core.analysis import PAPER_CLAIMS, pe_throughput_model


def rows():
    t0 = time.perf_counter()
    pe = pe_throughput_model()
    us = (time.perf_counter() - t0) * 1e6
    return [
        ("table1.mpix_per_s", us,
         f"{pe['mpix_s_at_target']:.1f} (paper {PAPER_CLAIMS['throughput_mpix_s']})"),
        ("table1.fps_capacity", us, f"{pe['fps_capacity']:.1f} (target 60)"),
        ("table1.num_macs", us, f"{pe['num_macs']} (paper {PAPER_CLAIMS['num_macs']})"),
        ("table1.utilization", us,
         f"{pe['utilization']:.3f} (paper {PAPER_CLAIMS['utilization']})"),
        ("table1.cycles_per_frame", us, f"{pe['cycles_per_frame']}"),
    ]
