"""Kernel microbenchmarks.

Wall-clock here is CPU interpret-mode (correctness vehicle, not TPU perf);
the ``derived`` column therefore reports the MODELED TPU numbers from the
dry-run machinery: per-tile MXU FLOPs, VMEM working set claimed by the
BlockSpecs, and the analytic HBM traffic of the streaming layout.

    PYTHONPATH=src python benchmarks/kernel_micro.py    # CSV rows

Run standalone by CI's bench-smoke job (the Pallas datapath must at least
execute + produce its modeled numbers on every change); also exposes
``rows()`` for the ``benchmarks/run.py`` harness.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.analysis import HWConfig
from repro.kernels import ops
from repro.models.abpn import ABPNConfig, init_abpn


def _time(fn, *args, iters=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def rows():
    cfg = ABPNConfig()
    hw = HWConfig()
    layers = init_abpn(jax.random.PRNGKey(0), cfg)
    img = jax.random.uniform(jax.random.PRNGKey(1), (60, 64, 3))

    us_fused = _time(
        lambda x: ops.tilted_fused_stack(x, layers, band_rows=60, tile_cols=8),
        img,
    )
    w = layers[1].w
    b = layers[1].b
    feat = jax.random.uniform(jax.random.PRNGKey(2), (60, 64, 28))
    us_conv = _time(lambda x: ops.conv3x3(x, w, b), feat)

    # modeled TPU numbers per (8-col x 60-row) tile, chp=32 padding
    chp, C, R, L = 32, 8, 60, 7
    tile_flops = L * 9 * 2 * (R * C) * chp * chp
    vmem_kb = (
        (R * C * chp)  # out block
        + (R * C * 8)  # in block (c0p=8)
        + L * 9 * chp * chp  # weights
        + L * R * 2 * chp  # overlap scratch
        + R * (C + L) * 8  # residual ring
    ) * 4 / 1e3
    return [
        ("kernel.tilted_fused_stack", us_fused,
         f"interpret-mode; modeled {tile_flops/1e6:.2f} MFLOP/tile on MXU"),
        ("kernel.conv3x3", us_conv,
         f"interpret-mode; vectorwise layer datapath"),
        ("kernel.vmem_claim_kb", 0.0,
         f"{vmem_kb:.0f} KB f32 VMEM/tile (SRAM analogue: {102.36} KB int8)"),
    ]


def main() -> int:
    print("name,us_per_call,derived")
    for name, us, derived in rows():
        print(f'{name},{us:.1f},"{derived}"')
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
