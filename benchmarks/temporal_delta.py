"""Temporal delta serving benchmark: reuse vs motion, bit-exact splice.

Streams three synthetic clips through a :class:`DeltaSession` on one
shared session and records, per clip, how much conv-stack compute the
delta path actually ran:

* ``static``      — a static camera: every frame after the first is
  byte-identical, so only frame 0 dispatches and the compute reduction
  equals the clip length (band-rows served collapse to one frame's).
* ``panning``     — a small patch walks down one band per frame over a
  static background: the dirty set is the changed bands dilated by the
  halo reach, so a sliver of the frame recomputes each step.
* ``full_motion`` — fresh noise every frame: nothing can be reused and
  the delta path degenerates to full re-upscale (reduction 1.0) — the
  honest lower bound, recorded so the static number has a denominator.

Every delta-served frame is compared against ``session.upscale`` on the
same frame — the ``bit_exact`` flag per clip is the splice guarantee,
measured, not assumed.  The ``acceptance`` block pins the headline
claim CI gates on: the static clip's compute reduction is at least
``MIN_STATIC_COMPUTE_REDUCTION`` (4x) and every clip is bit-exact.

    PYTHONPATH=src python benchmarks/temporal_delta.py \\
        --json-path BENCH_temporal.json             # full record
    PYTHONPATH=src python benchmarks/temporal_delta.py --quick

Schema key tuples live here, next to the producer;
``check_bench_schema.py`` imports them so producer and checker cannot
drift apart.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.engine import SRServer, SRSession
from repro.engine.temporal import DeltaSession, halo_reach
from repro.models.abpn import ABPNConfig, init_abpn

# --- the committed schema (imported by check_bench_schema.py) ----------
TEMPORAL_RECORD_KEYS = (
    "bench", "jax_backend", "platform", "lr_shape", "band_rows",
    "bands_per_frame", "halo_reach", "backend", "vertical_policy",
    "precision", "frames_per_clip", "quick", "seed", "clips",
    "acceptance",
)
TEMPORAL_CLIP_KEYS = (
    "clip", "frames", "bands_total", "bands_served", "bands_skipped",
    "reuse_ratio", "band_rows_total", "band_rows_served",
    "compute_reduction", "band_dispatches",
    "effective_hbm_bytes_per_frame", "full_hbm_bytes_per_frame",
    "hbm_reduction", "bit_exact", "cache",
)
TEMPORAL_CACHE_KEYS = ("hits", "misses", "puts", "evictions", "bytes_saved")
TEMPORAL_ACCEPTANCE_KEYS = (
    "min_static_compute_reduction", "static_compute_reduction",
    "static_ok", "all_bit_exact",
)

# the headline floor: a static clip must cut conv-stack band-rows by at
# least this factor vs re-upscaling every frame
MIN_STATIC_COMPUTE_REDUCTION = 4.0

FULL_SHAPE = (64, 32, 3)
QUICK_SHAPE = (32, 32, 3)
BAND_ROWS = 8


def make_clips(shape, frames: int, band_rows: int, seed: int) -> dict:
    """The three motion regimes, as lists of float32 (H, W, C) frames.
    Distinct seeds per clip keep cross-clip cache hits out of the data."""
    h, w, c = shape
    patch = band_rows  # one band tall: the panning object crosses bands
    rng = np.random.default_rng(seed)
    base = rng.random(shape, dtype=np.float32)
    static = [base.copy() for _ in range(frames)]

    rng = np.random.default_rng(seed + 1)
    pan_bg = rng.random(shape, dtype=np.float32)
    panning = []
    for t in range(frames):
        f = pan_bg.copy()
        r0 = (t * band_rows) % (h - patch)
        f[r0:r0 + patch, : w // 2] += 0.25
        panning.append(f)

    rng = np.random.default_rng(seed + 2)
    full_motion = [rng.random(shape, dtype=np.float32) for _ in range(frames)]
    return {"static": static, "panning": panning,
            "full_motion": full_motion}


def run_clip(session, server, name: str, clip) -> dict:
    """Serve one clip through a fresh DeltaSession; counters are the
    session-level temporal counts diffed across the clip, so the record
    is immune to what earlier clips (or the oracle calls) did."""
    before = dict(session._temporal_counts)
    dispatches_before = session._band_dispatches
    cache_before = dict(session.output_cache().stats())

    exact = True
    with DeltaSession(session, server=server) as ds:
        for frame in clip:
            out = ds.serve(frame)
            ref = np.asarray(session.upscale(frame))
            exact = exact and np.array_equal(out, ref)

    t = session._temporal_counts
    d = {k: t[k] - before[k] for k in t}
    cache = session.output_cache().stats()
    frames = d["frames"]
    total = d["bands_total"]
    served = total - d["bands_skipped"]
    rows_served = d["band_rows_served"]
    return {
        "clip": name,
        "frames": frames,
        "bands_total": total,
        "bands_served": served,
        "bands_skipped": d["bands_skipped"],
        "reuse_ratio": round(d["bands_skipped"] / total, 4) if total else 0.0,
        "band_rows_total": d["band_rows_total"],
        "band_rows_served": rows_served,
        "compute_reduction": round(
            d["band_rows_total"] / rows_served, 3) if rows_served else None,
        "band_dispatches": session._band_dispatches - dispatches_before,
        "effective_hbm_bytes_per_frame": round(
            d["hbm_bytes_served"] / frames, 1) if frames else 0.0,
        "full_hbm_bytes_per_frame": round(
            d["hbm_bytes_full"] / frames, 1) if frames else 0.0,
        "hbm_reduction": round(
            d["hbm_bytes_full"] / d["hbm_bytes_served"], 3)
        if d["hbm_bytes_served"] else None,
        "bit_exact": bool(exact),
        "cache": {k: cache[k] - cache_before[k] for k in TEMPORAL_CACHE_KEYS},
    }


def measure(*, quick: bool, seed: int) -> dict:
    shape = QUICK_SHAPE if quick else FULL_SHAPE
    frames = 6 if quick else 8
    policy = "halo"  # non-trivial dilation: reach = ceil(L / R) bands

    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(seed), cfg)
    session = SRSession(layers, backend="tilted", vertical_policy=policy,
                        band_rows=BAND_ROWS, autotune="off")
    clips = make_clips(shape, frames, BAND_ROWS, seed)
    with SRServer({"abpn_x3": session}) as server:
        results = [run_clip(session, server, name, clip)
                   for name, clip in clips.items()]

    by_name = {r["clip"]: r for r in results}
    static_red = by_name["static"]["compute_reduction"]
    acceptance = {
        "min_static_compute_reduction": MIN_STATIC_COMPUTE_REDUCTION,
        "static_compute_reduction": static_red,
        "static_ok": (static_red is not None
                      and static_red >= MIN_STATIC_COMPUTE_REDUCTION),
        "all_bit_exact": all(r["bit_exact"] for r in results),
    }
    return {
        "bench": "temporal_delta",
        "jax_backend": jax.default_backend(),
        "platform": jax.devices()[0].platform,
        "lr_shape": list(shape),
        "band_rows": BAND_ROWS,
        "bands_per_frame": shape[0] // BAND_ROWS,
        "halo_reach": halo_reach(BAND_ROWS, cfg.num_layers, policy),
        "backend": "tilted",
        "vertical_policy": policy,
        "precision": "fp32",
        "frames_per_clip": frames,
        "quick": quick,
        "seed": seed,
        "clips": results,
        "acceptance": acceptance,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes: smaller frames, shorter clips")
    ap.add_argument("--json-path", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rec = measure(quick=args.quick, seed=args.seed)
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
            f.write("\n")

    print(f"delta serving {tuple(rec['lr_shape'])} x "
          f"{rec['frames_per_clip']} frames, band_rows {rec['band_rows']} "
          f"({rec['bands_per_frame']} bands, halo reach "
          f"{rec['halo_reach']}), {rec['backend']}/{rec['vertical_policy']}")
    for r in rec["clips"]:
        print(f"  {r['clip']:>11}: served {r['bands_served']:>3}/"
              f"{r['bands_total']:>3} bands (reuse {r['reuse_ratio']:.2f}), "
              f"compute x{r['compute_reduction']} fewer band-rows, "
              f"hbm x{r['hbm_reduction']}, bit_exact={r['bit_exact']}")
    acc = rec["acceptance"]
    print(f"acceptance: static compute reduction "
          f"x{acc['static_compute_reduction']} "
          f"(>= x{acc['min_static_compute_reduction']}: {acc['static_ok']}), "
          f"all clips bit-exact: {acc['all_bit_exact']}")
    return 0 if acc["static_ok"] and acc["all_bit_exact"] else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
