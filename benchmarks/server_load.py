"""Open-loop load harness for SRServer's traffic-hardening stack.

Drives the server with Poisson arrivals (exponential inter-arrival
times scheduled on the monotonic clock, so latency is measured from the
SCHEDULED arrival — no coordinated omission) over a mixed workload:
two hosted models at different resolutions, heavy-tailed clip lengths
(capped Pareto), and mixed priorities.  Each load point runs the same
offered rate through two server configurations:

* ``block``    — bounded queue, ``admission="block"``, no deadlines, no
  degradation: the pre-hardening server.  Under overload the backlog
  (and the submitter) grows without bound and tail latency explodes.
* ``hardened`` — ``admission="shed"`` + per-request deadlines +
  :class:`DegradePolicy` (bf16 -> half lookahead -> half buckets): the
  server sheds and expires what it cannot serve in time and degrades
  what it can, holding the SERVED tail inside the SLO.

Rates are expressed as multiples of a closed-loop calibrated capacity,
so the ladder means the same thing on any machine.  The record's
``acceptance`` block pins the headline claim CI gates on: at the
overload point the hardened server's p99 stays within the SLO while
the block server's does not — with shedding, deadline expiries, and at
least one degradation transition actually observed.

A fault-injection section (``FailureInjector`` threaded into the
server's launch path) proves blast-radius isolation: failing the k-th
dispatch fails exactly that dispatch's request; every other request
completes bit-exact and the server keeps serving afterwards.

    PYTHONPATH=src python benchmarks/server_load.py \\
        --json-path BENCH_server_load.json          # full record
    PYTHONPATH=src python benchmarks/server_load.py --quick
    PYTHONPATH=src python benchmarks/server_load.py --fault-smoke

Schema key tuples live here, next to the producer;
``check_bench_schema.py`` imports them so producer and checker cannot
drift apart.
"""

from __future__ import annotations

import argparse
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import SRSession
from repro.engine.scheduler import (
    DeadlineExceededError,
    QueueFullError,
    RequestShedError,
)
from repro.engine.server import DegradePolicy, SRServer
from repro.models.abpn import ABPNConfig, init_abpn

# --- the committed schema (imported by check_bench_schema.py) ----------
LOAD_RECORD_KEYS = (
    "bench", "jax_backend", "platform", "lr_shapes", "slo_p99_ms",
    "duration_s", "seed", "calibration", "points", "acceptance",
    "fault_injection",
)
CALIBRATION_KEYS = (
    "capacity_fps", "capacity_rps", "mean_request_frames", "per_model",
)
LOAD_POINT_KEYS = ("offered_rate_rps", "load_factor", "block", "hardened")
LOAD_MODE_KEYS = (
    "offered", "completed", "shed", "rejected", "deadline_missed",
    "failed", "served_rate_rps", "p50_ms", "p99_ms", "degrade_level",
    "degrade_transitions", "degraded_requests", "elapsed_s",
)
ACCEPTANCE_KEYS = (
    "offered_rate_rps", "slo_p99_ms", "hardened_p99_ms", "block_p99_ms",
    "hardened_within_slo", "block_within_slo",
)
FAULT_KEYS = (
    "requests", "injected_failures", "failed_requests",
    "unaffected_completed", "neighbors_bit_exact", "served_after_failure",
)

FULL_SHAPES = {"sd": (12, 16, 3), "hd": (24, 32, 3)}
QUICK_SHAPES = {"sd": (12, 16, 3)}
MODEL_MIX = {"sd": 0.6, "hd": 0.4}

# queue bound, in max-bucket multiples.  Kept SHORT on purpose: frames
# already handed to a dispatch are expiry-immune, so a deep queue lets
# partially-served requests ride far past their deadline and blows the
# served tail out of the SLO even while shedding works
QUEUE_BOUND = 4


def _pow2s(cap: int):
    b, out = 1, []
    while b <= cap:
        out.append(b)
        b *= 2
    return out


class Workload:
    """Hosted sessions plus pre-generated clip pools for every
    (model, length) the sampler can emit — arrivals never pay array
    construction, and warmup can pre-compile every reachable
    (shape, bucket, dtype) executor."""

    def __init__(self, shapes: dict, *, max_bucket: int, seed: int):
        cfg = ABPNConfig()
        layers = init_abpn(jax.random.PRNGKey(0), cfg)
        self.layers = layers
        self.shapes = dict(shapes)
        self.max_bucket = max_bucket
        self.sessions = {
            name: SRSession(layers, backend="tilted", autotune="off",
                            max_bucket=max_bucket)
            for name in shapes
        }
        self.pools = {}
        key = jax.random.PRNGKey(seed)
        for name, shape in shapes.items():
            self.pools[name] = {}
            for n in range(1, max_bucket + 1):
                key, sub = jax.random.split(key)
                self.pools[name][n] = jax.random.uniform(sub, (n, *shape))
        names = [m for m in shapes]
        probs = np.array([MODEL_MIX.get(m, 1.0) for m in names])
        self._names, self._probs = names, probs / probs.sum()

    def sample(self, rng, count: int):
        """(model, n_frames, priority) for `count` arrivals: mixed
        models, capped-Pareto heavy-tail clip lengths, priorities 0-2."""
        models = rng.choice(self._names, size=count, p=self._probs)
        lengths = np.minimum(
            self.max_bucket, 1 + rng.pareto(1.1, size=count).astype(int))
        prios = rng.integers(0, 3, size=count)
        return list(zip(models.tolist(), lengths.tolist(), prios.tolist()))

    def mean_request_frames(self, rng) -> float:
        return float(np.mean([n for _, n, _ in self.sample(rng, 4096)]))


def warmup(work: Workload) -> None:
    """Compile every (model, bucket, dtype) executor the run can touch —
    including bf16, which the DegradePolicy's first ladder step switches
    live traffic onto."""
    with SRServer(work.sessions) as server:
        for name in work.sessions:
            for n in _pow2s(work.max_bucket):
                clip = work.pools[name][n]
                server.submit(clip, model=name).result()
                server.submit(jnp.asarray(clip, jnp.bfloat16),
                              model=name).result()


def calibrate(work: Workload, *, reps: int, probe_s: float, rng,
              seed: int) -> dict:
    """Capacity, measured the way the load points will spend it.

    Per-model CLOSED-loop request times (max-bucket clips, back to
    back) anchor the deadline/SLO budgets on the worst-case service
    time.  Capacity itself comes from a saturation probe: a BLOCK-mode
    server driven by a PACED open loop at several times the closed-loop
    estimate, with the drain thread running — i.e. exactly the baseline
    configuration the load points compare against, machinery overhead
    (submit, scheduling, GIL hand-offs) included.  Pacing matters: a
    submitter that spins flat-out starves the drain thread of the GIL
    and measures a capacity far below what paced traffic achieves,
    which would quietly turn every "load factor" downstream into a
    several-times-larger multiple than it claims.  The rate is
    over-driven enough that blocking admission, not the pacing, is the
    throughput governor, and the rate is read off a steady-state
    completion window."""
    per_model = {}
    with SRServer(work.sessions) as server:
        for name in work._names:
            clip = work.pools[name][work.max_bucket]
            server.submit(clip, model=name).result()  # warm
            t0 = time.perf_counter()
            for _ in range(reps):
                server.submit(clip, model=name).result()
            per_model[name] = {
                "request_ms": round(
                    (time.perf_counter() - t0) * 1e3 / reps, 4),
                "frames": work.max_bucket,
            }
    mean_frames = work.mean_request_frames(rng)
    # closed-loop estimate (optimistic: per-request overhead at typical
    # clip sizes is ignored) — only used to pick the probe's over-drive
    # rate, never reported as capacity
    blended_ms_per_frame = sum(
        float(p) * per_model[name]["request_ms"] / work.max_bucket
        for name, p in zip(work._names, work._probs))
    est_rps = 1e3 / blended_ms_per_frame / mean_frames

    server = SRServer(work.sessions,
                      max_inflight_frames=QUEUE_BOUND * work.max_bucket,
                      admission="block")
    stop = threading.Event()

    def drain():
        while not stop.is_set():
            server.flush()
            stop.wait(0.0005)

    drainer = threading.Thread(target=drain, daemon=True)
    drainer.start()
    reqs = work.sample(np.random.default_rng(seed), 65536)
    done, done_lock = [], threading.Lock()
    i = 0
    t0 = time.monotonic()

    def make_cb(n):
        def cb(fut):
            t = time.monotonic()
            with done_lock:
                done.append((t - t0, n))
        return cb

    interval = 1.0 / (4.0 * est_rps)
    next_t = t0
    while True:
        now = time.monotonic()
        if now - t0 >= probe_s:
            break
        if now < next_t:
            time.sleep(next_t - now)
        next_t += interval
        model, n, _ = reqs[i % len(reqs)]
        i += 1
        server.submit(work.pools[model][n],
                      model=model).add_done_callback(make_cb(n))
    t_sub = time.monotonic() - t0
    server.flush()
    elapsed = time.monotonic() - t0
    stop.set()
    drainer.join()
    server.close()
    # steady-state window: skip the warm-in quarter and the post-submit
    # drain tail, both of which bias the rate downward
    lo = 0.25 * probe_s
    steady = [(t, n) for t, n in done if lo <= t <= t_sub]
    span = t_sub - lo
    if len(steady) >= 10 and span > 0:
        rps = len(steady) / span
        fps = sum(n for _, n in steady) / span
    else:  # pragma: no cover - degenerate probe, fall back to the mean
        rps = len(done) / elapsed
        fps = sum(n for _, n in done) / elapsed
    return {
        "capacity_fps": round(fps, 2),
        "capacity_rps": round(rps, 2),
        "mean_request_frames": round(mean_frames, 3),
        "per_model": per_model,
    }


def run_point(work: Workload, *, rate_rps: float, duration_s: float,
              mode: str, slo_ms: float, deadline_ms: float,
              policy_slo_ms: float, seed: int) -> dict:
    """One (offered rate, server configuration) measurement."""
    rng = np.random.default_rng(seed)
    bound = QUEUE_BOUND * work.max_bucket
    policy = None
    if mode == "hardened":
        # a LONG breach streak so transient jitter at moderate load
        # cannot walk the ladder down; sustained overload (a queue that
        # is simply always full) breaches every observation and gets
        # there within a couple of queue drains anyway
        policy = DegradePolicy(policy_slo_ms, alpha=0.2,
                               breach_steps=4, recover_steps=8)
        server = SRServer(work.sessions, max_inflight_frames=bound,
                          admission="shed", degrade=policy)
    else:
        server = SRServer(work.sessions, max_inflight_frames=bound,
                          admission="block")

    # pre-sample the whole arrival schedule (open loop: times are fixed
    # BEFORE the run; a slow server cannot slow the offered load down)
    n_arrivals = max(1, int(rate_rps * duration_s))
    at = np.cumsum(rng.exponential(1.0 / rate_rps, size=n_arrivals))
    at = at[at <= duration_s]
    reqs = work.sample(rng, len(at))

    records, rec_lock = [], threading.Lock()
    stop = threading.Event()

    def drain():
        while not stop.is_set():
            server.flush()
            stop.wait(0.0005)

    def make_cb(sched):
        def cb(fut):
            end = time.monotonic()
            with rec_lock:
                records.append((sched, end, fut.exception()))
        return cb

    drainer = threading.Thread(target=drain, daemon=True)
    drainer.start()
    rejected = 0
    t0 = time.monotonic()
    for arrival, (model, n, prio) in zip(at, reqs):
        delay = (t0 + arrival) - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        sched = t0 + arrival
        kw = {}
        if mode == "hardened":
            kw["deadline"] = sched + deadline_ms / 1e3
        try:
            fut = server.submit(work.pools[model][n], model=model,
                                priority=int(prio), **kw)
        except QueueFullError:
            rejected += 1
            continue
        fut.add_done_callback(make_cb(sched))
    server.flush()
    elapsed = time.monotonic() - t0
    stop.set()
    drainer.join()
    server.close()  # releases the sessions for the next configuration

    ok_lat, shed, missed, failed = [], 0, 0, 0
    for sched, end, exc in records:
        if exc is None:
            ok_lat.append((end - sched) * 1e3)
        elif isinstance(exc, RequestShedError):
            shed += 1
        elif isinstance(exc, DeadlineExceededError):
            missed += 1
        else:
            failed += 1
    dg = server.stats().get("degrade", {})
    return {
        "offered": len(at),
        "completed": len(ok_lat),
        "shed": shed,
        "rejected": rejected,
        "deadline_missed": missed,
        "failed": failed,
        "served_rate_rps": round(len(ok_lat) / max(elapsed, 1e-9), 2),
        "p50_ms": round(float(np.percentile(ok_lat, 50)), 3) if ok_lat
        else None,
        "p99_ms": round(float(np.percentile(ok_lat, 99)), 3) if ok_lat
        else None,
        "degrade_level": dg.get("level", 0),
        "degrade_transitions": len(dg.get("transitions", [])),
        "degraded_requests": dg.get("degraded_requests", 0),
        "elapsed_s": round(elapsed, 3),
    }


def run_fault_injection(work: Workload) -> dict:
    """Blast-radius proof: fail the k-th dispatch, show only that
    dispatch's request fails, neighbors stay bit-exact, and the server
    serves normally afterwards."""
    from repro.runtime.resilience import FailureInjector, InjectedFailure

    name = next(iter(work.sessions))
    # sessions of their own: the injector run must not pollute the load
    # sessions' stats, and max_bucket=2 pins one request per dispatch.
    # References come from a SEPARATE clean session — upscale() would
    # lazily bind an embedded server to whichever session it runs on.
    session = SRSession(work.layers, backend="tilted", autotune="off",
                        max_bucket=2)
    ref_session = SRSession(work.layers, backend="tilted", autotune="off",
                            max_bucket=2)
    refs = []
    clips = []
    key = jax.random.PRNGKey(7)
    for _ in range(4):
        key, sub = jax.random.split(key)
        clip = jax.random.uniform(sub, (2, *work.shapes[name]))
        clips.append(clip)
        refs.append(np.asarray(ref_session.upscale(clip)))

    injector = FailureInjector(fail_dispatches={1})
    server = SRServer({name: session}, injector=injector)
    futs = [server.submit(c, model=name) for c in clips]
    server.flush()

    failed, exact, completed = 0, True, 0
    for i, fut in enumerate(futs):
        exc = fut.exception()
        if isinstance(exc, InjectedFailure):
            failed += 1
        elif exc is None:
            completed += 1
            exact = exact and np.array_equal(np.asarray(fut.result()),
                                             refs[i])
        else:  # pragma: no cover - any other failure breaks isolation
            failed += 1
            exact = False
    after = server.submit(clips[0], model=name).result()
    return {
        "requests": len(futs),
        "injected_failures": injector.stats()["injected_failures"],
        "failed_requests": failed,
        "unaffected_completed": completed,
        "neighbors_bit_exact": bool(exact),
        "served_after_failure": bool(
            np.array_equal(np.asarray(after), refs[0])),
    }


def measure(*, quick: bool, seed: int) -> dict:
    shapes = QUICK_SHAPES if quick else FULL_SHAPES
    max_bucket = 2 if quick else 8
    duration_s = 1.0 if quick else 3.0
    load_factors = (0.5, 3.0) if quick else (0.5, 1.5, 4.0)
    reps = 20 if quick else 40

    rng = np.random.default_rng(seed)
    work = Workload(shapes, max_bucket=max_bucket, seed=seed)
    warmup(work)
    cal = calibrate(work, reps=reps, probe_s=0.5 if quick else 1.0,
                    rng=rng, seed=seed)

    # the slowest model's closed-loop request time anchors the budgets:
    # a deadline many services deep, an SLO with drain headroom above
    # the deadline.  The 30 ms floor sits above the host's background
    # scheduling jitter (OS preemption, allocator stalls — visible as
    # ~30 ms stragglers even in an underloaded block-mode server), so
    # a healthy load point does not expire requests over noise.
    t_req = max(m["request_ms"] for m in cal["per_model"].values())
    deadline_ms = max(12.0 * t_req, 30.0)
    # 3x the deadline: a request dispatched JUST inside its deadline is
    # expiry-immune from its first served frame on, so its completion
    # can trail the deadline by a queue-bound drain plus scheduling
    # jitter — the SLO needs that overhang as headroom
    slo_ms = 3.0 * deadline_ms
    # degrade trigger: at overload the bounded queue is ALWAYS full, so
    # every served request waits about one full-queue drain — while a
    # healthy queue is mostly empty and latency is a service time or
    # two.  0.8x the drain time splits those regimes at any scale; the
    # 0.55x-deadline floor keeps the trigger above background jitter
    # when the drain time itself is tiny (per-request overhead, not
    # frame count, dominates small-bucket queues)
    drain_ms = QUEUE_BOUND * max_bucket / cal["capacity_fps"] * 1e3
    policy_slo_ms = max(0.8 * drain_ms, 0.55 * deadline_ms)

    points = []
    for lf in load_factors:
        rate = lf * cal["capacity_rps"]
        point = {"offered_rate_rps": round(rate, 2), "load_factor": lf}
        for mode in ("block", "hardened"):
            point[mode] = run_point(
                work, rate_rps=rate, duration_s=duration_s, mode=mode,
                slo_ms=slo_ms, deadline_ms=deadline_ms,
                policy_slo_ms=policy_slo_ms, seed=seed + int(lf * 10))
        points.append(point)

    top = points[-1]
    acceptance = {
        "offered_rate_rps": top["offered_rate_rps"],
        "slo_p99_ms": round(slo_ms, 3),
        "hardened_p99_ms": top["hardened"]["p99_ms"],
        "block_p99_ms": top["block"]["p99_ms"],
        "hardened_within_slo": (
            top["hardened"]["p99_ms"] is not None
            and top["hardened"]["p99_ms"] <= slo_ms),
        "block_within_slo": (
            top["block"]["p99_ms"] is not None
            and top["block"]["p99_ms"] <= slo_ms),
    }
    return {
        "bench": "server_load",
        "jax_backend": jax.default_backend(),
        "platform": jax.devices()[0].platform,
        "lr_shapes": {m: list(s) for m, s in shapes.items()},
        "slo_p99_ms": round(slo_ms, 3),
        "duration_s": duration_s,
        "seed": seed,
        "calibration": cal,
        "points": points,
        "acceptance": acceptance,
        "fault_injection": run_fault_injection(work),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes: one model, short points")
    ap.add_argument("--json-path", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-smoke", action="store_true",
                    help="run ONLY the fault-injection isolation proof")
    args = ap.parse_args()

    if args.fault_smoke:
        work = Workload(QUICK_SHAPES, max_bucket=2, seed=args.seed)
        fi = run_fault_injection(work)
        print(json.dumps(fi, indent=2, sort_keys=True))
        ok = (fi["neighbors_bit_exact"] and fi["served_after_failure"]
              and fi["failed_requests"] == fi["injected_failures"] == 1)
        print(f"fault isolation: {'ok' if ok else 'BROKEN'}")
        return 0 if ok else 1

    rec = measure(quick=args.quick, seed=args.seed)
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
            f.write("\n")

    cal = rec["calibration"]
    print(f"capacity: {cal['capacity_rps']} req/s "
          f"({cal['capacity_fps']} frames/s, "
          f"mean {cal['mean_request_frames']} frames/req); "
          f"SLO p99 <= {rec['slo_p99_ms']} ms")
    for p in rec["points"]:
        for mode in ("block", "hardened"):
            m = p[mode]
            print(f"  x{p['load_factor']:<4} {mode:>8}: "
                  f"offered {m['offered']:>4}  ok {m['completed']:>4}  "
                  f"shed {m['shed']:>3}  expired {m['deadline_missed']:>3}  "
                  f"p50 {m['p50_ms']} ms  p99 {m['p99_ms']} ms  "
                  f"degrade_level {m['degrade_level']}")
    acc = rec["acceptance"]
    fi = rec["fault_injection"]
    print(f"acceptance @ {acc['offered_rate_rps']} req/s: "
          f"hardened p99 {acc['hardened_p99_ms']} ms "
          f"(within SLO: {acc['hardened_within_slo']}), "
          f"block p99 {acc['block_p99_ms']} ms "
          f"(within SLO: {acc['block_within_slo']})")
    print(f"fault isolation: bit_exact={fi['neighbors_bit_exact']} "
          f"served_after={fi['served_after_failure']}")
    ok = acc["hardened_within_slo"] and not acc["block_within_slo"]
    return 0 if ok else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
