"""Paper §IV-B: off-chip bandwidth, layer-by-layer vs tilted fusion (−92%).

Also verifies the analytic model against the *implementation*: counts the
actual HBM-facing bytes of the kernel's streaming layout (fresh C-column
slabs, no halo re-reads) for one frame.
"""

from __future__ import annotations

import time

from repro.core.analysis import HWConfig, PAPER_CLAIMS, dram_reduction, dram_traffic
from repro.engine import SRPlan


def rows():
    t0 = time.perf_counter()
    lw = dram_traffic(mode="layerwise")["gb_s"]
    fu = dram_traffic(mode="fused")["gb_s"]
    red = dram_reduction()

    # implementation-level check: per band, the kernel streams exactly
    # K*C fresh input columns (disjoint BlockSpec reads) + writes K*C output
    # columns — matching the model's in+out traffic.  The schedule is taken
    # from the serving plan (the same geometry every engine backend runs).
    cfg = HWConfig()
    plan = SRPlan(height=cfg.band_rows, width=cfg.lr_width,
                  num_layers=len(cfg.channels) - 1, band_rows=cfg.band_rows,
                  tile_cols=cfg.tile_cols)
    sched = plan.schedule
    streamed_cols = sum(
        sched.fresh_input_cols(k)[1] - sched.fresh_input_cols(k)[0]
        for k in range(sched.num_tiles)
    )
    us = (time.perf_counter() - t0) * 1e6
    return [
        ("bandwidth.layerwise_gb_s", us,
         f"{lw:.2f} (paper {PAPER_CLAIMS['dram_layerwise_gb_s']})"),
        ("bandwidth.fused_gb_s", us,
         f"{fu:.3f} (paper {PAPER_CLAIMS['dram_fused_gb_s']})"),
        ("bandwidth.reduction", us,
         f"{red * 100:.1f}% (paper {PAPER_CLAIMS['dram_reduction'] * 100:.0f}%)"),
        ("bandwidth.streamed_cols_per_band", us,
         f"{streamed_cols} (= K*C = {sched.num_tiles * cfg.tile_cols}, "
         f"zero halo re-reads)"),
    ]
