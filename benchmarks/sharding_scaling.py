"""Sharded-serving scaling: frames/s from 1 device to an R x S mesh.

Serves the same clip through ``SRSession`` at a ladder of mesh topologies
— single device, band-sharded (1, S), and replicated + band-sharded
(R, S) — and records per-point throughput, the halo-exchange traffic the
topology implies, replica fill, and whether the output stayed bit-exact
vs the single-device baseline (the sharded executor's core guarantee;
the schema checker fails CI if any point breaks it).

The vertical policy defaults to ``halo`` because it is the one whose
output is independent of band geometry: topologies that force a re-banding
(``shardable_band_rows``) still compare bit-exact.  Points whose topology
does not fit the visible devices (or has no legal band decomposition) are
recorded under ``skipped``, never dropped silently.

JAX must see the devices BEFORE it initialises, so run standalone with
forced host devices (``engine_throughput.measure_sharding`` spawns this
script exactly that way):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python benchmarks/sharding_scaling.py --json-only
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.data.synthetic import sr_pair_batch
from repro.engine import SRSession
from repro.engine.plan import shardable_band_rows
from repro.models.abpn import ABPNConfig, init_abpn

# the scaling ladder: single device -> band shards -> replicas x shards
DEFAULT_SPECS = ((1, 1), (1, 2), (1, 4), (2, 4))


def measure_scaling(
    *,
    height: int = 120,
    width: int = 64,
    backend: str = "tilted",
    precision: str = "fp32",
    vertical_policy: str = "halo",
    frames: int = 4,
    reps: int = 3,
    specs=DEFAULT_SPECS,
) -> dict:
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(0), cfg)
    clip, _ = sr_pair_batch(0, frames, lr_shape=(height, width),
                            scale=cfg.scale)
    avail = jax.device_count()
    points, skipped = [], []
    base_fps = None
    want = None
    for replicas, band_shards in specs:
        needed = replicas * band_shards
        if needed > avail:
            skipped.append({"replicas": replicas, "band_shards": band_shards,
                            "reason": f"needs {needed} devices, "
                                      f"{avail} visible"})
            continue
        if band_shards > 1 and shardable_band_rows(height, band_shards) is None:
            skipped.append({"replicas": replicas, "band_shards": band_shards,
                            "reason": f"height {height} has no band "
                                      f"decomposition into {band_shards} "
                                      "shards"})
            continue
        mesh_kw = {} if needed == 1 else {"mesh": (replicas, band_shards)}
        session = SRSession(
            layers, backend=backend, precision=precision,
            vertical_policy=vertical_policy, scale=cfg.scale,
            autotune="off", **mesh_kw,
        )
        out = np.asarray(session.upscale(clip))  # compile pass
        if want is None:
            want = out
        bit_exact = bool(np.array_equal(out, want))
        session.reset_stats()
        for _ in range(reps):
            session.upscale(clip)
        fps = session.stats()["fps"]
        if base_fps is None:
            base_fps = fps
        sh = session.sharding_stats()
        points.append({
            "devices": needed,
            "replicas": replicas,
            "band_shards": band_shards,
            "frames_per_s": round(fps, 2),
            "scaling": round(fps / max(base_fps, 1e-9), 3),
            "halo_bytes_per_frame": (
                0 if sh is None else int(sh["halo_bytes_per_frame"])),
            "replica_fill": 0.0 if sh is None else round(sh["replica_fill"], 3),
            "bit_exact": bit_exact,
        })
    return {
        "device_count": avail,
        "backend": backend,
        "precision": precision,
        "vertical_policy": vertical_policy,
        "lr_shape": [height, width, cfg.in_channels],
        "frames": frames,
        "reps": reps,
        "points": points,
        "skipped": skipped,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes: tiny clip, 2 reps")
    ap.add_argument("--json-only", action="store_true",
                    help="emit ONLY the JSON record on stdout (for the "
                         "engine_throughput parent process)")
    ap.add_argument("--json-path", default=None)
    ap.add_argument("--height", type=int, default=120)
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--backend", default="tilted",
                    choices=["tilted", "kernel"])
    ap.add_argument("--policy", default="halo",
                    choices=["zero", "halo", "replicate"])
    ap.add_argument("--frames", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()

    kw = dict(height=args.height, width=args.width, backend=args.backend,
              vertical_policy=args.policy, frames=args.frames, reps=args.reps)
    if args.quick:
        kw.update(height=48, width=16, frames=2, reps=2)
    rec = measure_scaling(**kw)
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
            f.write("\n")
    if args.json_only:
        print(json.dumps(rec, sort_keys=True))
        return
    print("name,us_per_call,derived")
    for p in rec["points"]:
        print(f'sharding.r{p["replicas"]}s{p["band_shards"]},0.0,'
              f'"{p["frames_per_s"]:.1f} frames/s on {p["devices"]} '
              f'device(s) (x{p["scaling"]:.2f} vs 1 device, '
              f'{p["halo_bytes_per_frame"] / 1e3:.1f} kB halo/frame, '
              f'fill {p["replica_fill"]:.2f}, '
              f'bit_exact={p["bit_exact"]})"')
    for s in rec["skipped"]:
        print(f'# skipped ({s["replicas"]}x{s["band_shards"]}): {s["reason"]}')


if __name__ == "__main__":
    main()
