"""Paper Table II: on-chip buffer sizes, tilted vs classical fusion."""

from __future__ import annotations

import time

from repro.core.analysis import (
    PAPER_TABLE2,
    buffer_sizes,
    classical_buffer_sizes,
)


def rows():
    t0 = time.perf_counter()
    t = buffer_sizes()
    c = classical_buffer_sizes()
    us = (time.perf_counter() - t0) * 1e6
    out = []
    for key, paper_key in [("ping_pong_kb", "ping_pong"), ("overlap_kb", "overlap"),
                           ("residual_kb", "residual"), ("weight_kb", "weight"),
                           ("total_kb", "total")]:
        out.append((f"table2.tilted.{paper_key}", us,
                    f"{t[key]:.2f}KB (paper {PAPER_TABLE2['tilted'][paper_key]})"))
    out.append(("table2.classical.total", us,
                f"{c['total_kb']:.2f}KB (paper {PAPER_TABLE2['classical']['total']})"))
    out.append(("table2.saving", us,
                f"{(1 - t['total_kb'] / c['total_kb']) * 100:.1f}% (paper ~60%)"))
    return out
