"""Engine serving throughput: frames/s, dispatch/complete latency, cache.

The measurement the serving API exists for: batched requests stream
through an ``SRSession``, whose plan cache compiles ONE executor per
(plan, batch bucket, dtype) over a device-resident PreparedStack — so
throughput scales with batch size and repeat requests are pure cache hits.

Two serving modes are measured on the same multi-bucket clip:

* ``sync``      — ``pipeline_depth=1``: every chunk blocks before the next
  dispatches (the pre-pipeline serving path).
* ``pipelined`` — ``pipeline_depth=2`` (double buffering): chunk *t+1* is
  staged and dispatched while *t* computes; blocking happens only when the
  pipeline is full and at the tail.

Outputs are asserted bit-exact across modes, and the record carries the
compiled executor's roofline terms (per-frame FLOPs / HBM bytes via
``engine.plan_cost``) to tie serving throughput back to the paper's
DRAM-traffic claim.

The ``server`` section measures the SRServer front door on a burst of
concurrent small requests:

* ``solo``      — each request submitted and resolved alone (every request
  dispatches its own bucket, the pre-server behavior).
* ``coalesced`` — the whole burst submitted before the first ``result()``,
  so the micro-batching scheduler packs all requests' frames into shared
  bucket-sized dispatches.

Per-request outputs are asserted bit-exact across the two modes; the
record keeps each mode's dispatch count and mean bucket fill ratio plus
the coalesced-vs-solo speedup.

The ``autotune`` section runs the roofline-guided schedule autotuner
(``engine.autotune``) per request batch size: candidates are pruned
analytically, survivors compiled + measured, and each config reports the
winning schedule, its predicted-vs-measured time (achieved fraction of
roofline) and the default-vs-tuned speedup (>= 1 by construction — the
schema checker fails CI if a tuned schedule ever regresses).  The
``pipeline`` section records the autotuner's ``tuned_depth`` verdict on
the sync-vs-pipelined question (depth 1 on CPU, where overlap buys
nothing).

    PYTHONPATH=src python benchmarks/engine_throughput.py            # CSV rows
    PYTHONPATH=src python benchmarks/engine_throughput.py --json    # + BENCH_engine.json
    PYTHONPATH=src python benchmarks/engine_throughput.py --quick   # CI smoke sizes

Also exposes ``rows()`` for the ``benchmarks/run.py`` harness.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.data.synthetic import sr_pair_batch
from repro.engine import SRServer, SRSession, bucket_batch, plan_cost
from repro.models.abpn import ABPNConfig, init_abpn

DEFAULT_BATCHES = (1, 4, 8)

# keys a BENCH_engine.json record must carry — checked by
# benchmarks/check_bench_schema.py (CI fails on drift)
RECORD_KEYS = (
    "bench", "backend", "precision", "vertical_policy", "lr_shape",
    "band_rows", "jax_backend", "platform", "batch", "cache", "pipeline",
    "roofline", "server", "autotune", "analysis", "sharding",
)
BATCH_KEYS = (
    "frames_per_s", "p50_ms", "p95_ms", "p99_ms", "mean_ms",
    "dispatch_mean_ms", "compile_s", "bucket", "batches",
)
PIPELINE_KEYS = (
    "clip_frames", "bucket", "chunks", "depth", "reps", "bit_exact",
    "sync", "pipelined", "speedup", "tuned_depth",
)
MODE_KEYS = (
    "frames_per_s", "p50_ms", "p99_ms", "mean_ms", "dispatch_mean_ms",
    "peak_inflight",
)
ROOFLINE_KEYS = (
    "batch", "flops", "hbm_bytes", "flops_per_frame", "hbm_bytes_per_frame",
    "weight_bytes_resident",
)
SERVER_KEYS = (
    "request_frames", "concurrent_requests", "reps", "solo", "coalesced",
    "speedup", "bit_exact",
)
SERVER_MODE_KEYS = (
    "frames_per_s", "dispatches_per_burst", "mean_fill_ratio", "bucket",
)
AUTOTUNE_KEYS = (
    "batches", "depths", "prune_ratio", "configs",
)
AUTOTUNE_CONFIG_KEYS = (
    "batch", "band_rows", "pipeline_depth", "bucket", "bucket_policy",
    "predicted_ms", "measured_ms", "default_ms", "achieved_fraction",
    "default_frames_per_s", "tuned_frames_per_s", "speedup",
    "candidates_total", "candidates_pruned",
)
# static-analysis gate outcome: per-checker finding counts + the verdict
ANALYSIS_KEYS = ("concurrency", "plan", "program", "clean")
ANALYSIS_SEVERITY_KEYS = ("error", "warning", "info")
# mesh-sharded serving scaling curve (benchmarks/sharding_scaling.py,
# run in a forced-multi-device subprocess); every point must be bit-exact
SHARDING_KEYS = (
    "device_count", "backend", "precision", "vertical_policy", "lr_shape",
    "frames", "reps", "points", "skipped",
)
SHARDING_POINT_KEYS = (
    "devices", "replicas", "band_shards", "frames_per_s", "scaling",
    "halo_bytes_per_frame", "replica_fill", "bit_exact",
)


def _session(layers, cfg, args_like) -> SRSession:
    return SRSession(
        layers,
        backend=args_like["backend"],
        precision=args_like["precision"],
        vertical_policy=args_like["vertical_policy"],
        band_rows=args_like["band_rows"],
        scale=cfg.scale,
        pipeline_depth=args_like.get("pipeline_depth", 2),
        autotune="off",  # bench sections measure DEFAULT schedules; the
        # autotune section is where tuned schedules are measured
    )


def measure_batches(layers, cfg, opts, batch_sizes, reps) -> tuple:
    """Serve ``reps`` requests per batch size through one session; return
    stats per size plus the session's compile-cache record."""
    session = _session(layers, cfg, opts)
    results = {}
    h, w = opts["height"], opts["width"]
    for bs in batch_sizes:
        session.reset_stats()
        frames, _ = sr_pair_batch(0, bs * reps, lr_shape=(h, w),
                                  scale=cfg.scale)
        for i in range(0, bs * reps, bs):
            session.upscale(frames[i : i + bs])
        s = session.stats()
        bucket = bucket_batch(bs)
        compile_s = next(
            e["compile_s"] for e in session.cache_stats()["entries"]
            if e["bucket"] == bucket
        )
        results[str(bs)] = {
            "frames_per_s": round(s["fps"], 2),
            "p50_ms": round(s["p50_ms"], 2),
            "p95_ms": round(s["p95_ms"], 2),
            "p99_ms": round(s["p99_ms"], 2),
            "mean_ms": round(s["mean_ms"], 2),
            "dispatch_mean_ms": round(s["dispatch_mean_ms"], 2),
            "compile_s": round(compile_s, 2),
            "bucket": bucket,
            "batches": s["batches"],
        }
    cache = session.cache_stats()
    cache["hit_rate"] = round(cache["hit_rate"], 4)
    for e in cache["entries"]:
        e["compile_s"] = round(e["compile_s"], 2)
    for st in cache["stacks"]:
        st["prepare_s"] = round(st["prepare_s"], 4)
    return results, cache


def measure_pipeline(layers, cfg, opts, *, bucket, chunks, reps) -> dict:
    """One ``chunks * bucket``-frame clip served end-to-end in sync
    (depth 1) vs pipelined (depth 2) mode; steady-state fps over ``reps``
    passes, outputs checked bit-exact."""
    h, w = opts["height"], opts["width"]
    n = bucket * chunks
    clip, _ = sr_pair_batch(1, n, lr_shape=(h, w), scale=cfg.scale)
    modes = (("sync", 1), ("pipelined", 2))
    out = {"clip_frames": n, "bucket": bucket, "chunks": chunks,
           "depth": dict(modes)["pipelined"], "reps": reps}
    results = {}
    for mode, depth in modes:
        session = _session(layers, cfg, {**opts, "pipeline_depth": depth})
        session.max_bucket = bucket
        hr = session.upscale(clip)  # compile pass (outside the stats)
        session.reset_stats()
        for _ in range(reps):
            hr = session.upscale(clip)
        s = session.stats()
        results[mode] = hr
        out[mode] = {
            "frames_per_s": round(s["fps"], 2),
            "p50_ms": round(s["p50_ms"], 2),
            "p99_ms": round(s["p99_ms"], 2),
            "mean_ms": round(s["mean_ms"], 2),
            "dispatch_mean_ms": round(s["dispatch_mean_ms"], 2),
            "peak_inflight": s["peak_inflight"],
        }
    out["bit_exact"] = bool(
        np.array_equal(np.asarray(results["sync"]),
                       np.asarray(results["pipelined"]))
    )
    out["speedup"] = round(
        out["pipelined"]["frames_per_s"] / max(out["sync"]["frames_per_s"], 1e-9),
        3,
    )
    # the autotuner's measured pass is the ARBITER of pipeline depth: its
    # bounded-inflight dispatch loop measures depths 1..2 head-to-head and
    # ties prefer the shallower pipeline — on CPU (where overlap buys
    # nothing and depth 2 holds an extra slab live) this selects depth 1
    from repro.engine.autotune import tune

    probe = _session(layers, cfg, opts)
    plan = probe.plan_for((h, w, cfg.in_channels))
    entry = tune(layers, plan, bucket, depths=(1, 2), chunks=chunks,
                 reps=reps, max_band_candidates=1)
    out["tuned_depth"] = int(entry.pipeline_depth)
    return out


def measure_server(layers, cfg, opts, *, req_frames, n_requests, reps) -> dict:
    """Coalesced vs solo serving of ``n_requests`` concurrent
    ``req_frames``-frame requests through an ``SRServer``.

    Solo resolves each request before submitting the next (every request
    pays its own bucket dispatch); coalesced submits the whole burst
    first, so the scheduler packs the burst into shared bucket-sized
    dispatches.  Outputs are checked bit-exact per request across modes.
    """
    h, w = opts["height"], opts["width"]
    total = req_frames * n_requests
    clip, _ = sr_pair_batch(2, total, lr_shape=(h, w), scale=cfg.scale)
    requests = [clip[i * req_frames:(i + 1) * req_frames]
                for i in range(n_requests)]
    out = {"request_frames": req_frames, "concurrent_requests": n_requests,
           "reps": reps}
    results = {}
    for mode in ("solo", "coalesced"):
        session = _session(layers, cfg, opts)
        session.max_bucket = bucket_batch(total)
        server = SRServer({"bench": session})

        def burst():
            if mode == "solo":
                return [server.submit(r).result() for r in requests]
            futs = [server.submit(r) for r in requests]
            return [f.result() for f in futs]

        burst()  # compile pass for this mode's bucket (outside the timing)
        before = server.scheduler_stats()
        t0 = time.perf_counter()
        for _ in range(reps):
            hrs = burst()
        dt = time.perf_counter() - t0
        after = server.scheduler_stats()
        dispatches = after["dispatches"] - before["dispatches"]
        real = after["frames_dispatched"] - before["frames_dispatched"]
        slots = after["slots_dispatched"] - before["slots_dispatched"]
        results[mode] = hrs
        out[mode] = {
            "frames_per_s": round(total * reps / dt, 2) if dt > 0 else 0.0,
            "dispatches_per_burst": dispatches / reps,
            "mean_fill_ratio": round(real / slots, 4) if slots else 0.0,
            "bucket": int(after["recent_dispatches"][-1]["bucket"]),
        }
    out["bit_exact"] = bool(all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(results["solo"], results["coalesced"])
    ))
    out["speedup"] = round(
        out["coalesced"]["frames_per_s"] / max(out["solo"]["frames_per_s"], 1e-9),
        3,
    )
    return out


def measure_autotune(layers, cfg, opts, *, batches, depths, reps) -> dict:
    """The autotuner section: per request batch, sweep the legal schedule
    space (roofline-pruned, then measured) and report the winner against
    the default schedule.

    ``predicted_ms`` is the winner's analytic roofline time;
    ``achieved_fraction`` is predicted/measured (how close the measured
    schedule runs to its roofline bound); ``speedup`` is default_ms /
    tuned_ms — >= 1 by construction (the default candidate is always
    measured, never pruned, and the winner never measures worse).
    """
    from repro.engine.autotune import tune
    from repro.engine.plan import SRPlan

    h, w = opts["height"], opts["width"]
    plan = SRPlan.from_request(
        (h, w, cfg.in_channels),
        num_layers=len(layers),
        band_rows=opts["band_rows"],
        vertical_policy=opts["vertical_policy"],
        backend=opts["backend"],
        precision=opts["precision"],
        scale=cfg.scale,
    )
    configs = []
    for batch in batches:
        entry = tune(layers, plan, batch, depths=depths, reps=reps)
        cands = entry.candidates
        configs.append({
            "batch": int(batch),
            "band_rows": entry.band_rows,
            "pipeline_depth": entry.pipeline_depth,
            "bucket": entry.bucket,
            "bucket_policy": entry.bucket_policy,
            "predicted_ms": round(entry.predicted_ms, 3),
            "measured_ms": round(entry.measured_ms, 3),
            "default_ms": round(entry.default_ms, 3),
            "achieved_fraction": round(
                entry.predicted_ms / max(entry.measured_ms, 1e-9), 4),
            "default_frames_per_s": round(1e3 / max(entry.default_ms, 1e-9), 2),
            "tuned_frames_per_s": round(1e3 / max(entry.measured_ms, 1e-9), 2),
            "speedup": round(entry.speedup, 3),
            "candidates_total": len(cands),
            "candidates_pruned": sum(c.pruned for c in cands),
        })
    return {
        "batches": [int(b) for b in batches],
        "depths": [int(d) for d in depths],
        "prune_ratio": 1.5,
        "configs": configs,
    }


def measure_sharding(*, quick: bool = False, devices: int = 8) -> dict:
    """The mesh-sharded serving scaling curve (the ``sharding`` section).

    JAX fixes its device list at initialisation, so the multi-device sweep
    cannot run in this (already single-device) process: spawn
    ``benchmarks/sharding_scaling.py`` with forced host devices and adopt
    its JSON record verbatim.
    """
    import subprocess
    import sys

    script = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "sharding_scaling.py")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, script, "--json-only"]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharding_scaling.py failed:\n{proc.stderr[-3000:]}")
    return json.loads(proc.stdout)


def measure_analysis() -> dict:
    """The static-verification gate's outcome, recorded alongside the
    perf sections: per-checker finding counts by severity plus the
    ``clean`` verdict (``python -m repro.analysis --all`` on this exact
    tree).  A record with ``clean: false`` fails the schema check — perf
    numbers from a tree that violates its own static invariants are not
    comparable."""
    from repro.analysis.sweep import analysis_report

    return analysis_report()


def measure(
    *,
    backend: str = "tilted",
    precision: str = "fp32",
    vertical_policy: str = "zero",
    height: int = 120,
    width: int = 64,
    band_rows: int | None = None,
    batch_sizes=DEFAULT_BATCHES,
    reps: int = 4,
    pipe_bucket: int = 4,
    pipe_chunks: int = 4,
    srv_request_frames: int = 2,
    srv_requests: int = 4,
    tune_batches=(1, 3, 4),
    tune_depths=(1, 2),
    sharding_quick: bool = False,
    sharding_devices: int = 8,
) -> dict:
    """The full benchmark record: per-batch-size stats, the pipelined-vs-
    sync clip comparison, the server coalesced-vs-solo comparison, and the
    compiled executor's roofline terms."""
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(0), cfg)
    opts = {
        "backend": backend,
        "precision": precision,
        "vertical_policy": vertical_policy,
        "height": height,
        "width": width,
        "band_rows": band_rows,
    }
    batch, cache = measure_batches(layers, cfg, opts, batch_sizes, reps)
    pipeline = measure_pipeline(
        layers, cfg, opts, bucket=pipe_bucket, chunks=pipe_chunks, reps=reps
    )
    server = measure_server(
        layers, cfg, opts, req_frames=srv_request_frames,
        n_requests=srv_requests, reps=reps,
    )
    autotune = measure_autotune(
        layers, cfg, opts, batches=tune_batches, depths=tune_depths,
        reps=reps,
    )
    probe = _session(layers, cfg, opts)
    plan = probe.plan_for((height, width, cfg.in_channels))
    roofline = plan_cost(plan, layers, pipe_bucket)
    return {
        "bench": "engine_throughput",
        "backend": backend,
        "precision": precision,
        "vertical_policy": vertical_policy,
        "lr_shape": [height, width, cfg.in_channels],
        "band_rows": plan.band_rows,
        "jax_backend": jax.default_backend(),
        "platform": platform.platform(),
        "batch": batch,
        "cache": cache,
        "pipeline": pipeline,
        "server": server,
        "roofline": roofline,
        "autotune": autotune,
        "analysis": measure_analysis(),
        "sharding": measure_sharding(quick=sharding_quick,
                                     devices=sharding_devices),
    }


def rows():
    """Harness rows (kept small: batch 1 and 4, few reps)."""
    t0 = time.perf_counter()
    rec = measure(batch_sizes=(1, 4), reps=3, pipe_bucket=2, pipe_chunks=4,
                  tune_batches=(1, 3), sharding_quick=True)
    us = (time.perf_counter() - t0) * 1e6
    out = []
    for bs, r in rec["batch"].items():
        out.append((f"engine.throughput.b{bs}", us,
                    f"{r['frames_per_s']:.1f} frames/s, p50 {r['p50_ms']:.1f} ms "
                    f"({rec['backend']}/{rec['precision']})"))
    p = rec["pipeline"]
    out.append(("engine.pipeline.speedup", us,
                f"pipelined {p['pipelined']['frames_per_s']:.1f} vs sync "
                f"{p['sync']['frames_per_s']:.1f} frames/s "
                f"(x{p['speedup']:.2f}, bit_exact={p['bit_exact']})"))
    v = rec["server"]
    out.append(("engine.server.coalesce", us,
                f"coalesced {v['coalesced']['frames_per_s']:.1f} vs solo "
                f"{v['solo']['frames_per_s']:.1f} frames/s "
                f"(x{v['speedup']:.2f}, fill "
                f"{v['coalesced']['mean_fill_ratio']:.2f} vs "
                f"{v['solo']['mean_fill_ratio']:.2f}, "
                f"bit_exact={v['bit_exact']})"))
    for t in rec["autotune"]["configs"]:
        out.append((f"engine.autotune.b{t['batch']}", us,
                    f"tuned {t['tuned_frames_per_s']:.1f} vs default "
                    f"{t['default_frames_per_s']:.1f} frames/s "
                    f"(x{t['speedup']:.2f}, bucket {t['bucket']} "
                    f"{t['bucket_policy']}, depth {t['pipeline_depth']}, "
                    f"{t['achieved_fraction']:.0%} of roofline)"))
    for pt in rec["sharding"]["points"]:
        out.append((f"engine.sharding.r{pt['replicas']}s{pt['band_shards']}",
                    us,
                    f"{pt['frames_per_s']:.1f} frames/s on {pt['devices']} "
                    f"device(s) (x{pt['scaling']:.2f} vs 1, "
                    f"bit_exact={pt['bit_exact']})"))
    c = rec["cache"]
    out.append(("engine.plan_cache", us,
                f"{c['misses']} compiles, hit rate {c['hit_rate']:.2f}"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_engine.json next to this script's repo root")
    ap.add_argument("--json-path", default=None,
                    help="explicit output path for the JSON record")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes: tiny shapes, 2 batch sizes, 2 reps")
    ap.add_argument("--backend", default="tilted",
                    choices=["reference", "tilted", "kernel"])
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "int8"])
    ap.add_argument("--policy", default="zero",
                    choices=["zero", "halo", "replicate"],
                    help="vertical band boundary policy (all backends)")
    ap.add_argument("--height", type=int, default=120)
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--band-rows", type=int, default=None,
                    help="band height (default: derived from --height)")
    ap.add_argument("--reps", type=int, default=4)
    ap.add_argument("--batches", type=int, nargs="+", default=list(DEFAULT_BATCHES))
    ap.add_argument("--pipe-bucket", type=int, default=4,
                    help="chunk size of the pipelined-vs-sync clip")
    ap.add_argument("--pipe-chunks", type=int, default=4,
                    help="chunks in the pipelined-vs-sync clip (>= 4 shows "
                         "steady-state overlap)")
    args = ap.parse_args()

    kw = dict(backend=args.backend, precision=args.precision,
              vertical_policy=args.policy,
              height=args.height, width=args.width,
              band_rows=args.band_rows,
              batch_sizes=tuple(args.batches), reps=args.reps,
              pipe_bucket=args.pipe_bucket, pipe_chunks=args.pipe_chunks)
    if args.quick:
        kw.update(height=24, width=16, batch_sizes=(1, 2), reps=2,
                  pipe_bucket=2, pipe_chunks=4,
                  srv_request_frames=1, srv_requests=2,
                  tune_batches=(1, 3), sharding_quick=True)
    rec = measure(**kw)
    print("name,us_per_call,derived")
    for bs, r in rec["batch"].items():
        print(f'engine.throughput.b{bs},{r["mean_ms"] * 1e3:.1f},'
              f'"{r["frames_per_s"]:.1f} frames/s p50 {r["p50_ms"]:.1f} ms '
              f'p99 {r["p99_ms"]:.1f} ms (bucket {r["bucket"]}, '
              f'compile {r["compile_s"]:.2f}s)"')
    p = rec["pipeline"]
    print(f'engine.pipeline.sync,{p["sync"]["mean_ms"] * 1e3:.1f},'
          f'"{p["sync"]["frames_per_s"]:.1f} frames/s on '
          f'{p["chunks"]}x{p["bucket"]} clip"')
    print(f'engine.pipeline.pipelined,{p["pipelined"]["mean_ms"] * 1e3:.1f},'
          f'"{p["pipelined"]["frames_per_s"]:.1f} frames/s '
          f'(x{p["speedup"]:.2f} vs sync, bit_exact={p["bit_exact"]}, '
          f'tuned_depth={p["tuned_depth"]})"')
    v = rec["server"]
    print(f'engine.server.solo,0.0,'
          f'"{v["solo"]["frames_per_s"]:.1f} frames/s, '
          f'{v["solo"]["dispatches_per_burst"]:.1f} dispatches/burst '
          f'(bucket {v["solo"]["bucket"]}, fill '
          f'{v["solo"]["mean_fill_ratio"]:.2f})"')
    print(f'engine.server.coalesced,0.0,'
          f'"{v["coalesced"]["frames_per_s"]:.1f} frames/s, '
          f'{v["coalesced"]["dispatches_per_burst"]:.1f} dispatches/burst '
          f'(bucket {v["coalesced"]["bucket"]}, fill '
          f'{v["coalesced"]["mean_fill_ratio"]:.2f}, '
          f'x{v["speedup"]:.2f} vs solo, bit_exact={v["bit_exact"]})"')
    r = rec["roofline"]
    print(f'engine.roofline.b{r["batch"]},0.0,'
          f'"{r["hbm_bytes_per_frame"] / 1e6:.2f} MB HBM/frame, '
          f'{r["flops_per_frame"] / 1e9:.2f} GFLOP/frame, '
          f'{r["weight_bytes_resident"] / 1e3:.1f} kB weights resident"')
    for t in rec["autotune"]["configs"]:
        print(f'engine.autotune.b{t["batch"]},{t["measured_ms"] * 1e3:.1f},'
              f'"tuned {t["tuned_frames_per_s"]:.1f} vs default '
              f'{t["default_frames_per_s"]:.1f} frames/s '
              f'(x{t["speedup"]:.2f}, bucket {t["bucket"]} '
              f'{t["bucket_policy"]}, depth {t["pipeline_depth"]}, band '
              f'{t["band_rows"]}, {t["achieved_fraction"]:.0%} of roofline, '
              f'{t["candidates_pruned"]}/{t["candidates_total"]} pruned)"')
    for pt in rec["sharding"]["points"]:
        print(f'engine.sharding.r{pt["replicas"]}s{pt["band_shards"]},0.0,'
              f'"{pt["frames_per_s"]:.1f} frames/s on {pt["devices"]} '
              f'device(s) (x{pt["scaling"]:.2f} vs 1 device, '
              f'{pt["halo_bytes_per_frame"] / 1e3:.1f} kB halo/frame, '
              f'fill {pt["replica_fill"]:.2f}, bit_exact={pt["bit_exact"]})"')
    for s in rec["sharding"]["skipped"]:
        print(f'# sharding skipped ({s["replicas"]}x{s["band_shards"]}): '
              f'{s["reason"]}')
    c = rec["cache"]
    print(f'engine.plan_cache,0.0,"{c["misses"]} compiles {c["hits"]} hits '
          f'hit rate {c["hit_rate"]:.2f}"')
    if args.json or args.json_path:
        if args.json_path:
            path = args.json_path
        else:
            root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
            path = os.path.join(root, "BENCH_engine.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
