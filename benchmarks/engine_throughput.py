"""Engine serving throughput: frames/s and p50/p95 latency per batch size.

The measurement the tentpole refactor exists for: a batch of LR frames runs
through ONE jitted engine call (no Python loop over frames or bands), so
throughput should scale with batch size until the backend saturates.

    PYTHONPATH=src python benchmarks/engine_throughput.py            # CSV rows
    PYTHONPATH=src python benchmarks/engine_throughput.py --json    # + BENCH_engine.json

Also exposes ``rows()`` for the ``benchmarks/run.py`` harness.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax

from repro.data.synthetic import sr_pair_batch
from repro.engine import VideoStream, make_plan
from repro.models.abpn import ABPNConfig, init_abpn

DEFAULT_BATCHES = (1, 4, 8)


def measure(
    *,
    backend: str = "tilted",
    precision: str = "fp32",
    vertical_policy: str = "zero",
    height: int = 120,
    width: int = 64,
    band_rows: int = 60,
    batch_sizes=DEFAULT_BATCHES,
    reps: int = 4,
) -> dict:
    """Serve ``reps`` batches per batch size; return the stats per size."""
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(0), cfg)
    plan = make_plan(layers, (height, width, cfg.in_channels),
                     band_rows=band_rows, backend=backend,
                     vertical_policy=vertical_policy,
                     precision=precision, scale=cfg.scale)
    results = {}
    for bs in batch_sizes:
        stream = VideoStream(plan, layers, batch_size=bs)
        compile_s = stream.warmup()
        frames, _ = sr_pair_batch(0, bs * reps, lr_shape=(height, width),
                                  scale=cfg.scale)
        stream.run(frames)
        s = stream.stats()
        results[str(bs)] = {
            "frames_per_s": round(s["fps"], 2),
            "p50_ms": round(s["p50_ms"], 2),
            "p95_ms": round(s["p95_ms"], 2),
            "mean_ms": round(s["mean_ms"], 2),
            "compile_s": round(compile_s, 2),
            "batches": s["batches"],
        }
    return {
        "bench": "engine_throughput",
        "backend": backend,
        "precision": precision,
        "vertical_policy": vertical_policy,
        "lr_shape": [height, width, cfg.in_channels],
        "band_rows": band_rows,
        "jax_backend": jax.default_backend(),
        "platform": platform.platform(),
        "batch": results,
    }


def rows():
    """Harness rows (kept small: batch 1 and 4, few reps)."""
    t0 = time.perf_counter()
    rec = measure(batch_sizes=(1, 4), reps=3)
    us = (time.perf_counter() - t0) * 1e6
    out = []
    for bs, r in rec["batch"].items():
        out.append((f"engine.throughput.b{bs}", us,
                    f"{r['frames_per_s']:.1f} frames/s, p50 {r['p50_ms']:.1f} ms "
                    f"({rec['backend']}/{rec['precision']})"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_engine.json next to this script's repo root")
    ap.add_argument("--backend", default="tilted",
                    choices=["reference", "tilted", "kernel"])
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "int8"])
    ap.add_argument("--policy", default="zero",
                    choices=["zero", "halo", "replicate"],
                    help="vertical band boundary policy (all backends)")
    ap.add_argument("--height", type=int, default=120)
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--reps", type=int, default=4)
    ap.add_argument("--batches", type=int, nargs="+", default=list(DEFAULT_BATCHES))
    args = ap.parse_args()

    rec = measure(backend=args.backend, precision=args.precision,
                  vertical_policy=args.policy,
                  height=args.height, width=args.width,
                  batch_sizes=tuple(args.batches), reps=args.reps)
    print("name,us_per_call,derived")
    for bs, r in rec["batch"].items():
        print(f'engine.throughput.b{bs},{r["mean_ms"] * 1e3:.1f},'
              f'"{r["frames_per_s"]:.1f} frames/s p50 {r["p50_ms"]:.1f} ms '
              f'p95 {r["p95_ms"]:.1f} ms"')
    if args.json:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        path = os.path.join(root, "BENCH_engine.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
