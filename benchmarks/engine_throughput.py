"""Engine serving throughput: frames/s, p50/p95 latency, compile cache.

The measurement the serving API exists for: batched requests stream
through an ``SRSession``, whose plan cache compiles ONE executor per
(plan, batch bucket, dtype) — so throughput scales with batch size and
repeat requests are pure cache hits.  Records per-bucket compile time and
the session's cache hit-rate alongside the latency stats.

    PYTHONPATH=src python benchmarks/engine_throughput.py            # CSV rows
    PYTHONPATH=src python benchmarks/engine_throughput.py --json    # + BENCH_engine.json

Also exposes ``rows()`` for the ``benchmarks/run.py`` harness.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax

from repro.data.synthetic import sr_pair_batch
from repro.engine import SRSession, bucket_batch
from repro.models.abpn import ABPNConfig, init_abpn

DEFAULT_BATCHES = (1, 4, 8)


def measure(
    *,
    backend: str = "tilted",
    precision: str = "fp32",
    vertical_policy: str = "zero",
    height: int = 120,
    width: int = 64,
    band_rows: int | None = None,
    batch_sizes=DEFAULT_BATCHES,
    reps: int = 4,
) -> dict:
    """Serve ``reps`` requests per batch size through one session; return
    the stats per size plus the session's compile-cache record."""
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(0), cfg)
    session = SRSession(
        layers,
        backend=backend,
        precision=precision,
        vertical_policy=vertical_policy,
        band_rows=band_rows,
        scale=cfg.scale,
    )
    results = {}
    for bs in batch_sizes:
        session.reset_stats()
        frames, _ = sr_pair_batch(0, bs * reps, lr_shape=(height, width),
                                  scale=cfg.scale)
        for i in range(0, bs * reps, bs):
            session.upscale(frames[i : i + bs])
        s = session.stats()
        bucket = bucket_batch(bs)
        compile_s = next(
            e["compile_s"] for e in session.cache_stats()["entries"]
            if e["bucket"] == bucket
        )
        results[str(bs)] = {
            "frames_per_s": round(s["fps"], 2),
            "p50_ms": round(s["p50_ms"], 2),
            "p95_ms": round(s["p95_ms"], 2),
            "mean_ms": round(s["mean_ms"], 2),
            "compile_s": round(compile_s, 2),
            "bucket": bucket,
            "batches": s["batches"],
        }
    cache = session.cache_stats()
    cache["hit_rate"] = round(cache["hit_rate"], 4)
    for e in cache["entries"]:
        e["compile_s"] = round(e["compile_s"], 2)
    plan = session.plan_for((height, width, cfg.in_channels))
    return {
        "bench": "engine_throughput",
        "backend": backend,
        "precision": precision,
        "vertical_policy": vertical_policy,
        "lr_shape": [height, width, cfg.in_channels],
        "band_rows": plan.band_rows,
        "jax_backend": jax.default_backend(),
        "platform": platform.platform(),
        "batch": results,
        "cache": cache,
    }


def rows():
    """Harness rows (kept small: batch 1 and 4, few reps)."""
    t0 = time.perf_counter()
    rec = measure(batch_sizes=(1, 4), reps=3)
    us = (time.perf_counter() - t0) * 1e6
    out = []
    for bs, r in rec["batch"].items():
        out.append((f"engine.throughput.b{bs}", us,
                    f"{r['frames_per_s']:.1f} frames/s, p50 {r['p50_ms']:.1f} ms "
                    f"({rec['backend']}/{rec['precision']})"))
    c = rec["cache"]
    out.append(("engine.plan_cache", us,
                f"{c['misses']} compiles, hit rate {c['hit_rate']:.2f}"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_engine.json next to this script's repo root")
    ap.add_argument("--backend", default="tilted",
                    choices=["reference", "tilted", "kernel"])
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "int8"])
    ap.add_argument("--policy", default="zero",
                    choices=["zero", "halo", "replicate"],
                    help="vertical band boundary policy (all backends)")
    ap.add_argument("--height", type=int, default=120)
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--band-rows", type=int, default=None,
                    help="band height (default: derived from --height)")
    ap.add_argument("--reps", type=int, default=4)
    ap.add_argument("--batches", type=int, nargs="+", default=list(DEFAULT_BATCHES))
    args = ap.parse_args()

    rec = measure(backend=args.backend, precision=args.precision,
                  vertical_policy=args.policy,
                  height=args.height, width=args.width,
                  band_rows=args.band_rows,
                  batch_sizes=tuple(args.batches), reps=args.reps)
    print("name,us_per_call,derived")
    for bs, r in rec["batch"].items():
        print(f'engine.throughput.b{bs},{r["mean_ms"] * 1e3:.1f},'
              f'"{r["frames_per_s"]:.1f} frames/s p50 {r["p50_ms"]:.1f} ms '
              f'p95 {r["p95_ms"]:.1f} ms (bucket {r["bucket"]}, '
              f'compile {r["compile_s"]:.2f}s)"')
    c = rec["cache"]
    print(f'engine.plan_cache,0.0,"{c["misses"]} compiles {c["hits"]} hits '
          f'hit rate {c["hit_rate"]:.2f}"')
    if args.json:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        path = os.path.join(root, "BENCH_engine.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
