"""Paper §II claim: the tilted scheme's top/bottom information loss costs
<0.2 dB.  We measure PSNR(banded output, exact output) and the per-policy
deltas on synthetic textures at the paper's geometry (360x640, 6 bands).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

import jax

from repro.data.synthetic import sr_pair_batch
from repro.models.abpn import ABPNConfig, apply_abpn, init_abpn


def _psnr(a, b):
    mse = float(jnp.mean((a - b) ** 2))
    return 10 * np.log10(1.0 / max(mse, 1e-12))


def rows(height: int = 120, width: int = 64, n: int = 2):
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(0), cfg)
    lr_imgs, hr_imgs = sr_pair_batch(0, n, lr_shape=(height, width), scale=3)

    t0 = time.perf_counter()
    out = []
    psnrs = {"zero": [], "replicate": []}
    gt = {"zero": [], "replicate": []}
    for i in range(n):
        exact = apply_abpn(layers, lr_imgs[i], cfg, method="tilted",
                           band_rows=60, vertical_policy="halo")
        for policy in ("zero", "replicate"):
            banded = apply_abpn(layers, lr_imgs[i], cfg, method="tilted",
                                band_rows=60, vertical_policy=policy)
            psnrs[policy].append(_psnr(banded, exact))
            # end-metric deltas vs ground truth HR
            gt[policy].append(_psnr(exact, hr_imgs[i]) - _psnr(banded, hr_imgs[i]))
    us = (time.perf_counter() - t0) * 1e6 / max(n * 2, 1)
    for policy in ("zero", "replicate"):
        out.append((f"psnr.banded_vs_exact.{policy}", us,
                    f"{np.mean(psnrs[policy]):.1f} dB fidelity"))
        out.append((f"psnr.gt_penalty.{policy}", us,
                    f"{np.mean(gt[policy]):+.3f} dB (paper bound 0.2 dB)"))
    return out
