"""Paper §II claim: the tilted scheme's top/bottom information loss costs
<0.2 dB.  We measure PSNR(banded output, exact output) and the per-policy
deltas on synthetic textures at the paper's geometry (360x640, 6 bands).

Runs through the batched engine: one plan per vertical policy, each serving
ALL frames in a single jitted call.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.data.synthetic import sr_pair_batch
from repro.models.abpn import ABPNConfig, init_abpn


def _psnr(a, b):
    mse = float(jnp.mean((a - b) ** 2))
    return 10 * np.log10(1.0 / max(mse, 1e-12))


def rows(height: int = 120, width: int = 64, n: int = 2):
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(0), cfg)
    lr_imgs, hr_imgs = sr_pair_batch(0, n, lr_shape=(height, width), scale=3)

    def run_policy(policy):
        plan = engine.make_plan(layers, lr_imgs.shape[1:], band_rows=60,
                                backend="tilted", vertical_policy=policy,
                                scale=cfg.scale)
        return engine.run(plan, layers, lr_imgs)  # whole batch, one call

    t0 = time.perf_counter()
    out = []
    exact = run_policy("halo")
    banded = {policy: run_policy(policy) for policy in ("zero", "replicate")}
    us = (time.perf_counter() - t0) * 1e6 / max(n * 3, 1)
    for policy, hr in banded.items():
        fid = [_psnr(hr[i], exact[i]) for i in range(n)]
        # end-metric deltas vs ground truth HR
        pen = [_psnr(exact[i], hr_imgs[i]) - _psnr(hr[i], hr_imgs[i])
               for i in range(n)]
        out.append((f"psnr.banded_vs_exact.{policy}", us,
                    f"{np.mean(fid):.1f} dB fidelity"))
        out.append((f"psnr.gt_penalty.{policy}", us,
                    f"{np.mean(pen):+.3f} dB (paper bound 0.2 dB)"))
    return out
