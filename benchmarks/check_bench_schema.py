"""Validate committed benchmark records against their schemas.

CI's bench-smoke and load-smoke jobs regenerate quick records and run
this against both the fresh output and the committed JSON, so schema
drift (renamed/dropped keys, a missing pipelined-mode entry, a broken
bit-exactness or SLO guarantee) fails the build instead of silently
rotting the recorded numbers.

    PYTHONPATH=src python benchmarks/check_bench_schema.py [path ...]

Records are dispatched on their ``bench`` field: ``server_load``
records (benchmarks/server_load.py) get the load-harness checks,
``temporal_delta`` records (benchmarks/temporal_delta.py) get the
delta-serving checks; any other record is assumed to be a
BENCH_engine.json engine record.

No third-party schema library: the required key sets live next to the
producer (``engine_throughput.RECORD_KEYS``,
``server_load.LOAD_RECORD_KEYS``, ...), so adding a field means
updating producer and checker in the same place.
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from engine_throughput import (  # noqa: E402
    ANALYSIS_KEYS,
    ANALYSIS_SEVERITY_KEYS,
    AUTOTUNE_CONFIG_KEYS,
    AUTOTUNE_KEYS,
    BATCH_KEYS,
    MODE_KEYS,
    PIPELINE_KEYS,
    RECORD_KEYS,
    ROOFLINE_KEYS,
    SERVER_KEYS,
    SERVER_MODE_KEYS,
    SHARDING_KEYS,
    SHARDING_POINT_KEYS,
)
from server_load import (  # noqa: E402
    ACCEPTANCE_KEYS,
    CALIBRATION_KEYS,
    FAULT_KEYS,
    LOAD_MODE_KEYS,
    LOAD_POINT_KEYS,
    LOAD_RECORD_KEYS,
)
from temporal_delta import (  # noqa: E402
    MIN_STATIC_COMPUTE_REDUCTION,
    TEMPORAL_ACCEPTANCE_KEYS,
    TEMPORAL_CACHE_KEYS,
    TEMPORAL_CLIP_KEYS,
    TEMPORAL_RECORD_KEYS,
)


def _require(obj: dict, keys, where: str, errors: list) -> None:
    missing = [k for k in keys if k not in obj]
    if missing:
        errors.append(f"{where}: missing keys {missing}")


def check_record(rec: dict) -> list:
    """All schema violations in one record (empty list = valid)."""
    errors: list = []
    _require(rec, RECORD_KEYS, "record", errors)
    for bs, r in rec.get("batch", {}).items():
        _require(r, BATCH_KEYS, f"batch[{bs}]", errors)
    pipe = rec.get("pipeline", {})
    _require(pipe, PIPELINE_KEYS, "pipeline", errors)
    for mode in ("sync", "pipelined"):
        _require(pipe.get(mode, {}), MODE_KEYS, f"pipeline.{mode}", errors)
    _require(rec.get("roofline", {}), ROOFLINE_KEYS, "roofline", errors)
    if pipe.get("bit_exact") is not True:
        errors.append(
            "pipeline.bit_exact must be true — pipelined serving changed "
            "the output"
        )
    if pipe.get("chunks", 0) < 4:
        errors.append(
            "pipeline comparison must run on a >= 4-chunk clip "
            f"(got chunks={pipe.get('chunks')})"
        )
    server = rec.get("server", {})
    _require(server, SERVER_KEYS, "server", errors)
    for mode in ("solo", "coalesced"):
        _require(server.get(mode, {}), SERVER_MODE_KEYS,
                 f"server.{mode}", errors)
    if server.get("bit_exact") is not True:
        errors.append(
            "server.bit_exact must be true — coalesced serving changed a "
            "request's output"
        )
    solo_d = server.get("solo", {}).get("dispatches_per_burst")
    coal_d = server.get("coalesced", {}).get("dispatches_per_burst")
    if solo_d is not None and coal_d is not None and coal_d > solo_d:
        errors.append(
            "server.coalesced must not dispatch MORE than solo serving "
            f"(coalesced {coal_d} vs solo {solo_d} per burst)"
        )
    tuned = rec.get("autotune", {})
    _require(tuned, AUTOTUNE_KEYS, "autotune", errors)
    configs = tuned.get("configs", [])
    if not configs:
        errors.append("autotune.configs must list at least one swept config")
    for i, t in enumerate(configs):
        _require(t, AUTOTUNE_CONFIG_KEYS, f"autotune.configs[{i}]", errors)
        # the tuner's hard guarantee: the tuned schedule NEVER regresses
        # below the default (the default candidate is always measured)
        sp = t.get("speedup")
        if sp is not None and sp < 1.0:
            errors.append(
                f"autotune.configs[{i}] (batch {t.get('batch')}): tuned "
                f"schedule regressed below default (speedup {sp} < 1.0)"
            )
        frac = t.get("achieved_fraction")
        if frac is not None and frac <= 0:
            errors.append(
                f"autotune.configs[{i}]: achieved_fraction {frac} must be "
                "positive (roofline prediction or measurement is broken)"
            )
    depth = rec.get("pipeline", {}).get("tuned_depth")
    if depth is not None and not 1 <= depth <= 4:
        errors.append(f"pipeline.tuned_depth {depth} outside the legal 1..4")
    analysis = rec.get("analysis", {})
    _require(analysis, ANALYSIS_KEYS, "analysis", errors)
    for checker in ("concurrency", "plan", "program"):
        counts = analysis.get(checker, {})
        _require(counts, ANALYSIS_SEVERITY_KEYS,
                 f"analysis.{checker}", errors)
        n_err = counts.get("error")
        if n_err is not None and n_err != 0:
            errors.append(
                f"analysis.{checker} recorded {n_err} error-level "
                "finding(s) — the static gate must be clean when a bench "
                "record is produced"
            )
    if analysis and analysis.get("clean") is not True:
        errors.append(
            "analysis.clean must be true — perf numbers from a tree that "
            "violates its own static invariants are not comparable"
        )
    sharding = rec.get("sharding", {})
    _require(sharding, SHARDING_KEYS, "sharding", errors)
    points = sharding.get("points", [])
    if not points:
        errors.append("sharding.points must hold at least one scaling point")
    for i, p in enumerate(points):
        _require(p, SHARDING_POINT_KEYS, f"sharding.points[{i}]", errors)
        if p.get("bit_exact") is not True:
            errors.append(
                f"sharding.points[{i}] "
                f"({p.get('replicas')}x{p.get('band_shards')}): bit_exact "
                "must be true — sharded execution changed the output"
            )
    if points and not any(p.get("devices", 0) > 1 for p in points):
        errors.append(
            "sharding.points must include at least one multi-device "
            "topology — a 1-device-only curve proves nothing about the "
            "sharded executor"
        )
    return errors


def check_server_load(rec: dict) -> list:
    """All violations in one server_load record (empty list = valid)."""
    errors: list = []
    _require(rec, LOAD_RECORD_KEYS, "record", errors)
    _require(rec.get("calibration", {}), CALIBRATION_KEYS,
             "calibration", errors)
    points = rec.get("points", [])
    if not points:
        errors.append("points must hold at least one load point")
    for i, p in enumerate(points):
        _require(p, LOAD_POINT_KEYS, f"points[{i}]", errors)
        for mode in ("block", "hardened"):
            _require(p.get(mode, {}), LOAD_MODE_KEYS,
                     f"points[{i}].{mode}", errors)
    acc = rec.get("acceptance", {})
    _require(acc, ACCEPTANCE_KEYS, "acceptance", errors)
    # the headline claim: at the overload point, shedding + degradation
    # hold the served tail inside the SLO while plain blocking admission
    # at the same offered rate does not
    if acc.get("hardened_within_slo") is not True:
        errors.append(
            "acceptance.hardened_within_slo must be true — the hardened "
            "server failed to hold its p99 inside the SLO at overload"
        )
    if acc.get("block_within_slo") is not False:
        errors.append(
            "acceptance.block_within_slo must be false — if blocking "
            "admission also holds the SLO, the record never actually "
            "overloaded the server and proves nothing"
        )
    if points:
        top = points[-1].get("hardened", {})
        if (top.get("shed", 0) or 0) + (top.get("deadline_missed", 0)
                                        or 0) <= 0:
            errors.append(
                "points[-1].hardened must shed or expire at overload — "
                "an SLO held without rejecting anything means the point "
                "was not an overload"
            )
        if (top.get("degrade_transitions", 0) or 0) < 1:
            errors.append(
                "points[-1].hardened.degrade_transitions must be >= 1 — "
                "the DegradePolicy never stepped down under overload"
            )
    fi = rec.get("fault_injection", {})
    _require(fi, FAULT_KEYS, "fault_injection", errors)
    if fi.get("neighbors_bit_exact") is not True:
        errors.append(
            "fault_injection.neighbors_bit_exact must be true — an "
            "injected dispatch failure changed an UNAFFECTED request's "
            "output"
        )
    if fi.get("served_after_failure") is not True:
        errors.append(
            "fault_injection.served_after_failure must be true — the "
            "server stopped serving after an injected failure"
        )
    if fi.get("failed_requests") != fi.get("injected_failures"):
        errors.append(
            "fault_injection.failed_requests must equal "
            "injected_failures — the blast radius leaked past the "
            f"failed dispatch ({fi.get('failed_requests')} failed for "
            f"{fi.get('injected_failures')} injected)"
        )
    return errors


def check_temporal(rec: dict) -> list:
    """All violations in one temporal_delta record (empty list = valid)."""
    errors: list = []
    _require(rec, TEMPORAL_RECORD_KEYS, "record", errors)
    clips = rec.get("clips", [])
    names = [c.get("clip") for c in clips]
    for want in ("static", "panning", "full_motion"):
        if want not in names:
            errors.append(f"clips must include the {want!r} motion regime")
    for i, c in enumerate(clips):
        where = f"clips[{i}] ({c.get('clip')})"
        _require(c, TEMPORAL_CLIP_KEYS, where, errors)
        _require(c.get("cache", {}), TEMPORAL_CACHE_KEYS,
                 f"{where}.cache", errors)
        # the subsystem's contract: splicing cached bands NEVER changes
        # the output, no matter the motion regime
        if c.get("bit_exact") is not True:
            errors.append(
                f"{where}: bit_exact must be true — the delta splice "
                "diverged from full re-upscale"
            )
        ratio = c.get("reuse_ratio")
        if ratio is not None and not 0.0 <= ratio <= 1.0:
            errors.append(f"{where}: reuse_ratio {ratio} outside [0, 1]")
        served = c.get("bands_served")
        skipped = c.get("bands_skipped")
        total = c.get("bands_total")
        if None not in (served, skipped, total) and served + skipped != total:
            errors.append(
                f"{where}: bands_served {served} + bands_skipped {skipped} "
                f"!= bands_total {total} — a band was double-counted or "
                "dropped from the splice accounting"
            )
    acc = rec.get("acceptance", {})
    _require(acc, TEMPORAL_ACCEPTANCE_KEYS, "acceptance", errors)
    # the headline claim: a static clip reuses enough to cut conv-stack
    # compute by at least the committed floor
    floor = acc.get("min_static_compute_reduction")
    if floor is not None and floor < MIN_STATIC_COMPUTE_REDUCTION:
        errors.append(
            f"acceptance.min_static_compute_reduction {floor} is below "
            f"the committed floor {MIN_STATIC_COMPUTE_REDUCTION}"
        )
    red = acc.get("static_compute_reduction")
    if red is None or red < MIN_STATIC_COMPUTE_REDUCTION:
        errors.append(
            f"acceptance.static_compute_reduction {red} must be >= "
            f"{MIN_STATIC_COMPUTE_REDUCTION} — the static clip did not "
            "reuse enough to justify the delta path"
        )
    if acc.get("static_ok") is not True:
        errors.append("acceptance.static_ok must be true")
    if acc.get("all_bit_exact") is not True:
        errors.append(
            "acceptance.all_bit_exact must be true — delta serving "
            "changed at least one frame's output"
        )
    return errors


def main(argv) -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = argv[1:] or [os.path.join(root, "BENCH_engine.json")]
    status = 0
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("bench") == "server_load":
            errors = check_server_load(rec)
            if errors:
                status = 1
                print(f"{path}: SCHEMA DRIFT")
                for e in errors:
                    print(f"  - {e}")
            else:
                acc = rec["acceptance"]
                top = rec["points"][-1]["hardened"]
                print(f"{path}: ok "
                      f"(@{acc['offered_rate_rps']} req/s hardened p99 "
                      f"{acc['hardened_p99_ms']} ms <= SLO "
                      f"{acc['slo_p99_ms']} ms, block p99 "
                      f"{acc['block_p99_ms']} ms, shed {top['shed']}, "
                      f"expired {top['deadline_missed']}, "
                      f"degrade_level {top['degrade_level']})")
            continue
        if rec.get("bench") == "temporal_delta":
            errors = check_temporal(rec)
            if errors:
                status = 1
                print(f"{path}: SCHEMA DRIFT")
                for e in errors:
                    print(f"  - {e}")
            else:
                acc = rec["acceptance"]
                pan = next(c for c in rec["clips"]
                           if c["clip"] == "panning")
                print(f"{path}: ok "
                      f"(static compute x{acc['static_compute_reduction']} "
                      f">= x{acc['min_static_compute_reduction']}, "
                      f"panning reuse {pan['reuse_ratio']}, "
                      f"bit_exact={acc['all_bit_exact']})")
            continue
        errors = check_record(rec)
        if errors:
            status = 1
            print(f"{path}: SCHEMA DRIFT")
            for e in errors:
                print(f"  - {e}")
        else:
            tuned_best = max(
                (t["speedup"] for t in rec["autotune"]["configs"]),
                default=0.0,
            )
            shard_best = max(
                (p["scaling"] for p in rec["sharding"]["points"]
                 if p["devices"] > 1),
                default=0.0,
            )
            print(f"{path}: ok "
                  f"(pipelined x{rec['pipeline']['speedup']} vs sync, "
                  f"tuned_depth={rec['pipeline']['tuned_depth']}, "
                  f"coalesced x{rec['server']['speedup']} vs solo, "
                  f"autotune best x{tuned_best}, "
                  f"sharded best x{shard_best} vs 1 device, "
                  f"bit_exact={rec['pipeline']['bit_exact']})")
    return status


if __name__ == "__main__":
    sys.exit(main(sys.argv))
