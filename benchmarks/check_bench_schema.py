"""Validate BENCH_engine.json against the schema the repo commits to.

CI's bench-smoke job regenerates a quick record and runs this against both
the fresh output and the committed BENCH_engine.json, so schema drift
(renamed/dropped keys, a missing pipelined-mode entry, a broken
bit-exactness guarantee) fails the build instead of silently rotting the
recorded numbers.

    PYTHONPATH=src python benchmarks/check_bench_schema.py [path ...]

No third-party schema library: the required key sets live next to the
producer (``engine_throughput.RECORD_KEYS`` etc.), so adding a field means
updating producer and checker in the same place.
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from engine_throughput import (  # noqa: E402
    ANALYSIS_KEYS,
    ANALYSIS_SEVERITY_KEYS,
    AUTOTUNE_CONFIG_KEYS,
    AUTOTUNE_KEYS,
    BATCH_KEYS,
    MODE_KEYS,
    PIPELINE_KEYS,
    RECORD_KEYS,
    ROOFLINE_KEYS,
    SERVER_KEYS,
    SERVER_MODE_KEYS,
    SHARDING_KEYS,
    SHARDING_POINT_KEYS,
)


def _require(obj: dict, keys, where: str, errors: list) -> None:
    missing = [k for k in keys if k not in obj]
    if missing:
        errors.append(f"{where}: missing keys {missing}")


def check_record(rec: dict) -> list:
    """All schema violations in one record (empty list = valid)."""
    errors: list = []
    _require(rec, RECORD_KEYS, "record", errors)
    for bs, r in rec.get("batch", {}).items():
        _require(r, BATCH_KEYS, f"batch[{bs}]", errors)
    pipe = rec.get("pipeline", {})
    _require(pipe, PIPELINE_KEYS, "pipeline", errors)
    for mode in ("sync", "pipelined"):
        _require(pipe.get(mode, {}), MODE_KEYS, f"pipeline.{mode}", errors)
    _require(rec.get("roofline", {}), ROOFLINE_KEYS, "roofline", errors)
    if pipe.get("bit_exact") is not True:
        errors.append(
            "pipeline.bit_exact must be true — pipelined serving changed "
            "the output"
        )
    if pipe.get("chunks", 0) < 4:
        errors.append(
            "pipeline comparison must run on a >= 4-chunk clip "
            f"(got chunks={pipe.get('chunks')})"
        )
    server = rec.get("server", {})
    _require(server, SERVER_KEYS, "server", errors)
    for mode in ("solo", "coalesced"):
        _require(server.get(mode, {}), SERVER_MODE_KEYS,
                 f"server.{mode}", errors)
    if server.get("bit_exact") is not True:
        errors.append(
            "server.bit_exact must be true — coalesced serving changed a "
            "request's output"
        )
    solo_d = server.get("solo", {}).get("dispatches_per_burst")
    coal_d = server.get("coalesced", {}).get("dispatches_per_burst")
    if solo_d is not None and coal_d is not None and coal_d > solo_d:
        errors.append(
            "server.coalesced must not dispatch MORE than solo serving "
            f"(coalesced {coal_d} vs solo {solo_d} per burst)"
        )
    tuned = rec.get("autotune", {})
    _require(tuned, AUTOTUNE_KEYS, "autotune", errors)
    configs = tuned.get("configs", [])
    if not configs:
        errors.append("autotune.configs must list at least one swept config")
    for i, t in enumerate(configs):
        _require(t, AUTOTUNE_CONFIG_KEYS, f"autotune.configs[{i}]", errors)
        # the tuner's hard guarantee: the tuned schedule NEVER regresses
        # below the default (the default candidate is always measured)
        sp = t.get("speedup")
        if sp is not None and sp < 1.0:
            errors.append(
                f"autotune.configs[{i}] (batch {t.get('batch')}): tuned "
                f"schedule regressed below default (speedup {sp} < 1.0)"
            )
        frac = t.get("achieved_fraction")
        if frac is not None and frac <= 0:
            errors.append(
                f"autotune.configs[{i}]: achieved_fraction {frac} must be "
                "positive (roofline prediction or measurement is broken)"
            )
    depth = rec.get("pipeline", {}).get("tuned_depth")
    if depth is not None and not 1 <= depth <= 4:
        errors.append(f"pipeline.tuned_depth {depth} outside the legal 1..4")
    analysis = rec.get("analysis", {})
    _require(analysis, ANALYSIS_KEYS, "analysis", errors)
    for checker in ("concurrency", "plan", "program"):
        counts = analysis.get(checker, {})
        _require(counts, ANALYSIS_SEVERITY_KEYS,
                 f"analysis.{checker}", errors)
        n_err = counts.get("error")
        if n_err is not None and n_err != 0:
            errors.append(
                f"analysis.{checker} recorded {n_err} error-level "
                "finding(s) — the static gate must be clean when a bench "
                "record is produced"
            )
    if analysis and analysis.get("clean") is not True:
        errors.append(
            "analysis.clean must be true — perf numbers from a tree that "
            "violates its own static invariants are not comparable"
        )
    sharding = rec.get("sharding", {})
    _require(sharding, SHARDING_KEYS, "sharding", errors)
    points = sharding.get("points", [])
    if not points:
        errors.append("sharding.points must hold at least one scaling point")
    for i, p in enumerate(points):
        _require(p, SHARDING_POINT_KEYS, f"sharding.points[{i}]", errors)
        if p.get("bit_exact") is not True:
            errors.append(
                f"sharding.points[{i}] "
                f"({p.get('replicas')}x{p.get('band_shards')}): bit_exact "
                "must be true — sharded execution changed the output"
            )
    if points and not any(p.get("devices", 0) > 1 for p in points):
        errors.append(
            "sharding.points must include at least one multi-device "
            "topology — a 1-device-only curve proves nothing about the "
            "sharded executor"
        )
    return errors


def main(argv) -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = argv[1:] or [os.path.join(root, "BENCH_engine.json")]
    status = 0
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        errors = check_record(rec)
        if errors:
            status = 1
            print(f"{path}: SCHEMA DRIFT")
            for e in errors:
                print(f"  - {e}")
        else:
            tuned_best = max(
                (t["speedup"] for t in rec["autotune"]["configs"]),
                default=0.0,
            )
            shard_best = max(
                (p["scaling"] for p in rec["sharding"]["points"]
                 if p["devices"] > 1),
                default=0.0,
            )
            print(f"{path}: ok "
                  f"(pipelined x{rec['pipeline']['speedup']} vs sync, "
                  f"tuned_depth={rec['pipeline']['tuned_depth']}, "
                  f"coalesced x{rec['server']['speedup']} vs solo, "
                  f"autotune best x{tuned_best}, "
                  f"sharded best x{shard_best} vs 1 device, "
                  f"bit_exact={rec['pipeline']['bit_exact']})")
    return status


if __name__ == "__main__":
    sys.exit(main(sys.argv))
