"""Mamba2 SSD: chunked dual form vs naive recurrence, decode continuity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.layers.ssd import (
    mamba_block,
    mamba_schema,
    ssd_chunked,
    ssd_decode_step,
    ssd_reference,
)
from repro.layers.params import init_params


def make_inputs(key, B, S, H, P, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, H, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, H, N)) * 0.3
    return x, dt, A, Bm, Cm


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([16, 32, 64]),
    chunk=st.sampled_from([4, 8, 16, 64]),
    h=st.integers(1, 4),
    p=st.sampled_from([4, 8]),
    n=st.sampled_from([4, 16]),
)
def test_chunked_equals_recurrence(s, chunk, h, p, n):
    x, dt, A, Bm, Cm = make_inputs(jax.random.PRNGKey(s + h), 2, s, h, p, n)
    y_ref, h_ref = ssd_reference(x, dt, A, Bm, Cm)
    y, hT = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref), atol=1e-4,
                               rtol=1e-3)


@pytest.mark.slow
def test_prefill_then_decode_continues_exactly():
    x, dt, A, Bm, Cm = make_inputs(jax.random.PRNGKey(0), 2, 48, 3, 8, 16)
    y_ref, _ = ssd_reference(x, dt, A, Bm, Cm)
    _, h = ssd_chunked(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32], chunk=16)
    outs = []
    for t in range(32, 48):
        h, y = ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        outs.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(y_ref[:, 32:]), atol=1e-4,
        rtol=1e-3)


def test_state_carry_is_the_overlap_buffer():
    """Processing a sequence in two chunked calls with carried state equals
    one call — the tilted-fusion hand-off property on the sequence axis."""
    x, dt, A, Bm, Cm = make_inputs(jax.random.PRNGKey(1), 1, 64, 2, 4, 8)
    y_all, h_all = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    y1, h1 = ssd_chunked(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32], chunk=16)
    y2, h2 = ssd_chunked(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
                         chunk=16, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_all), atol=1e-4,
                               rtol=1e-3)


@pytest.mark.slow
def test_mamba_block_decode_matches_prefill_tail():
    cfg = get_config("mamba2-130m").reduced()
    p = init_params(mamba_schema(cfg), jax.random.PRNGKey(2))
    B, S = 2, 33
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model)) * 0.5
    y_full, _ = mamba_block(p, cfg, x, mode="train")

    from repro.layers.ssd import init_ssm_cache_spec
    (cs, _), (ss, _) = init_ssm_cache_spec(cfg, B)
    cache = (jnp.zeros(cs), jnp.zeros(ss))
    y_pre, cache = mamba_block(p, cfg, x[:, :32], cache=cache, mode="prefill")
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :32]),
                               atol=2e-4, rtol=1e-3)
    y_dec, _ = mamba_block(p, cfg, x[:, 32:33], cache=cache, mode="decode")
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, 32]),
                               atol=2e-4, rtol=1e-3)
