"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the deliverable: shape/dtype sweeps + hypothesis cases, allclose
against ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.fusion import ConvLayer
from repro.kernels import ops, ref
from repro.models.abpn import ABPNConfig, init_abpn


def make_layers(key, channels, dtype=jnp.float32):
    layers = []
    for i in range(len(channels) - 1):
        k1, k2, key = jax.random.split(key, 3)
        ci, co = channels[i], channels[i + 1]
        layers.append(ConvLayer(
            w=(jax.random.normal(k1, (3, 3, ci, co)) * 0.2).astype(dtype),
            b=(jax.random.normal(k2, (co,)) * 0.1).astype(dtype),
            relu=(i < len(channels) - 2),
        ))
    return layers


# ----------------------------------------------------------------------
# conv3x3 (vectorwise single layer)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("shape,co,tile", [
    ((60, 64, 28), 28, 8),
    ((60, 37, 28), 16, 8),   # width not a tile multiple
    ((15, 8, 3), 5, 4),
    ((8, 9, 1), 1, 2),
])
def test_conv3x3_shapes(shape, co, tile):
    x = jax.random.uniform(jax.random.PRNGKey(1), shape)
    w = jax.random.normal(jax.random.PRNGKey(2), (3, 3, shape[2], co)) * 0.2
    b = jax.random.normal(jax.random.PRNGKey(3), (co,)) * 0.1
    out = ops.conv3x3(x, w, b, tile_cols=tile)
    expect = ref.conv3x3_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv3x3_dtypes(dtype):
    x = jax.random.uniform(jax.random.PRNGKey(4), (20, 24, 8)).astype(dtype)
    w = (jax.random.normal(jax.random.PRNGKey(5), (3, 3, 8, 8)) * 0.2).astype(dtype)
    b = jnp.zeros((8,), dtype)
    out = ops.conv3x3(x, w, b)
    expect = ref.conv3x3_ref(x, w, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol, rtol=tol)


# ----------------------------------------------------------------------
# tilted fused stack (the paper's kernel)
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_tilted_abpn_exact():
    layers = make_layers(jax.random.PRNGKey(0), [3, 28, 28, 28, 28, 28, 28, 27])
    img = jax.random.uniform(jax.random.PRNGKey(1), (120, 64, 3))
    out = ops.tilted_fused_stack(img, layers, band_rows=60, tile_cols=8)
    expect = ref.tilted_fused_stack_ref(img, layers, band_rows=60)
    # 7 layers of reordered f32 accumulation: tolerance scales with depth
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=5e-4, rtol=0)


@pytest.mark.slow
def test_tilted_with_anchor():
    layers = make_layers(jax.random.PRNGKey(2), [3, 28, 28, 28, 28, 28, 28, 27])
    img = jax.random.uniform(jax.random.PRNGKey(3), (60, 40, 3))
    out = ops.tilted_fused_stack(img, layers, band_rows=60, tile_cols=8,
                                 add_anchor=True)
    expect = ref.tilted_fused_stack_ref(img, layers, band_rows=60, add_anchor=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=5e-4, rtol=0)


@pytest.mark.slow
def test_tilted_bf16():
    layers = make_layers(jax.random.PRNGKey(4), [3, 8, 8, 6], dtype=jnp.bfloat16)
    img = jax.random.uniform(jax.random.PRNGKey(5), (30, 24, 3)).astype(jnp.bfloat16)
    out = ops.tilted_fused_stack(img, layers, band_rows=30, tile_cols=4)
    expect = ref.tilted_fused_stack_ref(img, layers, band_rows=30)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=5e-2, rtol=5e-2)


def test_tilted_chp_128_lane_padding():
    """Full MXU lane padding (chp=128) must not change results."""
    layers = make_layers(jax.random.PRNGKey(6), [3, 28, 28, 27])
    img = jax.random.uniform(jax.random.PRNGKey(7), (30, 32, 3))
    out = ops.tilted_fused_stack(img, layers, band_rows=30, tile_cols=8, chp=128)
    expect = ref.tilted_fused_stack_ref(img, layers, band_rows=30)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=1e-5)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(
    width=st.integers(6, 40),
    tile=st.integers(2, 8),
    depth=st.integers(1, 4),
    ch=st.integers(1, 8),
    bands=st.integers(1, 2),
    rows=st.integers(4, 10),
)
def test_tilted_fused_property(width, tile, depth, ch, bands, rows):
    layers = make_layers(jax.random.PRNGKey(depth * 7 + ch), [3] + [ch] * depth)
    img = jax.random.uniform(jax.random.PRNGKey(11), (bands * rows, width, 3))
    out = ops.tilted_fused_stack(img, layers, band_rows=rows, tile_cols=tile)
    expect = ref.tilted_fused_stack_ref(img, layers, band_rows=rows)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=3e-5, rtol=1e-4)


@pytest.mark.slow
def test_kernel_matches_pure_jax_fusion():
    """Triangle check: Pallas kernel == lax.scan executor == reference."""
    from repro.core.fusion import run_banded

    layers = make_layers(jax.random.PRNGKey(8), [3, 12, 12, 9])
    img = jax.random.uniform(jax.random.PRNGKey(9), (40, 28, 3))
    k = ops.tilted_fused_stack(img, layers, band_rows=20, tile_cols=4)
    s = run_banded(img, layers, band_rows=20, tile_cols=4, vertical_policy="zero")
    np.testing.assert_allclose(np.asarray(k), np.asarray(s), atol=2e-5, rtol=1e-5)
