"""Paper Tables I & II and the 92% DRAM-bandwidth claim."""

import pytest

from repro.core.analysis import (
    HWConfig,
    PAPER_CLAIMS,
    PAPER_TABLE2,
    buffer_sizes,
    classical_buffer_sizes,
    dram_reduction,
    dram_traffic,
    pe_throughput_model,
    weight_bytes,
)


def test_table2_tilted_buffers_exact():
    b = buffer_sizes()
    paper = PAPER_TABLE2["tilted"]
    # eqs (1)-(3) reproduce the paper bit-exactly (decimal KB)
    assert b["ping_pong_kb"] == pytest.approx(paper["ping_pong"], abs=1e-9)
    assert b["overlap_kb"] == pytest.approx(paper["overlap"], abs=1e-9)
    assert b["residual_kb"] == pytest.approx(paper["residual"], abs=1e-9)
    # weight buffer differs only by bias-width bookkeeping (<1.5%)
    assert b["weight_kb"] == pytest.approx(paper["weight"], rel=0.015)
    assert b["total_kb"] == pytest.approx(paper["total"], rel=0.006)


def test_table2_classical_buffers():
    c = classical_buffer_sizes()
    paper = PAPER_TABLE2["classical"]
    assert c["ping_pong_kb"] == pytest.approx(paper["ping_pong"], abs=1e-9)
    assert c["residual_kb"] == pytest.approx(paper["residual"], abs=1e-9)
    assert c["total_kb"] == pytest.approx(paper["total"], rel=0.006)


def test_buffer_saving_is_about_60_percent():
    t = buffer_sizes()["total_kb"]
    c = classical_buffer_sizes()["total_kb"]
    assert 0.55 < 1 - t / c < 0.65  # paper: "nearly 60%"


def test_dram_bandwidth_reduction_92_percent():
    lw = dram_traffic(mode="layerwise")["gb_s"]
    fu = dram_traffic(mode="fused")["gb_s"]
    assert lw == pytest.approx(PAPER_CLAIMS["dram_layerwise_gb_s"], rel=0.01)
    assert fu == pytest.approx(PAPER_CLAIMS["dram_fused_gb_s"], rel=0.03)
    assert dram_reduction() == pytest.approx(PAPER_CLAIMS["dram_reduction"], abs=0.01)


def test_pe_model_reproduces_table1():
    pe = pe_throughput_model()
    assert pe["num_macs"] == PAPER_CLAIMS["num_macs"]  # 1260
    assert pe["meets_60fps"]  # FHD @ 60fps at 600 MHz
    assert pe["mpix_s_at_target"] == pytest.approx(
        PAPER_CLAIMS["throughput_mpix_s"], rel=0.001)  # 124.4
    assert pe["utilization"] == pytest.approx(PAPER_CLAIMS["utilization"], abs=0.02)


def test_weight_bytes_matches_param_count():
    import jax
    from repro.models.abpn import ABPNConfig, init_abpn, param_count
    layers = init_abpn(jax.random.PRNGKey(0), ABPNConfig())
    assert weight_bytes() == param_count(layers)  # 8-bit: bytes == params


def test_tile_width_sweep_monotone():
    """Smaller C shrinks ping-pong cost but the overlap buffer is fixed."""
    totals = []
    for c in (2, 4, 8, 16, 32, 60):
        b = buffer_sizes(HWConfig(tile_cols=c))
        totals.append(b["total_kb"])
        assert b["overlap_kb"] == buffer_sizes()["overlap_kb"]
    assert totals == sorted(totals)
