"""ABPN model: anchor, pixel shuffle, execution-path equivalence, quant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import dequantize_layers, fake_quant, quantize, quantize_layers
from repro.models.abpn import (
    ABPNConfig,
    apply_abpn,
    depth_to_space,
    init_abpn,
    make_anchor,
    param_count,
)


def test_param_count_matches_paper_weight_buffer():
    layers = init_abpn(jax.random.PRNGKey(0), ABPNConfig())
    assert param_count(layers) == 43035  # 42840 weights + 195 biases (8-bit)


def test_depth_to_space_roundtrip_convention():
    x = jnp.arange(2 * 3 * 9, dtype=jnp.float32).reshape(2, 3, 9)
    y = depth_to_space(x, 3)
    assert y.shape == (6, 9, 1)
    # block-major: out[y*3+dy, x*3+dx, 0] == in[y, x, dy*3+dx]
    assert y[0, 0, 0] == x[0, 0, 0]
    assert y[0, 1, 0] == x[0, 0, 1]
    assert y[1, 0, 0] == x[0, 0, 3]


def test_anchor_is_nearest_upsample():
    lr = jax.random.uniform(jax.random.PRNGKey(1), (5, 7, 3))
    up = depth_to_space(make_anchor(lr, 3), 3)
    nn = jnp.repeat(jnp.repeat(lr, 3, axis=0), 3, axis=1)
    np.testing.assert_array_equal(np.asarray(up), np.asarray(nn))


@pytest.mark.parametrize("method,policy", [
    ("tilted", "halo"),
    ("kernel", "zero"),
    pytest.param("kernel", "halo", marks=pytest.mark.slow),
])
def test_execution_paths_agree(method, policy):
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(2), cfg)
    lr = jax.random.uniform(jax.random.PRNGKey(3), (120, 64, 3))
    hr_ref = apply_abpn(layers, lr, cfg, method="reference")
    hr = apply_abpn(layers, lr, cfg, method=method, band_rows=60,
                    vertical_policy=policy)
    assert hr.shape == (360, 192, 3)
    if policy == "halo":
        np.testing.assert_allclose(np.asarray(hr_ref), np.asarray(hr), atol=1e-5)
    else:
        # zero policy: interior rows must agree exactly
        d = np.abs(np.asarray(hr_ref) - np.asarray(hr)).max(axis=(1, 2))
        assert d[30:120].max() < 1e-5


def test_quant_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(4), (64, 64))
    q, s = quantize(x)
    err = np.abs(np.asarray(x) - np.asarray(q, np.float32) * np.asarray(s))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_fake_quant_straight_through_grad():
    x = jnp.linspace(-1, 1, 32)
    g = jax.grad(lambda t: jnp.sum(fake_quant(t)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(32), atol=1e-6)


def test_quantized_abpn_stays_close():
    """8-bit deployment (the accelerator's numerics) ~ float within tol."""
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(5), cfg)
    qlayers = dequantize_layers(quantize_layers(layers))
    lr = jax.random.uniform(jax.random.PRNGKey(6), (60, 64, 3))
    hr_f = apply_abpn(layers, lr, cfg, method="reference")
    hr_q = apply_abpn(qlayers, lr, cfg, method="reference")
    # PSNR between float and int8-weight outputs should be high
    mse = float(jnp.mean((hr_f - hr_q) ** 2))
    psnr = 10 * np.log10(1.0 / max(mse, 1e-12))
    assert psnr > 40.0
