"""End-to-end behaviour tests for the paper's system.

These are the integration contracts: train the paper's SR model and watch
PSNR improve; serve through the Pallas kernel path; run the LM trainer and
the server as a user would.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fusion import ConvLayer
from repro.data.synthetic import sr_pair_batch
from repro.models.abpn import ABPNConfig, apply_abpn, init_abpn


def psnr(a, b):
    mse = float(jnp.mean((a - b) ** 2))
    return 10 * np.log10(1.0 / max(mse, 1e-12))


@pytest.mark.slow
def test_train_abpn_improves_psnr():
    """A short training run on synthetic SR pairs beats the anchor
    (nearest-neighbour) baseline — the network learns a real residual."""
    cfg = ABPNConfig(feature_channels=12, num_layers=4)
    layers = init_abpn(jax.random.PRNGKey(0), cfg)
    lr_img, hr_img = sr_pair_batch(0, 4, lr_shape=(24, 24), scale=3)

    def loss_fn(layers, lr_b, hr_b):
        out = jnp.stack([apply_abpn(layers, im, cfg) for im in lr_b])
        return jnp.mean(jnp.abs(out - hr_b))

    @jax.jit
    def step(layers, lr_b, hr_b):
        l, g = jax.value_and_grad(loss_fn)(layers, lr_b, hr_b)
        layers = jax.tree_util.tree_map(lambda p, gg: p - 0.02 * gg, layers, g)
        return layers, l

    psnr_before = psnr(jnp.stack([apply_abpn(layers, im, cfg) for im in lr_img]),
                       hr_img)
    for i in range(60):
        lr_b, hr_b = sr_pair_batch(i, 4, lr_shape=(24, 24), scale=3)
        layers, l = step(layers, lr_b, hr_b)
    out = jnp.stack([apply_abpn(layers, im, cfg) for im in lr_img])
    psnr_after = psnr(out, hr_img)
    assert psnr_after > psnr_before + 0.5, (psnr_before, psnr_after)


def test_serve_kernel_path_matches_reference():
    """Inference through the Pallas tilted-fusion kernel == reference model
    (the accelerator produces the same image as the float network, modulo
    the vertical band policy)."""
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(1), cfg)
    lr_img, _ = sr_pair_batch(1, 1, lr_shape=(60, 64), scale=3)
    hr_ref = apply_abpn(layers, lr_img[0], cfg, method="reference")
    hr_kernel = apply_abpn(layers, lr_img[0], cfg, method="kernel",
                           band_rows=60, tile_cols=8)
    # single band -> no vertical boundary -> must match everywhere
    np.testing.assert_allclose(np.asarray(hr_ref), np.asarray(hr_kernel),
                               atol=1e-4)


def test_psnr_penalty_below_paper_bound():
    """Paper §II: the tilted scheme's top/bottom information loss costs
    <0.2 dB.  Measured against the exact (halo) execution on synthetic
    textures."""
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(2), cfg)
    lr_img, _ = sr_pair_batch(2, 2, lr_shape=(120, 64), scale=3)
    deltas = []
    for im in lr_img:
        exact = apply_abpn(layers, im, cfg, method="tilted", band_rows=60,
                           vertical_policy="halo")
        banded = apply_abpn(layers, im, cfg, method="tilted", band_rows=60,
                            vertical_policy="zero")
        # PSNR of banded output w.r.t. exact output
        deltas.append(psnr(banded, exact))
    # paper claims the penalty is marginal; the banded image stays very
    # close to the exact one
    assert min(deltas) > 20.0, deltas


@pytest.mark.slow
def test_lm_train_cli_runs():
    from repro.launch.train import main

    rc = main(["--arch", "qwen2-0.5b", "--steps", "8", "--batch", "2",
               "--seq", "32", "--ckpt-dir", "/tmp/repro_test_ckpt",
               "--checkpoint-every", "0", "--log-every", "4"])
    assert rc == 0


def test_lm_serve_cli_runs():
    from repro.launch.serve import main

    rc = main(["--arch", "mamba2-130m", "--batch", "2", "--prompt-len", "16",
               "--gen", "4"])
    assert rc == 0
