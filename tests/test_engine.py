"""Batched engine: plan validation, batched == per-frame, halo exactness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core.fusion import conv_stack_reference
from repro.models.abpn import ABPNConfig, apply_abpn, init_abpn

CFG = ABPNConfig()
LAYERS = init_abpn(jax.random.PRNGKey(2), CFG)
FRAMES = jax.random.uniform(jax.random.PRNGKey(3), (3, 120, 64, 3))


# ----------------------------------------------------------------------
# SRPlan validation
# ----------------------------------------------------------------------
def test_plan_validates_geometry():
    with pytest.raises(ValueError):  # height not a band multiple
        engine.SRPlan(height=100, width=64, band_rows=60)
    with pytest.raises(ValueError):  # tile_cols below the overlap hand-off
        engine.SRPlan(height=120, width=64, tile_cols=1)
    with pytest.raises(ValueError):
        engine.SRPlan(height=120, width=64, band_rows=-60)
    with pytest.raises(ValueError):
        engine.SRPlan(height=0, width=64)


def test_plan_validates_enums():
    with pytest.raises(ValueError):
        engine.SRPlan(height=120, width=64, backend="magic")
    with pytest.raises(ValueError):
        engine.SRPlan(height=120, width=64, vertical_policy="mirror")
    with pytest.raises(ValueError):
        engine.SRPlan(height=120, width=64, precision="fp8")


def test_plan_kernel_accepts_every_policy_and_precision():
    """The Pallas backend covers the full plan space (no zero-only carve-out)."""
    for policy in engine.VERTICAL_POLICIES:
        for precision in engine.PRECISIONS:
            plan = engine.SRPlan(height=120, width=64, backend="kernel",
                                 vertical_policy=policy, precision=precision)
            assert (plan.vertical_policy, plan.precision) == (policy, precision)


def test_plan_checks_layer_channels():
    with pytest.raises(ValueError):
        engine.make_plan(LAYERS, (120, 64, 4))


def test_make_plan_rejects_empty_layer_stack():
    with pytest.raises(ValueError, match="layer stack is empty"):
        engine.make_plan([], (120, 64, 3))


def test_plan_derived_geometry_and_invariants():
    plan = engine.make_plan(LAYERS, (120, 64, 3), band_rows=60, tile_cols=8)
    assert plan.num_bands == 2
    assert plan.num_layers == 7
    assert plan.schedule.num_tiles == (64 + 6 + 7) // 8
    assert plan.hr_shape == (360, 192, 3)
    plan.check_invariants()  # full tile/layer hand-off sweep


# ----------------------------------------------------------------------
# Batched engine == per-frame legacy shim, all backends
# ----------------------------------------------------------------------
@pytest.mark.parametrize("backend,policy", [
    ("reference", "zero"),
    ("tilted", "zero"),
    ("tilted", "halo"),
    ("tilted", "replicate"),
    pytest.param("kernel", "zero", marks=pytest.mark.slow),
    pytest.param("kernel", "halo", marks=pytest.mark.slow),
    pytest.param("kernel", "replicate", marks=pytest.mark.slow),
])
def test_batched_equals_per_frame(backend, policy):
    plan = engine.make_plan(LAYERS, FRAMES.shape[1:], band_rows=60,
                            vertical_policy=policy, backend=backend)
    batched = engine.run(plan, LAYERS, FRAMES)
    assert batched.shape == (3, 360, 192, 3)
    for i in range(FRAMES.shape[0]):
        single = apply_abpn(LAYERS, FRAMES[i], CFG, method=backend,
                            band_rows=60, vertical_policy=policy)
        np.testing.assert_array_equal(np.asarray(batched[i]),
                                      np.asarray(single))


@pytest.mark.slow
def test_batch_of_8_single_call_per_backend():
    """Acceptance: 8 frames through one jitted engine call per backend."""
    frames = jax.random.uniform(jax.random.PRNGKey(9), (8, 60, 32, 3))
    outs = {}
    for backend in engine.BACKENDS:
        plan = engine.make_plan(LAYERS, frames.shape[1:], band_rows=30,
                                backend=backend)
        fn = engine.build_executor(plan, LAYERS)
        outs[backend] = np.asarray(fn(frames))  # one call, whole batch
        assert outs[backend].shape == (8, 180, 96, 3)
    # tilted and kernel share the zero band policy -> near-identical
    np.testing.assert_allclose(outs["tilted"], outs["kernel"],
                               atol=5e-4, rtol=0)


# ----------------------------------------------------------------------
# Halo exactness via the plan API
# ----------------------------------------------------------------------
def test_halo_features_bit_exact_with_reference():
    plan = engine.make_plan(LAYERS, FRAMES.shape[1:], band_rows=60,
                            vertical_policy="halo", backend="tilted")
    feats = engine.sr_features(plan, LAYERS, FRAMES)
    for i in range(FRAMES.shape[0]):
        ref = conv_stack_reference(FRAMES[i], LAYERS)
        np.testing.assert_array_equal(np.asarray(feats[i]), np.asarray(ref))


def test_halo_single_band_image():
    """Halo margins past both image edges (1-band frame) stay exact."""
    frames = jax.random.uniform(jax.random.PRNGKey(4), (2, 60, 40, 3))
    plan = engine.make_plan(LAYERS, frames.shape[1:], band_rows=60,
                            vertical_policy="halo", backend="tilted")
    feats = engine.sr_features(plan, LAYERS, frames)
    for i in range(2):
        ref = conv_stack_reference(frames[i], LAYERS)
        np.testing.assert_array_equal(np.asarray(feats[i]), np.asarray(ref))


# ----------------------------------------------------------------------
# Numerics policies
# ----------------------------------------------------------------------
def test_precision_int8_stays_close():
    plan32 = engine.make_plan(LAYERS, FRAMES.shape[1:], backend="tilted")
    plan8 = engine.make_plan(LAYERS, FRAMES.shape[1:], backend="tilted",
                             precision="int8")
    hr32 = engine.run(plan32, LAYERS, FRAMES)
    hr8 = engine.run(plan8, LAYERS, FRAMES)
    mse = float(jnp.mean((hr32 - hr8) ** 2))
    psnr = 10 * np.log10(1.0 / max(mse, 1e-12))
    assert psnr > 40.0


def test_precision_bf16_runs_and_tracks_fp32():
    plan = engine.make_plan(LAYERS, FRAMES.shape[1:], backend="tilted",
                            precision="bf16")
    hr = engine.run(plan, LAYERS, FRAMES)
    assert hr.dtype == FRAMES.dtype  # cast back at the boundary
    ref = engine.run(
        engine.make_plan(LAYERS, FRAMES.shape[1:], backend="tilted"),
        LAYERS, FRAMES)
    assert float(jnp.max(jnp.abs(hr - ref))) < 0.1


# ----------------------------------------------------------------------
# VideoStream driver
# ----------------------------------------------------------------------
def test_video_stream_serves_and_reports():
    plan = engine.make_plan(LAYERS, (60, 32, 3), band_rows=30,
                            backend="tilted")
    stream = engine.VideoStream(plan, LAYERS, batch_size=2)
    compile_s = stream.warmup()
    assert compile_s > 0
    frames = jax.random.uniform(jax.random.PRNGKey(5), (6, 60, 32, 3))
    hr = stream.run(frames)
    assert hr.shape == (6, 180, 96, 3)
    s = stream.stats()
    assert s["frames"] == 6 and s["batches"] == 3
    assert s["fps"] > 0 and s["p95_ms"] >= s["p50_ms"] > 0
    # streamed result == one-shot batch through the same plan
    np.testing.assert_array_equal(
        np.asarray(hr), np.asarray(engine.run(plan, LAYERS, frames)))


def test_video_stream_rejects_wrong_batch():
    plan = engine.make_plan(LAYERS, (60, 32, 3), band_rows=30)
    stream = engine.VideoStream(plan, LAYERS, batch_size=2)
    with pytest.raises(ValueError):
        stream.process(jnp.zeros((3, 60, 32, 3)))
    with pytest.raises(ValueError):  # real_frames outside the batch
        stream.process(jnp.zeros((2, 60, 32, 3)), real_frames=3)


def test_video_stream_ragged_tail():
    """A clip that is not a batch multiple serves without recompilation:
    the tail batch is padded, the output trimmed, stats count real frames."""
    plan = engine.make_plan(LAYERS, (60, 32, 3), band_rows=30,
                            backend="tilted")
    stream = engine.VideoStream(plan, LAYERS, batch_size=4)
    stream.warmup()
    frames = jax.random.uniform(jax.random.PRNGKey(7), (7, 60, 32, 3))
    hr = stream.run(frames)
    assert hr.shape == (7, 180, 96, 3)
    s = stream.stats()
    assert s["frames"] == 7 and s["batches"] == 2  # 4 + 3(padded to 4)
    # output equals frame-by-frame execution through the same plan
    np.testing.assert_array_equal(
        np.asarray(hr), np.asarray(engine.run(plan, LAYERS, frames)))


def test_video_stream_empty_clip_and_degenerate_stats():
    plan = engine.make_plan(LAYERS, (60, 32, 3), band_rows=30)
    stream = engine.VideoStream(plan, LAYERS, batch_size=2)
    hr = stream.run(jnp.zeros((0, 60, 32, 3)))
    assert hr.shape == (0, 180, 96, 3)
    s = stream.stats()
    assert s["frames"] == 0 and s["fps"] == 0.0
    # zero recorded latency (clock too coarse) must report 0.0, not inf
    stream._lat_ms.append(0.0)
    stream._frames += 2
    s = stream.stats()
    assert s["fps"] == 0.0 and np.isfinite(s["fps"])
