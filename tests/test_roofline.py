"""HLO cost parser: validated against XLA cost_analysis ground truth."""

import pytest

from repro.roofline.hlo_parse import parse_hlo


def test_parser_on_synthetic_hlo():
    text = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%dot.1), replica_groups={}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ip, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%z, %a)
  %w2 = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[8,16] get-tuple-element(%w2), index=1
}
"""
    cost = parse_hlo(text)
    # dot: 2*8*16*16 = 4096 flops x 5 trips
    assert cost.flops == 4096 * 5
    # all-reduce: 8*16*4 bytes x 5 trips
    assert cost.collective_bytes == 512 * 5
    assert cost.while_trip_counts == [5]
    assert cost.collective_by_type == {"all-reduce": 512 * 5}


def test_parser_vs_cost_analysis_unrolled(subproc):
    """On an UNROLLED program (no while), parsed flops ~== XLA's."""
    out = subproc("""
        import jax, jax.numpy as jnp
        from repro.roofline.hlo_parse import parse_hlo
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        f = lambda x, y: (x @ y).sum()
        c = jax.jit(f).lower(a, b).compile()
        got = parse_hlo(c.as_text()).flops
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)):  # older jax: one dict per device
            ca = ca[0]
        want = ca["flops"]
        assert abs(got - want) / want < 0.05, (got, want)
        print("OK")
    """, devices=1)
    assert "OK" in out


def test_parser_scan_trip_multiplier(subproc):
    """With lax.scan, XLA undercounts by the trip count; the parser must
    recover the x L factor."""
    out = subproc("""
        import jax, jax.numpy as jnp
        from repro.roofline.hlo_parse import parse_hlo
        L = 7
        def f(ws, x):
            def body(h, w):
                return jnp.tanh(h @ w), ()
            h, _ = jax.lax.scan(body, x, ws)
            return h.sum()
        ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        c = jax.jit(f).lower(ws, x).compile()
        cost = parse_hlo(c.as_text())
        assert L in cost.while_trip_counts, cost.while_trip_counts
        per_layer = 2 * 8 * 64 * 64
        assert cost.flops >= per_layer * L * 0.9, (cost.flops, per_layer * L)
        print("OK")
    """, devices=1)
    assert "OK" in out


def test_parser_finds_collectives_in_sharded_program(subproc):
    out = subproc("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline.hlo_parse import parse_hlo
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
        f = lambda x, w: (x @ w).sum()
        c = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P("data", None)),
                NamedSharding(mesh, P("model", None))),
            out_shardings=NamedSharding(mesh, P())).lower(x, w).compile()
        cost = parse_hlo(c.as_text())
        assert cost.collective_bytes > 0
        assert cost.collective_count > 0
        print("OK")
    """, devices=8)
    assert "OK" in out
