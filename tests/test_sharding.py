"""Sharded serving: MeshSpec/ShardedPlan validation, shard-aware plan
verification, replica routing policy, and multi-device bit-exactness.

Pure-logic tests run anywhere.  Multi-device parity runs two ways: directly
in-process when the interpreter already sees >= 8 devices (the CI
tier1-multidevice job forces host devices via XLA_FLAGS), and in
subprocesses (``slow`` tier) so the full suite covers sharding even from a
single-device main process.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.plan_check import required_halo_margin, verify_plan
from repro.engine.plan import SRPlan, shardable_band_rows
from repro.engine.session import SRSession
from repro.engine.sharding import (
    MeshSpec,
    ReplicaRouter,
    ShardedPlan,
    build_sharded_executor,
    halo_exchange_bytes_per_frame,
)
from repro.engine.sharding.mesh_plan import check_shardable, ensure_shardable
from repro.engine.sharding.router import _Replica
from repro.models.abpn import ABPNConfig, init_abpn

CFG = ABPNConfig(num_layers=3, feature_channels=8)
LAYERS = init_abpn(jax.random.PRNGKey(0), CFG)


def small_plan(**kw):
    kw.setdefault("height", 24)
    kw.setdefault("width", 16)
    kw.setdefault("num_layers", 3)
    kw.setdefault("band_rows", 6)
    return SRPlan(**kw)


# ----------------------------------------------------------------------
# MeshSpec
# ----------------------------------------------------------------------
def test_mesh_spec_coerce():
    assert MeshSpec.coerce(None) == MeshSpec(1, 1)
    assert MeshSpec.coerce((2, 4)) == MeshSpec(replicas=2, band_shards=4)
    spec = MeshSpec(3, 2)
    assert MeshSpec.coerce(spec) is spec


def test_mesh_spec_properties():
    spec = MeshSpec(replicas=2, band_shards=4)
    assert spec.devices_needed == 8
    assert spec.descriptor == "2x4"
    assert not spec.is_trivial
    assert MeshSpec().is_trivial


def test_mesh_spec_rejects_bad_values():
    with pytest.raises(ValueError):
        MeshSpec(0, 1)
    with pytest.raises(ValueError):
        MeshSpec(1, -2)
    with pytest.raises(ValueError):
        MeshSpec.coerce("2x4")  # strings are not topologies
    with pytest.raises(ValueError):
        MeshSpec.coerce((1, 2, 3))


# ----------------------------------------------------------------------
# Shardability: check / ensure / ShardedPlan
# ----------------------------------------------------------------------
def test_check_shardable():
    assert check_shardable(small_plan(), 1) is None
    assert check_shardable(small_plan(), 2) is None  # 4 bands / 2 shards
    err = check_shardable(small_plan(backend="reference"), 2)
    assert err is not None and "reference" in err
    err = check_shardable(small_plan(band_rows=24), 2)  # 1 band, 2 shards
    assert err is not None and "split" in err


def test_ensure_shardable_rebands():
    plan = small_plan(height=48, band_rows=48)  # 1 band: not 2-shardable
    fixed = ensure_shardable(plan, MeshSpec(1, 2))
    assert fixed.band_rows == 24 and fixed.num_bands == 2
    assert fixed.height == plan.height
    ok = small_plan()
    assert ensure_shardable(ok, MeshSpec(1, 2)) is ok  # untouched when legal
    with pytest.raises(ValueError):
        ensure_shardable(small_plan(backend="reference"), MeshSpec(1, 2))
    with pytest.raises(ValueError):
        # prime height: only the full-height single band is legal
        ensure_shardable(SRPlan(height=97, width=16, num_layers=3,
                                band_rows=97), MeshSpec(1, 2))


def test_shardable_band_rows():
    assert shardable_band_rows(360, 3) == 60  # paper frame: 6 bands / 3
    assert shardable_band_rows(48, 2) == 24
    assert shardable_band_rows(97, 2) is None
    with pytest.raises(ValueError):
        shardable_band_rows(48, 0)


def test_sharded_plan_local_geometry():
    splan = ShardedPlan(plan=small_plan(), spec=MeshSpec(1, 2))
    assert splan.local_plan.height == 12
    assert splan.local_plan.band_rows == 6
    assert splan.bands_per_shard == 2
    trivial = ShardedPlan(plan=small_plan())
    assert trivial.local_plan is trivial.plan
    with pytest.raises(ValueError):
        ShardedPlan(plan=small_plan(band_rows=24), spec=MeshSpec(1, 2))
    with pytest.raises(ValueError):
        ShardedPlan(plan=small_plan(backend="reference"), spec=MeshSpec(1, 2))


# ----------------------------------------------------------------------
# Shard-aware static verification (plan_check satellite)
# ----------------------------------------------------------------------
def _shard_errors(findings):
    return [f for f in findings
            if f.rule.startswith("shard_") and f.severity == "error"]


def test_verify_plan_shard_halo_insufficiency_is_error():
    plan = small_plan(vertical_policy="halo")
    need = required_halo_margin(plan.num_layers)
    bad = verify_plan(plan, band_shards=2, shard_halo_margin=need - 1)
    errs = _shard_errors(bad)
    assert errs and errs[0].rule == "shard_halo_sufficiency"
    assert "shards=2" in errs[0].where
    # sufficient margin (the default, derived from the geometry) is clean
    good = verify_plan(plan, band_shards=2)
    assert not _shard_errors(good)


def test_verify_plan_shard_backend_and_alignment():
    ref = SRPlan(height=24, width=16, num_layers=3, backend="reference",
                 band_rows=24)
    errs = _shard_errors(verify_plan(ref, band_shards=2))
    assert errs and errs[0].rule == "shard_backend"
    one_band = small_plan(band_rows=24)
    errs = _shard_errors(verify_plan(one_band, band_shards=2))
    assert errs and errs[0].rule == "shard_band_alignment"


def test_verify_plan_unsharded_has_no_shard_findings():
    plan = small_plan(vertical_policy="halo")
    assert not [f for f in verify_plan(plan) if f.rule.startswith("shard_")]
    assert not [f for f in verify_plan(plan, band_shards=1)
                if f.rule.startswith("shard_")]


def test_sharded_plan_verify_threads_band_shards():
    splan = ShardedPlan(plan=small_plan(vertical_policy="halo"),
                        spec=MeshSpec(1, 2))
    assert not _shard_errors(splan.verify())
    errs = _shard_errors(splan.verify(shard_halo_margin=0))
    assert errs and errs[0].rule == "shard_halo_sufficiency"


# ----------------------------------------------------------------------
# Halo-exchange traffic model
# ----------------------------------------------------------------------
def test_halo_exchange_bytes_per_frame():
    plan = small_plan(vertical_policy="halo", width=32)
    # 2 directions * (S-1) edges * L rows * W * C0 * fp32
    assert halo_exchange_bytes_per_frame(plan, 2) == 2 * 1 * 3 * 32 * 3 * 4
    assert halo_exchange_bytes_per_frame(plan, 4) == 2 * 3 * 3 * 32 * 3 * 4
    assert halo_exchange_bytes_per_frame(plan, 1) == 0
    for policy in ("zero", "replicate"):
        p = small_plan(vertical_policy=policy, width=32)
        assert halo_exchange_bytes_per_frame(p, 4) == 0


# ----------------------------------------------------------------------
# Replica routing policy (host-side logic; no devices required)
# ----------------------------------------------------------------------
def _bare_router(policy, n):
    r = ReplicaRouter.__new__(ReplicaRouter)
    r.policy = policy
    r._replicas = [_Replica(index=i, mesh=None, cache=None, stacks={})
                   for i in range(n)]
    r._rr = 0
    return r


def test_round_robin_rotation():
    r = _bare_router("round_robin", 3)
    assert [r.pick() for _ in range(7)] == [0, 1, 2, 0, 1, 2, 0]


def test_least_loaded_prefers_idle_then_cold():
    r = _bare_router("least_loaded", 3)
    assert r.pick() == 0  # all equal: lowest index
    r.note_launch(0)
    assert r.pick() == 1  # 0 has one in flight
    r.note_launch(1)
    assert r.pick() == 2
    r.note_launch(2)
    r.note_complete(1)  # 1 drains first: equal inflight broken by history?
    # inflight: [1, 0, 1] -> replica 1
    assert r.pick() == 1
    r.note_complete(0)
    r.note_complete(2)
    # all idle again; dispatch history [1, 1, 1] ties -> lowest index
    assert r.pick() == 0


def test_note_complete_floors_at_zero():
    r = _bare_router("least_loaded", 2)
    r.note_complete(0)
    assert r._replicas[0].inflight == 0


def test_replica_fill():
    r = _bare_router("round_robin", 2)
    assert r.replica_fill() == 0.0  # no traffic yet
    r.note_launch(0)
    r.note_launch(1)
    assert r.replica_fill() == 1.0
    r.note_launch(0)
    r.note_launch(0)
    assert r.replica_fill() == pytest.approx(2 / 3)  # mean 2 / peak 3


def test_router_rejects_unknown_policy():
    with pytest.raises(ValueError):
        ReplicaRouter(None, MeshSpec(1, 1), policy="random")


# ----------------------------------------------------------------------
# Session-level mesh validation (topology-independent paths)
# ----------------------------------------------------------------------
def test_session_trivial_mesh_is_unsharded():
    s = SRSession(LAYERS, mesh=(1, 1), autotune="off")
    assert s.mesh_spec is None and s._router is None
    assert s.sharding_stats() is None


def test_session_rejects_full_autotune_on_mesh():
    with pytest.raises(ValueError):
        SRSession(LAYERS, mesh=(1, 2), autotune="full")


def test_session_rejects_bogus_mesh():
    with pytest.raises(ValueError):
        SRSession(LAYERS, mesh="2x4", autotune="off")


@pytest.mark.skipif(jax.device_count() != 1,
                    reason="needs a single-device interpreter")
def test_session_mesh_needs_devices():
    with pytest.raises(ValueError, match="devices"):
        SRSession(LAYERS, mesh=(1, 2), autotune="off")


# ----------------------------------------------------------------------
# Multi-device parity, in-process (runs under CI tier1-multidevice, where
# XLA_FLAGS forces 8 host devices before jax initialises)
# ----------------------------------------------------------------------
needs_devices = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs >= 8 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@needs_devices
@pytest.mark.parametrize("backend", ["tilted", "kernel"])
@pytest.mark.parametrize("policy", ["zero", "halo", "replicate"])
@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_executor_bit_exact(backend, policy, shards):
    from repro.engine.executor import build_stack_executor, prepare_stack
    from repro.launch.mesh import band_submesh, make_sr_mesh

    plan = small_plan(vertical_policy=policy, backend=backend)
    stack = prepare_stack(plan, LAYERS)
    frames = jax.random.uniform(jax.random.PRNGKey(7),
                                (2, *plan.lr_shape), jnp.float32)
    ref = build_stack_executor(plan, stack)(frames)
    mesh = band_submesh(make_sr_mesh(1, shards), 0)
    fn = build_sharded_executor(
        ShardedPlan(plan=plan, spec=MeshSpec(1, shards)), stack, mesh)
    out = fn(frames)
    assert out.dtype == ref.dtype and out.shape == ref.shape
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@needs_devices
def test_sharded_executor_rejects_mismatched_mesh():
    from repro.engine.executor import prepare_stack
    from repro.launch.mesh import band_submesh, make_sr_mesh

    plan = small_plan()
    stack = prepare_stack(plan, LAYERS)
    mesh2 = band_submesh(make_sr_mesh(1, 2), 0)
    with pytest.raises(ValueError, match="band_shards"):
        build_sharded_executor(
            ShardedPlan(plan=plan, spec=MeshSpec(1, 4)), stack, mesh2)


@needs_devices
def test_session_serving_bit_exact_and_routed():
    base = SRSession(LAYERS, vertical_policy="halo", autotune="off")
    sharded = SRSession(LAYERS, vertical_policy="halo", autotune="off",
                        mesh=(2, 4), route="round_robin")
    frames = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(3), (2, 48, 16, 3), jnp.float32))
    want = np.asarray(base.upscale(frames))
    for _ in range(4):  # sequential: each call is its own routed dispatch
        got = np.asarray(sharded.upscale(frames))
        np.testing.assert_array_equal(got, want)
    stats = sharded.sharding_stats()
    assert stats["mesh"] == "2x4" and stats["devices"] == 8
    assert sum(r["dispatches"] for r in stats["replicas"]) >= 4
    assert all(r["dispatches"] >= 1 for r in stats["replicas"])  # rotated
    assert stats["replica_fill"] > 0.0
    assert stats["halo_bytes_per_frame"] > 0


@needs_devices
def test_session_auto_rebands_for_mesh():
    # height 48 defaults to one 48-row band; 2 band shards force 24.
    # halo policy so the re-banded output stays bit-identical (zero /
    # replicate boundaries legitimately depend on where the bands fall).
    s = SRSession(LAYERS, vertical_policy="halo", autotune="off", mesh=(1, 2))
    plan = s.plan_for((48, 16, 3))
    assert plan.num_bands % 2 == 0
    base = SRSession(LAYERS, vertical_policy="halo", autotune="off")
    frames = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(9), (1, 48, 16, 3), jnp.float32))
    np.testing.assert_array_equal(np.asarray(s.upscale(frames)),
                                  np.asarray(base.upscale(frames)))


@needs_devices
def test_session_rejects_unshardable_explicit_band_rows():
    s = SRSession(LAYERS, autotune="off", mesh=(1, 2), band_rows=48)
    with pytest.raises(ValueError):
        s.plan_for((48, 16, 3))


# ----------------------------------------------------------------------
# Subprocess coverage (slow tier): the same guarantees from a
# single-device main process, via forced host devices
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_sharded_parity_subprocess(subproc):
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.engine.executor import build_stack_executor, prepare_stack
        from repro.engine.plan import SRPlan
        from repro.engine.sharding import (MeshSpec, ShardedPlan,
                                           build_sharded_executor)
        from repro.launch.mesh import band_submesh, make_sr_mesh
        from repro.models.abpn import ABPNConfig, init_abpn

        layers = init_abpn(jax.random.PRNGKey(0),
                           ABPNConfig(num_layers=3, feature_channels=8))
        frames = jax.random.uniform(jax.random.PRNGKey(7), (2, 24, 16, 3))
        for backend in ("tilted", "kernel"):
            for policy in ("zero", "halo", "replicate"):
                plan = SRPlan(height=24, width=16, num_layers=3, band_rows=6,
                              vertical_policy=policy, backend=backend)
                stack = prepare_stack(plan, layers)
                ref = np.asarray(build_stack_executor(plan, stack)(frames))
                for S in (2, 4):
                    mesh = band_submesh(make_sr_mesh(1, S), 0)
                    fn = build_sharded_executor(
                        ShardedPlan(plan=plan, spec=MeshSpec(1, S)),
                        stack, mesh)
                    np.testing.assert_array_equal(np.asarray(fn(frames)), ref)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_replica_routing_subprocess(subproc):
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.engine.session import SRSession
        from repro.models.abpn import ABPNConfig, init_abpn

        layers = init_abpn(jax.random.PRNGKey(0),
                           ABPNConfig(num_layers=3, feature_channels=8))
        base = SRSession(layers, vertical_policy="halo", autotune="off")
        sharded = SRSession(layers, vertical_policy="halo", autotune="off",
                            mesh=(2, 2), route="least_loaded")
        frames = np.asarray(jax.random.uniform(
            jax.random.PRNGKey(3), (2, 24, 16, 3), jnp.float32))
        want = np.asarray(base.upscale(frames))
        for _ in range(4):
            np.testing.assert_array_equal(
                np.asarray(sharded.upscale(frames)), want)
        stats = sharded.sharding_stats()
        assert stats["mesh"] == "2x2", stats
        assert sum(r["dispatches"] for r in stats["replicas"]) >= 4, stats
        print("OK")
    """)
    assert "OK" in out
