"""SRSession serving API: plan derivation, batch bucketing, PlanCache LRU,
session/stream parity, empty-clip dtype, warmup dtype.  All fast tier."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_compat import given, settings, strategies as st

from repro import engine
from repro.engine.plan import derive_band_rows
from repro.engine.session import PlanCache, bucket_batch
from repro.models.abpn import ABPNConfig, init_abpn
from repro.models.registry import get_sr_model, register_sr_model

CFG = ABPNConfig()
LAYERS = init_abpn(jax.random.PRNGKey(2), CFG)


def make_stream(plan, layers, batch_size, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return engine.VideoStream(plan, layers, batch_size, **kw)


# ----------------------------------------------------------------------
# Batch bucketing + band_rows derivation (property-style)
# ----------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(n=st.integers(min_value=1, max_value=4096))
def test_bucket_batch_rounds_to_next_power_of_two(n):
    b = bucket_batch(n)
    assert b >= n
    assert b & (b - 1) == 0  # power of two
    assert b // 2 < n  # the NEXT power of two, not a later one


def test_bucket_batch_rejects_nonpositive():
    with pytest.raises(ValueError):
        bucket_batch(0)


@settings(max_examples=60, deadline=None)
@given(h=st.integers(min_value=1, max_value=2000))
def test_derive_band_rows_always_legal(h):
    r = derive_band_rows(h)
    assert h % r == 0  # banded backends need an even partition
    # either near the paper's 60-row design point or one full-height band
    assert r <= 60 or r == h


def test_derive_band_rows_design_points():
    assert derive_band_rows(360) == 60  # the paper's frame
    assert derive_band_rows(120) == 60
    assert derive_band_rows(64) == 32
    assert derive_band_rows(97) == 97  # prime: one band, no slivers
    assert derive_band_rows(6) == 6


def test_plan_from_request_derives_geometry():
    plan = engine.SRPlan.from_request((120, 64, 3), num_layers=7)
    assert (plan.band_rows, plan.num_bands) == (60, 2)
    explicit = engine.SRPlan.from_request((120, 64, 3), num_layers=7,
                                          band_rows=30)
    assert explicit.num_bands == 4
    with pytest.raises(ValueError):
        engine.SRPlan.from_request((120, 64), num_layers=7)  # not (H, W, C)


# ----------------------------------------------------------------------
# PlanCache: LRU order, eviction, counters
# ----------------------------------------------------------------------
def test_plan_cache_lru_eviction_order():
    cache = PlanCache(capacity=2)
    cache.put("a", "A")
    cache.put("b", "B")
    assert cache.get("a") == "A"  # bumps a to MRU
    cache.put("c", "C")  # evicts b (LRU), not a
    assert cache.keys() == ["a", "c"]
    assert "b" not in cache and cache.evictions == 1
    assert cache.get("b") is None  # miss


def test_plan_cache_counters_and_stats():
    cache = PlanCache(capacity=3)
    assert cache.get("x") is None  # miss on empty
    cache.put("x", 1)
    assert cache.get("x") == 1 and cache.get("x") == 1  # two hits
    s = cache.stats()
    assert s["hits"] == 2 and s["misses"] == 1 and s["evictions"] == 0
    assert s["size"] == 1 and s["capacity"] == 3
    assert s["hit_rate"] == pytest.approx(2 / 3)
    with pytest.raises(ValueError):
        PlanCache(capacity=0)


@settings(max_examples=20, deadline=None)
@given(capacity=st.integers(min_value=1, max_value=6),
       n_keys=st.integers(min_value=1, max_value=12))
def test_plan_cache_never_exceeds_capacity(capacity, n_keys):
    cache = PlanCache(capacity=capacity)
    for k in range(n_keys):
        cache.put(k, k)
    assert len(cache) == min(capacity, n_keys)
    assert cache.evictions == max(0, n_keys - capacity)
    # survivors are the most recently inserted keys, oldest first
    assert cache.keys() == list(range(max(0, n_keys - capacity), n_keys))


# ----------------------------------------------------------------------
# SRSession serving (the acceptance scenario)
# ----------------------------------------------------------------------
def test_session_serves_mixed_resolutions_and_batches():
    """One session, three resolutions x two batch sizes, no user-visible
    plan construction: exactly one compile per (plan, bucket), hits on
    repeats."""
    session = engine.SRSession.open("abpn_x3", layers=LAYERS, backend="tilted")
    resolutions = [(12, 16, 3), (24, 16, 3), (36, 8, 3)]
    batch_sizes = (1, 3)  # buckets 1 and 4
    for _ in range(2):  # second pass must be all cache hits
        for (h, w, c) in resolutions:
            for bs in batch_sizes:
                frames = jnp.ones((bs, h, w, c))
                hr = session.upscale(frames)
                assert hr.shape == (bs, 3 * h, 3 * w, c)
    s = session.cache_stats()
    assert s["misses"] == len(resolutions) * len(batch_sizes)  # one compile each
    assert s["hits"] == len(resolutions) * len(batch_sizes)
    assert s["evictions"] == 0 and s["size"] == 6
    assert sorted({(tuple(e["lr_shape"]), e["bucket"]) for e in s["entries"]}) == \
        sorted((r, engine.bucket_batch(b)) for r in resolutions for b in batch_sizes)
    assert all(e["compile_s"] > 0 for e in s["entries"])
    st_ = session.stats()
    assert st_["frames"] == 2 * sum(batch_sizes) * len(resolutions)


def test_session_rank_handling_matches_flat_batch():
    session = engine.SRSession(LAYERS, backend="tilted")
    frames = jax.random.uniform(jax.random.PRNGKey(5), (4, 12, 16, 3))
    flat = session.upscale(frames)
    single = session.upscale(frames[0])  # (H, W, C)
    np.testing.assert_array_equal(np.asarray(single), np.asarray(flat[0]))
    nested = session.upscale(frames.reshape(2, 2, 12, 16, 3))  # (B, T, ...)
    assert nested.shape == (2, 2, 36, 48, 3)
    np.testing.assert_array_equal(
        np.asarray(nested.reshape(4, 36, 48, 3)), np.asarray(flat))
    with pytest.raises(ValueError):
        session.upscale(jnp.ones((12, 16)))  # rank 2
    with pytest.raises(ValueError):
        session.upscale(jnp.ones((2, 12, 16, 4)))  # channel mismatch


def test_session_bucket_padding_parity():
    """A batch that is not a power of two is padded to its bucket; padding
    must not leak into the real frames' output."""
    session = engine.SRSession(LAYERS, backend="tilted")
    frames = jax.random.uniform(jax.random.PRNGKey(6), (3, 12, 16, 3))
    out3 = session.upscale(frames)  # bucket 4, one padded frame
    plan = session.plan_for((12, 16, 3))
    np.testing.assert_array_equal(
        np.asarray(out3), np.asarray(engine.run(plan, LAYERS, frames)))


def test_session_max_bucket_is_a_ceiling():
    """max_bucket is never exceeded: the bucket clamps DOWN to the largest
    power of two within the cap and larger requests chunk."""
    session = engine.SRSession(LAYERS, backend="tilted", max_bucket=5)
    frames = jax.random.uniform(jax.random.PRNGKey(8), (8, 12, 16, 3))
    out = session.upscale(frames)  # bucket 4, two chunks
    assert out.shape == (8, 36, 48, 3)
    entries = session.cache_stats()["entries"]
    assert [e["bucket"] for e in entries] == [4]
    assert session.stats()["batches"] == 2
    plan = session.plan_for((12, 16, 3))
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(engine.run(plan, LAYERS, frames)))


def test_session_matches_video_stream_on_identical_input():
    plan = engine.make_plan(LAYERS, (60, 32, 3), band_rows=30,
                            backend="tilted")
    stream = make_stream(plan, LAYERS, batch_size=2)
    session = engine.SRSession(LAYERS, backend="tilted", band_rows=30)
    frames = jax.random.uniform(jax.random.PRNGKey(7), (5, 60, 32, 3))
    np.testing.assert_array_equal(
        np.asarray(session.upscale(frames)), np.asarray(stream.run(frames)))


def test_session_lru_eviction_keeps_serving():
    session = engine.SRSession(LAYERS, backend="tilted", cache_capacity=1)
    a = jnp.ones((1, 12, 16, 3))
    b = jnp.ones((1, 24, 16, 3))
    session.upscale(a)
    session.upscale(b)  # evicts the (12, 16) entry
    out = session.upscale(a)  # recompiles, still correct
    assert out.shape == (1, 36, 48, 3)
    s = session.cache_stats()
    assert s["evictions"] == 2 and s["size"] == 1 and s["misses"] == 3


def test_session_empty_request_matches_compiled_dtype():
    session = engine.SRSession(LAYERS, backend="tilted")
    for dtype in (jnp.float32, jnp.bfloat16):
        full = session.upscale(jnp.ones((1, 12, 16, 3), dtype))
        empty = session.upscale(jnp.zeros((0, 12, 16, 3), dtype))
        assert empty.shape == (0, 36, 48, 3)
        assert empty.dtype == full.dtype
    nested = session.upscale(jnp.zeros((2, 0, 12, 16, 3)))
    assert nested.shape == (2, 0, 36, 48, 3)


def test_session_open_resolves_registry_and_unknown_model():
    spec = get_sr_model("abpn_x3")
    assert spec is get_sr_model("abpn-x3")  # alias
    assert len(spec.init(jax.random.PRNGKey(0))) == CFG.num_layers
    session = engine.SRSession.open("abpn", seed=3)
    assert session.model == "abpn_x3" and session.scale == CFG.scale
    with pytest.raises(ValueError, match="unknown SR model"):
        engine.SRSession.open("espcn_x4")
    with pytest.raises(ValueError, match="layer stack is empty"):
        engine.SRSession([])


def test_register_sr_model_collision_leaves_registry_untouched():
    with pytest.raises(ValueError, match="already registered"):
        register_sr_model("espcn_x4", CFG, init_abpn, aliases=("abpn",))
    with pytest.raises(ValueError, match="unknown SR model"):
        get_sr_model("espcn_x4")  # the failed call must not half-register


# ----------------------------------------------------------------------
# VideoStream shim: empty-clip dtype + warmup dtype (the two bugfixes)
# ----------------------------------------------------------------------
def test_video_stream_is_deprecated():
    plan = engine.make_plan(LAYERS, (60, 32, 3), band_rows=30)
    with pytest.warns(DeprecationWarning):
        engine.VideoStream(plan, LAYERS, batch_size=1)


def test_video_stream_empty_clip_dtype_matches_compiled_output():
    plan = engine.make_plan(LAYERS, (60, 32, 3), band_rows=30,
                            backend="tilted")
    stream = make_stream(plan, LAYERS, batch_size=2)
    for dtype in (jnp.float32, jnp.bfloat16):
        full = stream.process(jnp.ones((2, 60, 32, 3), dtype))
        empty = stream.run(jnp.zeros((0, 60, 32, 3), dtype))
        assert empty.dtype == full.dtype
        assert empty.shape == (0, 180, 96, 3)


def test_video_stream_warmup_compiles_serving_dtype():
    """Warming up in the serving dtype means the first real batch is a
    cache hit — no second compile counted as serving latency."""
    plan = engine.make_plan(LAYERS, (60, 32, 3), band_rows=30,
                            backend="tilted")
    stream = make_stream(plan, LAYERS, batch_size=2, dtype=jnp.bfloat16)
    compile_s = stream.warmup()
    assert compile_s > 0
    stream.process(jnp.ones((2, 60, 32, 3), jnp.bfloat16))
    s = stream.cache_stats()
    assert s["misses"] == 1 and s["hits"] == 1  # one compile, then a hit
    assert s["entries"][0]["dtype"] == "bfloat16"
    # a batch in a different dtype compiles separately (outside the timed
    # region), it does not silently recompile the warm entry
    stream.process(jnp.ones((2, 60, 32, 3), jnp.float32))
    s = stream.cache_stats()
    assert s["misses"] == 2 and s["size"] == 2
    assert s["entries"][-1]["dtype"] == "float32"
