"""Tests for the roofline-guided plan autotuner (engine.autotune).

Covers the four contracts ISSUE 6 pins down:

* TuningDB — round-trip, atomic write, bounded capacity, stale-schema /
  wrong-backend rejection.
* Pruning safety — the analytic roofline's 1.5x prune never discards the
  measured-best candidate on a parity-matrix-style plan set (tune with
  ``measure_all=True`` finds the true best; the pruned sweep must land
  within tie tolerance of it), and the default candidate always survives.
* Serving integration — a cold ``SRSession`` with ``autotune="cached"``
  and a warm DB compiles ONLY the winning plan (cache misses == 1, no
  non-winning candidate ever compiled), and ``"cached"`` NEVER measures.
* Numerics — a tuned schedule is bit-exact against the default schedule
  (tuning changes the schedule, never the output), including band_rows
  moves under the halo policy, where band decomposition is an exact
  recompute.
"""

import json
import os

import jax
import numpy as np
import pytest

from repro.engine import autotune as at
from repro.engine.autotune import (
    SCHEMA_VERSION,
    PlanTuner,
    TuningDB,
    TuningEntry,
    TuningKey,
    enumerate_candidates,
    predict_cost,
    tune,
)
from repro.engine.plan import SRPlan, derive_band_rows, legal_band_rows
from repro.engine.session import SRSession
from repro.models.abpn import ABPNConfig, init_abpn

CFG = ABPNConfig()
LAYERS = init_abpn(jax.random.PRNGKey(0), CFG)
SMALL = (24, 16, 3)


def small_plan(**kw) -> SRPlan:
    return SRPlan.from_request(
        SMALL, num_layers=len(LAYERS), scale=CFG.scale, **kw
    )


def entry_for(plan: SRPlan, batch: int, **over) -> TuningEntry:
    base = dict(
        band_rows=plan.band_rows, pipeline_depth=1, bucket=batch,
        bucket_policy="exact", predicted_ms=1.0, measured_ms=1.0,
        default_ms=1.5, speedup=1.5,
        jax_backend=jax.default_backend(), device_kind=at.device_kind(),
        created=123.0,
        device_count=jax.device_count(), mesh_shape="1x1",
    )
    base.update(over)
    return TuningEntry(**base)


# ----------------------------------------------------------------------
# legal_band_rows / derive_band_rows (the satellite generalisation)
# ----------------------------------------------------------------------
def test_legal_band_rows_all_divisors_sorted_by_preference():
    cands = legal_band_rows(120)
    assert all(120 % d == 0 for d in cands)
    assert cands[0] == 60  # nearest the paper's design point
    assert set(cands) == {8, 10, 12, 15, 20, 24, 30, 40, 60, 120}
    # distance from preferred is non-decreasing
    dist = [abs(d - 60) for d in cands]
    assert dist == sorted(dist)


def test_legal_band_rows_prime_height_only_full_band():
    assert legal_band_rows(127) == [127]


def test_derive_band_rows_matches_legacy_semantics():
    assert derive_band_rows(360) == 60
    assert derive_band_rows(120) == 60
    assert derive_band_rows(80) == 40
    assert derive_band_rows(62) == 31
    assert derive_band_rows(24) == 24
    assert derive_band_rows(127) == 127  # prime: one giant band


def test_prime_height_warns_and_flags_degenerate():
    with pytest.warns(RuntimeWarning, match="ONE 127-row band"):
        plan = SRPlan.from_request((127, 16, 3), num_layers=len(LAYERS))
    assert plan.degenerate_bands is True
    assert plan.band_rows == 127
    # metadata only: equal to the same plan without the flag, same hash
    twin = SRPlan.from_request((127, 16, 3), num_layers=len(LAYERS),
                               band_rows=127)
    assert twin.degenerate_bands is False
    assert plan == twin and hash(plan) == hash(twin)


def test_non_degenerate_heights_do_not_warn():
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plan = SRPlan.from_request((120, 16, 3), num_layers=len(LAYERS))
    assert plan.degenerate_bands is False


# ----------------------------------------------------------------------
# TuningDB
# ----------------------------------------------------------------------
def test_db_round_trip(tmp_path):
    path = str(tmp_path / "db.json")
    plan = small_plan()
    key = TuningKey.from_plan(plan, 3)
    db = TuningDB(path)
    db.put(key, entry_for(plan, 3))
    db.save()

    db2 = TuningDB(path)
    got = db2.get(key)
    assert got is not None
    assert got.bucket == 3 and got.bucket_policy == "exact"
    assert got.speedup == 1.5
    # a different batch is a different key
    assert db2.get(TuningKey.from_plan(plan, 5)) is None


def test_db_atomic_write_leaves_no_partial_file(tmp_path):
    path = str(tmp_path / "db.json")
    plan = small_plan()
    db = TuningDB(path)
    db.put(TuningKey.from_plan(plan, 1), entry_for(plan, 1))
    db.save()
    before = open(path).read()

    # a failing save must leave the original intact and no temp litter
    class Boom(RuntimeError):
        pass

    unserializable = entry_for(plan, 2)
    unserializable.band_rows = object()  # json.dump will raise mid-write
    db.put(TuningKey.from_plan(plan, 2), unserializable)
    with pytest.raises(TypeError):
        db.save()
    assert open(path).read() == before
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []
    # and the intact file still loads
    assert TuningDB(path).get(TuningKey.from_plan(plan, 1)) is not None


def test_db_stale_schema_rejected(tmp_path):
    path = str(tmp_path / "db.json")
    plan = small_plan()
    key = TuningKey.from_plan(plan, 1)
    db = TuningDB(path)
    db.put(key, entry_for(plan, 1))
    db.save()

    raw = json.load(open(path))
    raw["schema"] = SCHEMA_VERSION + 1
    json.dump(raw, open(path, "w"))
    stale = TuningDB(path)
    assert stale.stale_schema is True
    assert len(stale) == 0
    assert stale.get(key) is None


def test_db_wrong_backend_or_device_rejected(tmp_path):
    path = str(tmp_path / "db.json")
    plan = small_plan()
    key = TuningKey.from_plan(plan, 1)
    db = TuningDB(path)
    db.put(key, entry_for(plan, 1, jax_backend="tpu"))
    db.put(TuningKey.from_plan(plan, 2),
           entry_for(plan, 2, device_kind="TPU v4"))
    db.save()
    db2 = TuningDB(path)
    assert db2.get(key) is None  # wrong jax backend
    assert db2.get(TuningKey.from_plan(plan, 2)) is None  # wrong device
    # entries are still PRESENT (not deleted) — just never applied here
    assert len(db2) == 2


def test_db_wrong_topology_rejected(tmp_path):
    """An entry tuned on one device layout must never apply on another
    (PR 8 satellite: device_count + mesh_shape validity stamps)."""
    path = str(tmp_path / "db.json")
    plan = small_plan()
    key = TuningKey.from_plan(plan, 1)
    db = TuningDB(path)
    db.put(key, entry_for(plan, 1, device_count=jax.device_count() + 7))
    db.put(TuningKey.from_plan(plan, 2),
           entry_for(plan, 2, mesh_shape="2x4"))
    db.save()
    db2 = TuningDB(path)
    assert db2.get(key) is None  # wrong device count
    assert db2.get(TuningKey.from_plan(plan, 2)) is None  # wrong mesh
    # the consumer's own topology accepts it again
    assert db2.get(key, device_count=jax.device_count() + 7) is not None
    assert db2.get(TuningKey.from_plan(plan, 2),
                   mesh_shape="2x4") is not None
    # entries are still PRESENT (not deleted) — just never applied here
    assert len(db2) == 2
    # and a PlanTuner pinned to a topology only sees matching entries
    tuner = PlanTuner(db2, mesh_shape="2x4")
    entry, kind = tuner.lookup(TuningKey.from_plan(plan, 2))
    assert kind == "hit" and entry.mesh_shape == "2x4"
    assert PlanTuner(db2).lookup(key) == (None, "miss")


def test_entry_missing_topology_stamp_rejected():
    """Entries persisted before the topology stamp (schema v1 layout) are
    malformed under v2 — from_dict must reject them even though the
    dataclass fields now carry defaults."""
    d = entry_for(small_plan(), 1).to_dict()
    del d["device_count"]
    assert TuningEntry.from_dict(d) is None
    d2 = entry_for(small_plan(), 1).to_dict()
    del d2["mesh_shape"]
    assert TuningEntry.from_dict(d2) is None


def test_db_malformed_and_torn_files_start_empty(tmp_path):
    torn = tmp_path / "torn.json"
    torn.write_text('{"schema": 1, "entries": {"k": ')  # truncated
    db = TuningDB(str(torn))
    assert len(db) == 0 and db.stale_schema is False

    notdict = tmp_path / "list.json"
    notdict.write_text("[1, 2, 3]")
    db2 = TuningDB(str(notdict))
    assert len(db2) == 0 and db2.stale_schema is True


def test_db_bounded_capacity_evicts_oldest(tmp_path):
    plan = small_plan()
    db = TuningDB(str(tmp_path / "db.json"), capacity=3)
    for b in (1, 2, 3, 4):
        db.put(TuningKey.from_plan(plan, b), entry_for(plan, b))
    assert len(db) == 3
    assert db.get(TuningKey.from_plan(plan, 1)) is None  # oldest evicted
    assert db.get(TuningKey.from_plan(plan, 4)) is not None


def test_db_nearest_batch_fallback(tmp_path):
    plan = small_plan()
    db = TuningDB(str(tmp_path / "db.json"))
    db.put(TuningKey.from_plan(plan, 4), entry_for(plan, 4, bucket=4))
    db.put(TuningKey.from_plan(plan, 16), entry_for(plan, 16, bucket=16))
    near = db.get_nearest_batch(TuningKey.from_plan(plan, 5))
    assert near is not None
    entry, tuned_batch = near
    assert tuned_batch == 4  # |5-4| < |5-16|
    # a different configuration (other policy) never matches
    other = SRPlan.from_request(SMALL, num_layers=len(LAYERS),
                                vertical_policy="halo", scale=CFG.scale)
    assert db.get_nearest_batch(TuningKey.from_plan(other, 5)) is None


# ----------------------------------------------------------------------
# PlanTuner lookup semantics
# ----------------------------------------------------------------------
def test_tuner_hit_fallback_miss(tmp_path):
    plan = small_plan()
    db = TuningDB(str(tmp_path / "db.json"))
    db.put(TuningKey.from_plan(plan, 3), entry_for(plan, 3))
    tuner = PlanTuner(db)
    assert tuner.lookup(TuningKey.from_plan(plan, 3))[1] == "hit"
    assert tuner.lookup(TuningKey.from_plan(plan, 7))[1] == "fallback"
    other = SRPlan.from_request(SMALL, num_layers=len(LAYERS),
                                precision="bf16", scale=CFG.scale)
    assert tuner.lookup(TuningKey.from_plan(other, 3))[1] == "miss"


def test_tuner_rejects_numerics_unsafe_band_override(tmp_path):
    """A DB entry moving band_rows off the default must only apply under
    halo (exact band decomposition); zero-policy plans ignore it."""
    zero_plan = small_plan()  # zero policy, band_rows == 24 (default)
    db = TuningDB(str(tmp_path / "db.json"))
    db.put(TuningKey.from_plan(zero_plan, 1),
           entry_for(zero_plan, 1, band_rows=8))  # 8 != default 24
    tuner = PlanTuner(db)
    entry, kind = tuner.lookup(TuningKey.from_plan(zero_plan, 1))
    assert entry is None and kind == "miss"
    # the same override IS honoured for a halo plan
    halo_plan = SRPlan.from_request(SMALL, num_layers=len(LAYERS),
                                    vertical_policy="halo", scale=CFG.scale)
    db.put(TuningKey.from_plan(halo_plan, 1),
           entry_for(halo_plan, 1, band_rows=8))
    entry, kind = tuner.lookup(TuningKey.from_plan(halo_plan, 1))
    assert entry is not None and entry.band_rows == 8


def test_tuner_rejects_stale_geometry(tmp_path):
    """An entry whose band_rows no longer divides the height is stale."""
    plan = small_plan(vertical_policy="halo")
    db = TuningDB(str(tmp_path / "db.json"))
    db.put(TuningKey.from_plan(plan, 1), entry_for(plan, 1, band_rows=7))
    assert PlanTuner(db).lookup(TuningKey.from_plan(plan, 1))[0] is None


def test_from_request_consults_tuner(tmp_path):
    halo_plan = SRPlan.from_request(SMALL, num_layers=len(LAYERS),
                                    vertical_policy="halo", scale=CFG.scale)
    db = TuningDB(str(tmp_path / "db.json"))
    db.put(TuningKey.from_plan(halo_plan, 2),
           entry_for(halo_plan, 2, band_rows=8))
    tuned = SRPlan.from_request(
        SMALL, num_layers=len(LAYERS), vertical_policy="halo",
        scale=CFG.scale, tuner=PlanTuner(db), bucket=2,
    )
    assert tuned.band_rows == 8
    assert tuned.degenerate_bands is False  # a measured choice, not a fallback
    # no tuner -> unchanged default
    assert SRPlan.from_request(
        SMALL, num_layers=len(LAYERS), vertical_policy="halo",
        scale=CFG.scale,
    ).band_rows == 24


# ----------------------------------------------------------------------
# Candidate space + analytic roofline
# ----------------------------------------------------------------------
def test_enumerate_has_exactly_one_default():
    for policy in ("zero", "halo"):
        plan = small_plan(vertical_policy=policy)
        cands = enumerate_candidates(plan, 3)
        assert sum(c.is_default for c in cands) == 1
        d = next(c for c in cands if c.is_default)
        assert d.bucket == 4 and d.pipeline_depth == 2
        assert d.band_rows == derive_band_rows(plan.height)


def test_enumerate_band_axis_only_under_halo():
    zero = enumerate_candidates(small_plan(), 1)
    assert {c.band_rows for c in zero} == {24}
    halo = enumerate_candidates(
        SRPlan.from_request((120, 16, 3), num_layers=len(LAYERS),
                            vertical_policy="halo", scale=CFG.scale),
        1,
    )
    assert len({c.band_rows for c in halo}) > 1
    assert all(120 % c.band_rows == 0 for c in halo)


def test_predict_cost_orders_padding_waste():
    """The analytic model must charge bucket padding: serving 3 real
    frames in a bucket of 4 predicts slower per-frame than exact 3."""
    plan = small_plan()
    exact = predict_cost(plan, LAYERS, 3, 3)["ms_per_frame"]
    padded = predict_cost(plan, LAYERS, 4, 3)["ms_per_frame"]
    assert padded > exact
    assert padded == pytest.approx(exact * 4 / 3)


def test_predict_cost_charges_halo_recompute():
    h = SRPlan.from_request((120, 16, 3), num_layers=len(LAYERS),
                            vertical_policy="halo", scale=CFG.scale)
    z = SRPlan.from_request((120, 16, 3), num_layers=len(LAYERS),
                            scale=CFG.scale)
    fh = predict_cost(h, LAYERS, 1, 1)["flops_per_frame"]
    fz = predict_cost(z, LAYERS, 1, 1)["flops_per_frame"]
    assert fh > fz  # (R + 2L) rows computed per band vs R


# ----------------------------------------------------------------------
# tune(): pruning safety, winner guarantees (measured — the slower tests)
# ----------------------------------------------------------------------
def test_default_candidate_never_pruned():
    plan = small_plan()
    # absurd peaks make the analytic model maximally wrong: everything
    # prunable... except the exempt default
    peaks = at.RooflinePeaks(flops_per_s=1.0, hbm_bytes_per_s=1e18,
                             cache_bytes=1e18)
    entry = tune(LAYERS, plan, 3, depths=(1,), chunks=2, reps=1, peaks=peaks)
    cands = entry.candidates
    assert not any(c.pruned and c.is_default for c in cands)
    assert any(not c.pruned for c in cands)


def test_tuned_never_regresses_below_default():
    plan = small_plan()
    for batch in (1, 3):
        entry = tune(LAYERS, plan, batch, depths=(1, 2), chunks=2, reps=1)
        assert entry.measured_ms <= entry.default_ms
        assert entry.speedup >= 1.0


@pytest.mark.slow
def test_pruning_never_discards_measured_best():
    """Parity-matrix-style plan set: run ONE unpruned (measure_all) sweep
    per plan, find the measured-best candidate, and check the 1.5x
    analytic prune rule would have kept it.  (Deterministic: the prune
    decision is a pure function of the analytic predictions already on
    the candidates — no second noisy measurement.)"""
    plan_set = [
        small_plan(),
        small_plan(vertical_policy="halo"),
        small_plan(precision="bf16"),
        SRPlan.from_request((48, 16, 3), num_layers=len(LAYERS),
                            vertical_policy="halo", scale=CFG.scale),
    ]
    for plan in plan_set:
        full = tune(LAYERS, plan, 3, depths=(1, 2), chunks=2, reps=2,
                    measure_all=True)
        cands = full.candidates
        assert not any(c.pruned for c in cands)  # measure_all measured all
        best_pred = min(c.predicted_ms for c in cands)
        import math

        measured = [c for c in cands if not math.isnan(c.measured_ms)]
        best = min(measured, key=lambda c: c.measured_ms)
        assert best.is_default or best.predicted_ms <= 1.5 * best_pred, (
            f"{plan.vertical_policy}/{plan.precision}: the 1.5x prune "
            f"would discard the measured-best candidate (band "
            f"{best.band_rows}, bucket {best.bucket}, depth "
            f"{best.pipeline_depth}: predicted {best.predicted_ms:.3f}ms "
            f"vs roofline-best {best_pred:.3f}ms)"
        )


def test_tune_persists_and_reload_hits(tmp_path):
    plan = small_plan()
    db = TuningDB(str(tmp_path / "db.json"))
    entry = tune(LAYERS, plan, 3, db=db, depths=(1,), chunks=2, reps=1)
    got = TuningDB(str(tmp_path / "db.json")).get(TuningKey.from_plan(plan, 3))
    assert got is not None
    assert got.bucket == entry.bucket
    assert got.pipeline_depth == entry.pipeline_depth


# ----------------------------------------------------------------------
# Serving integration (SRSession / SRServer)
# ----------------------------------------------------------------------
def warm_db(path: str, plan: SRPlan, batch: int) -> TuningEntry:
    db = TuningDB(path)
    return tune(LAYERS, plan, batch, db=db, depths=(1, 2), chunks=2, reps=1)


def test_cached_session_compiles_only_the_winner(tmp_path):
    """The acceptance criterion: cold session + warm DB => exactly one
    compile, and it is the tuned winner's (plan, bucket)."""
    path = str(tmp_path / "db.json")
    plan = small_plan()
    entry = warm_db(path, plan, 3)

    session = SRSession(LAYERS, scale=CFG.scale, autotune="cached",
                        tuning_db=path)
    frames = np.random.default_rng(0).random((3, *SMALL), np.float32)
    out = session.upscale(frames)
    assert out.shape == (3, 72, 48, 3)

    ts = session.tuning_stats()
    assert ts["hits"] == 1 and ts["misses"] == 0
    assert ts["applied"] == 1 and ts["tuned_now"] == 0
    cs = session.cache_stats()
    assert cs["misses"] == 1  # ONLY the winning plan compiled
    assert len(cs["entries"]) == 1
    assert cs["entries"][0]["bucket"] == entry.bucket
    assert cs["entries"][0]["band_rows"] == entry.band_rows
    assert session.pipeline_depth == entry.pipeline_depth


def test_cached_mode_never_measures_on_miss(tmp_path):
    """"cached" on a cold DB: miss counted, defaults used, NO sweep run
    (the DB file stays empty)."""
    path = str(tmp_path / "db.json")
    session = SRSession(LAYERS, scale=CFG.scale, autotune="cached",
                        tuning_db=path)
    frames = np.zeros((3, *SMALL), np.float32)
    session.upscale(frames)
    ts = session.tuning_stats()
    assert ts["misses"] == 1 and ts["tuned_now"] == 0
    assert not os.path.exists(path)  # nothing measured, nothing written
    # defaults: pow2 bucket, depth 2
    assert session.cache_stats()["entries"][0]["bucket"] == 4
    assert session.pipeline_depth == 2


def test_full_mode_tunes_on_miss_and_persists(tmp_path):
    path = str(tmp_path / "db.json")
    session = SRSession(LAYERS, scale=CFG.scale, autotune="full",
                        tuning_db=path)
    frames = np.zeros((3, *SMALL), np.float32)
    session.upscale(frames)
    ts = session.tuning_stats()
    assert ts["misses"] == 1 and ts["tuned_now"] == 1 and ts["applied"] == 1
    assert len(TuningDB(path)) == 1
    # a SECOND session now cold-starts as a pure cache hit
    s2 = SRSession(LAYERS, scale=CFG.scale, autotune="cached",
                   tuning_db=path)
    s2.upscale(frames)
    assert s2.tuning_stats()["hits"] == 1
    assert s2.tuning_stats()["tuned_now"] == 0


def test_off_mode_never_touches_db(tmp_path):
    session = SRSession(LAYERS, scale=CFG.scale, autotune="off")
    assert session._tuner is None
    session.upscale(np.zeros((3, *SMALL), np.float32))
    ts = session.tuning_stats()
    assert ts == {"mode": "off", "db_path": None, "hits": 0, "misses": 0,
                  "fallbacks": 0, "applied": 0, "tuned_now": 0,
                  "pipeline_depth": 2, "exact_buckets": [],
                  "degenerate_plans": 0}


def test_explicit_pipeline_depth_never_overridden(tmp_path):
    path = str(tmp_path / "db.json")
    plan = small_plan()
    db = TuningDB(path)
    db.put(TuningKey.from_plan(plan, 3), entry_for(plan, 3, pipeline_depth=4))
    db.save()
    session = SRSession(LAYERS, scale=CFG.scale, autotune="cached",
                        tuning_db=path, pipeline_depth=3)
    session.upscale(np.zeros((3, *SMALL), np.float32))
    assert session.tuning_stats()["applied"] == 1
    assert session.pipeline_depth == 3  # the caller's explicit choice


def test_invalid_autotune_mode_rejected():
    with pytest.raises(ValueError, match="autotune"):
        SRSession(LAYERS, scale=CFG.scale, autotune="always")


def test_server_passes_policy_per_model(tmp_path):
    from repro.engine.server import SRServer

    srv = SRServer.open("abpn_x3", autotune="off")
    assert srv.session().tuning_stats()["mode"] == "off"
    srv2 = SRServer.open("abpn_x3", autotune={"abpn_x3": "full"})
    assert srv2.session().tuning_stats()["mode"] == "full"


# ----------------------------------------------------------------------
# Numerics: tuning must never change the output
# ----------------------------------------------------------------------
def test_tuned_output_bit_exact_vs_default(tmp_path):
    """End-to-end: the tuned session's output equals the default
    session's, bit for bit (exact bucket + depth change only)."""
    path = str(tmp_path / "db.json")
    warm_db(path, small_plan(), 3)
    frames = np.random.default_rng(1).random((3, *SMALL), np.float32)
    tuned = SRSession(LAYERS, scale=CFG.scale, autotune="cached",
                      tuning_db=path).upscale(frames)
    default = SRSession(LAYERS, scale=CFG.scale,
                        autotune="off").upscale(frames)
    assert np.array_equal(np.asarray(tuned), np.asarray(default))


@pytest.mark.slow
def test_halo_band_rows_move_is_bit_exact(tmp_path):
    """The numerics-safety premise of the band axis: under halo, EVERY
    legal band decomposition produces the identical output — so a tuned
    band_rows override cannot change serving results."""
    shape = (48, 16, 3)
    frames = np.random.default_rng(2).random((2, *shape), np.float32)
    outs = []
    for band in legal_band_rows(48):
        plan = SRPlan.from_request(shape, num_layers=len(LAYERS),
                                   vertical_policy="halo",
                                   band_rows=band, scale=CFG.scale)
        s = SRSession.from_plan(plan, LAYERS, autotune="off")
        outs.append(np.asarray(s.upscale(frames)))
    for o in outs[1:]:
        assert np.array_equal(outs[0], o)
