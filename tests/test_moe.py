"""MoE: routing/capacity semantics vs an explicit per-token reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.layers.moe import capacity, moe_block, moe_schema
from repro.layers.params import init_params


def loop_reference(p, cfg, x):
    """Token-by-token routing with the same capacity-drop rule."""
    B, S, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = capacity(cfg, S)
    logits = np.einsum("bsd,de->bse", np.asarray(x, np.float32),
                       np.asarray(p["router"], np.float32))
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    out = np.zeros((B, S, d), np.float32)
    silu = lambda t: t / (1 + np.exp(-t))
    for b in range(B):
        counts = np.zeros(e, np.int64)
        for s in range(S):
            pr = np.asarray(probs[b, s])
            top = np.argsort(-pr)[:k]
            gates = pr[top] / pr[top].sum()
            for j, ei in enumerate(top):
                if counts[ei] >= cap:
                    continue  # dropped
                counts[ei] += 1
                xi = np.asarray(x[b, s], np.float32)
                g = silu(xi @ np.asarray(p["wg"][ei], np.float32))
                h = g * (xi @ np.asarray(p["wi"][ei], np.float32))
                out[b, s] += gates[j] * (h @ np.asarray(p["wo"][ei], np.float32))
    return out


def small_cfg(**kw):
    base = get_config("arctic-480b").reduced(
        num_experts=4, experts_per_token=2, d_model=16, moe_d_ff=32,
        capacity_factor=1.5, dense_residual=False)
    import dataclasses
    return dataclasses.replace(base, **kw) if kw else base


def test_moe_matches_loop_reference():
    cfg = small_cfg()
    p = init_params(moe_schema(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model))
    y, metrics = moe_block(p, cfg, x)
    expect = loop_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), expect, atol=1e-4, rtol=1e-3)
    assert 0.0 <= float(metrics["moe_dropped_frac"]) < 1.0


def test_no_drops_at_high_capacity():
    cfg = small_cfg(capacity_factor=8.0)
    p = init_params(moe_schema(cfg), jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    _, metrics = moe_block(p, cfg, x)
    assert float(metrics["moe_dropped_frac"]) == 0.0


def test_shared_experts_add_dense_path():
    import dataclasses
    cfg = dataclasses.replace(small_cfg(), num_shared_experts=2)
    p = init_params(moe_schema(cfg), jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, cfg.d_model))
    y_with, _ = moe_block(p, cfg, x)
    p0 = dict(p)
    p0["shared"] = jax.tree_util.tree_map(jnp.zeros_like, p["shared"])
    y_without, _ = moe_block(p0, cfg, x)
    assert float(jnp.abs(y_with - y_without).max()) > 1e-6


def test_aux_loss_balanced_vs_collapsed():
    """Load-balance loss must be ~1 for uniform routing, >1 for collapse."""
    cfg = small_cfg()
    p = init_params(moe_schema(cfg), jax.random.PRNGKey(6))
    # near-uniform random router (an all-zero router ties -> top_k picks
    # the first k experts deterministically, which is itself collapse)
    p_uni = dict(p)
    p_uni["router"] = jax.random.normal(jax.random.PRNGKey(0),
                                        p["router"].shape) * 0.01
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 64, cfg.d_model))
    _, m_uni = moe_block(p_uni, cfg, x)
    assert float(m_uni["moe_aux_loss"]) == pytest.approx(1.0, abs=0.15)
    # collapsed router: a linear router needs sign-definite inputs for a
    # constant argmax, so use positive x with one hot column
    p_col = dict(p)
    p_col["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(20.0)
    x_pos = jnp.abs(x) + 0.5
    _, m_col = moe_block(p_col, cfg, x_pos)
    # collapse onto expert 0 (+1 forced runner-up): aux -> ~E/k = 2
    assert float(m_col["moe_aux_loss"]) > 1.5


@pytest.mark.slow
def test_moe_is_differentiable():
    cfg = small_cfg()
    p = init_params(moe_schema(cfg), jax.random.PRNGKey(8))
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 8, cfg.d_model))
    g = jax.grad(lambda pp: jnp.sum(moe_block(pp, cfg, x)[0] ** 2))(p)
    norms = [float(jnp.abs(t).max()) for t in jax.tree_util.tree_leaves(g)]
    assert all(np.isfinite(norms)) and max(norms) > 0
