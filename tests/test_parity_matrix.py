"""Cross-backend parity matrix: ``kernel`` vs the pure-JAX oracles.

Every (vertical_policy × precision) combination must produce the same HR
output from the Pallas datapath (``backend="kernel"``, interpret mode on
CPU) as from the pure-JAX tilted sweep (``backend="tilted"``) and the
band-loop oracle (``core.fusion.run_banded``).

Documented tolerances (max abs diff on a [0, 1] HR output):

| precision | tolerance | source of the difference                         |
|-----------|-----------|--------------------------------------------------|
| fp32      | 5e-4      | 9-shifted-MXU-matmul accumulation order vs conv  |
| int8      | 5e-4      | same fp32 compute over dequantised weights       |
| bf16      | 5e-2      | bf16 feature maps on both sides; rounding points |
|           |           | inside the tile differ from the full-band conv   |

fp32/int8 differences are pure float-summation reordering (~1e-6 for the
ABPN stack); the 5e-4 bound is the documented contract, deliberately loose
enough to hold on any XLA CPU/TPU build.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core.fusion import ConvLayer, run_banded
from repro.kernels import ops
from repro.models.abpn import ABPNConfig, init_abpn

TOL = {"fp32": 5e-4, "int8": 5e-4, "bf16": 5e-2}

MATRIX = [(p, q) for p in engine.VERTICAL_POLICIES for q in engine.PRECISIONS]


def small_stack(key=0, scale=2):
    """A 3-layer stack sized for the anchor epilogue at the given scale."""
    co = 3 * scale * scale
    channels = [3, 12, 12, co]
    layers = []
    k = jax.random.PRNGKey(key)
    for i in range(len(channels) - 1):
        k1, k2, k = jax.random.split(k, 3)
        layers.append(ConvLayer(
            w=jax.random.normal(k1, (3, 3, channels[i], channels[i + 1])) * 0.2,
            b=jax.random.normal(k2, (channels[i + 1],)) * 0.1,
            relu=(i < len(channels) - 2),
        ))
    return layers


SMALL = small_stack()
SMALL_FRAMES = jax.random.uniform(jax.random.PRNGKey(1), (2, 40, 24, 3))


# ----------------------------------------------------------------------
# Engine-level matrix: kernel plan == tilted plan, full HR pipeline
# ----------------------------------------------------------------------
@pytest.mark.parametrize("policy,precision", MATRIX)
def test_kernel_matches_tilted_matrix(policy, precision):
    kwargs = dict(band_rows=20, tile_cols=4, scale=2,
                  vertical_policy=policy, precision=precision)
    pk = engine.make_plan(SMALL, SMALL_FRAMES.shape[1:], backend="kernel", **kwargs)
    pt = engine.make_plan(SMALL, SMALL_FRAMES.shape[1:], backend="tilted", **kwargs)
    hk = engine.run(pk, SMALL, SMALL_FRAMES)
    ht = engine.run(pt, SMALL, SMALL_FRAMES)
    assert hk.shape == ht.shape == (2, 80, 48, 3)
    np.testing.assert_allclose(np.asarray(hk, np.float32),
                               np.asarray(ht, np.float32),
                               atol=TOL[precision], rtol=0)


# ----------------------------------------------------------------------
# Ops-level matrix: kernel features == run_banded band-loop oracle
# ----------------------------------------------------------------------
@pytest.mark.parametrize("policy", engine.VERTICAL_POLICIES)
def test_kernel_features_match_run_banded(policy):
    img = SMALL_FRAMES[0]
    k = ops.tilted_fused_stack(img, SMALL, band_rows=20, tile_cols=4,
                               vertical_policy=policy)
    s = run_banded(img, SMALL, band_rows=20, tile_cols=4,
                   vertical_policy=policy)
    np.testing.assert_allclose(np.asarray(k), np.asarray(s),
                               atol=TOL["fp32"], rtol=0)


def test_kernel_halo_single_band_image():
    """Halo margins past both image edges (1-band frame) stay within tol."""
    frames = jax.random.uniform(jax.random.PRNGKey(4), (2, 20, 24, 3))
    plan = engine.make_plan(SMALL, frames.shape[1:], band_rows=20, tile_cols=4,
                            scale=2, vertical_policy="halo", backend="kernel")
    feats = engine.sr_features(plan, SMALL, frames)
    for i in range(2):
        ref = run_banded(frames[i], SMALL, band_rows=20, tile_cols=4,
                         vertical_policy="halo")
        np.testing.assert_allclose(np.asarray(feats[i]), np.asarray(ref),
                                   atol=TOL["fp32"], rtol=0)


# ----------------------------------------------------------------------
# Ragged-tail serving through the kernel backend
# ----------------------------------------------------------------------
def test_kernel_ragged_tail_stream_equals_unbatched():
    plan = engine.make_plan(SMALL, SMALL_FRAMES.shape[1:], band_rows=20,
                            tile_cols=4, scale=2, backend="kernel")
    stream = engine.VideoStream(plan, SMALL, batch_size=2)
    frames = jax.random.uniform(jax.random.PRNGKey(5), (3, 40, 24, 3))
    hr = stream.run(frames)  # 2 + 1(padded to 2), trimmed back to 3
    assert hr.shape == (3, 80, 48, 3)
    np.testing.assert_array_equal(
        np.asarray(hr), np.asarray(engine.run(plan, SMALL, frames)))


# ----------------------------------------------------------------------
# ABPN-sized matrix (the paper's 7-layer stack) — heavy, full-suite only
# ----------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("policy,precision", MATRIX)
def test_kernel_matches_tilted_matrix_abpn(policy, precision):
    cfg = ABPNConfig()
    layers = init_abpn(jax.random.PRNGKey(2), cfg)
    frames = jax.random.uniform(jax.random.PRNGKey(3), (2, 120, 64, 3))
    kwargs = dict(band_rows=60, scale=cfg.scale,
                  vertical_policy=policy, precision=precision)
    pk = engine.make_plan(layers, frames.shape[1:], backend="kernel", **kwargs)
    pt = engine.make_plan(layers, frames.shape[1:], backend="tilted", **kwargs)
    hk = engine.run(pk, layers, frames)
    ht = engine.run(pt, layers, frames)
    np.testing.assert_allclose(np.asarray(hk, np.float32),
                               np.asarray(ht, np.float32),
                               atol=TOL[precision], rtol=0)
