"""Per-arch smoke tests (assignment requirement): every architecture at a
REDUCED config runs one forward/train step plus prefill+decode on CPU,
asserting output shapes and no NaNs.  Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import LM_ARCH_IDS, get_config
from repro.distributed.steps import init_train_state, make_train_step
from repro.layers.params import count_params, init_params
from repro.models.registry import get_model

B, S = 2, 64

# published sizes (billions) the full schemas must land near
EXPECTED_PARAMS_B = {
    "arctic-480b": (440, 500),
    "deepseek-v2-236b": (225, 245),
    "qwen3-14b": (13.5, 15.5),
    "qwen3-8b": (7.6, 8.6),
    "qwen2-0.5b": (0.4, 0.55),
    "qwen3-1.7b": (1.5, 2.0),
    "internvl2-1b": (0.4, 0.6),  # LM backbone only (stub ViT)
    "zamba2-2.7b": (2.1, 2.9),
    "seamless-m4t-large-v2": (1.2, 2.4),  # backbone only (stub frontend)
    "mamba2-130m": (0.1, 0.16),
}


def _batch(cfg, key=1):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks, "mask": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.frontend_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["src"] = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", LM_ARCH_IDS)
def test_full_schema_param_count(arch):
    cfg = get_config(arch)
    n = count_params(get_model(cfg).schema(cfg)) / 1e9
    lo, hi = EXPECTED_PARAMS_B[arch]
    assert lo <= n <= hi, f"{arch}: {n:.2f}B params out of [{lo}, {hi}]"


# the largest reduced configs still take ~5-10s each on CPU; PR CI runs the
# fast tier, the full-suite job on main covers every architecture
_HEAVY_ARCHS = {"arctic-480b", "deepseek-v2-236b", "zamba2-2.7b",
                "seamless-m4t-large-v2"}


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS else a
    for a in LM_ARCH_IDS
])
def test_smoke_forward_and_decode(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = init_params(model.schema(cfg), jax.random.PRNGKey(0),
                         cfg.weight_dtype)
    batch = _batch(cfg)
    loss, metrics = model.loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0

    extra = cfg.frontend_tokens if cfg.family == "vlm" else 0
    max_len = S + extra + 8
    if cfg.family == "encdec":
        cs = model.cache_schema(cfg, B, max_len, enc_len=S)
    else:
        cs = model.cache_schema(cfg, B, max_len)
    cache = init_params(cs, jax.random.PRNGKey(0))
    pf = {k: v for k, v in batch.items() if k not in ("targets", "mask")}
    logits, cache = model.prefill(params, cfg, pf, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = model.decode_step(params, cfg, tok, cache,
                                       jnp.int32(S + extra))
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", [
    "qwen2-0.5b",
    pytest.param("mamba2-130m", marks=pytest.mark.slow),
    pytest.param("zamba2-2.7b", marks=pytest.mark.slow),
])
def test_train_step_decreases_loss(arch):
    cfg = get_config(arch).reduced(remat="none")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=2, total_steps=30)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    losses = []
    batch = _batch(cfg)  # overfit one fixed batch
    for _ in range(12):
        state, metrics = step(state, batch)
        losses.append(float(metrics["total_loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_decode_matches_prefill_logits_lm():
    """prefill over S tokens then decode token S == forward over S+1."""
    cfg = get_config("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = init_params(model.schema(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0, cfg.vocab_size)
    logits_full, _, _ = model.forward(params, cfg, toks, mode="train")

    cache = init_params(model.cache_schema(cfg, B, S + 4), jax.random.PRNGKey(0))
    _, cache = model.prefill(params, cfg, {"tokens": toks[:, :S]}, cache)
    logits_dec, _ = model.decode_step(params, cfg, toks[:, S:S + 1], cache,
                                      jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, S]), atol=2e-4,
                               rtol=1e-3)
