"""Checkpointing, restart loop, straggler detection, data determinism."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import get_config
from repro.data.pipeline import Prefetcher
from repro.data.synthetic import lm_batch, sr_pair_batch
from repro.distributed.steps import init_train_state, make_train_step
from repro.runtime import checkpoint as ck
from repro.runtime.resilience import (
    FailureInjector,
    StragglerDetector,
    resilient_train_loop,
)


def tiny_state(key=0):
    return {
        "params": {"w": jax.random.normal(jax.random.PRNGKey(key), (4, 4)),
                   "b": jnp.zeros((4,))},
        "opt": {"step": jnp.int32(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    state = tiny_state()
    ck.save(str(tmp_path), 12, state, cfg="cfg-a")
    step, restored = ck.restore(str(tmp_path), state, cfg="cfg-a")
    assert step == 12
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_fingerprint_mismatch(tmp_path):
    state = tiny_state()
    ck.save(str(tmp_path), 1, state, cfg="cfg-a")
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), state, cfg="cfg-b")


def test_checkpoint_retention_and_latest(tmp_path):
    state = tiny_state()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, state, keep=2)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_000000004", "step_000000005"]
    assert ck.latest_step(str(tmp_path)) == 5


def test_checkpoint_async(tmp_path):
    state = tiny_state()
    ck.save(str(tmp_path), 9, state, blocking=False)
    ck.wait_pending()
    assert ck.latest_step(str(tmp_path)) == 9


def test_incomplete_checkpoint_ignored(tmp_path):
    state = tiny_state()
    ck.save(str(tmp_path), 3, state)
    # simulate a crash mid-write: tmp dir without manifest promotion
    os.makedirs(tmp_path / ".tmp_4")
    assert ck.latest_step(str(tmp_path)) == 3


@pytest.mark.slow
def test_resilient_loop_survives_injected_failures(tmp_path):
    cfg = get_config("qwen2-0.5b").reduced(
        num_layers=2, d_model=32, d_ff=64, vocab_size=128, remat="none")
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=30)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    batch_fn = lambda s: lm_batch(cfg, s, 2, 16)
    injector = FailureInjector(fail_at_steps={7, 13})
    seen = []
    state, report = resilient_train_loop(
        init_state=state, train_step=step_fn, batch_fn=batch_fn,
        total_steps=20, ckpt_dir=str(tmp_path), cfg=cfg, checkpoint_every=5,
        injector=injector, on_metrics=lambda s, m: seen.append(s),
    )
    assert report["restarts"] == 2
    assert report["finished_step"] == 20
    assert int(state["opt"]["step"]) >= 18  # optimizer advanced past restarts


def test_straggler_detector_flags_outlier():
    d = StragglerDetector(z_threshold=3.0, warmup=3)
    for i in range(20):
        d.update(i, 0.10 + 0.001 * (i % 3))
    assert not d.flagged
    assert d.update(20, 1.5)  # 15x the mean
    assert d.flagged and d.flagged[0][0] == 20


def test_straggler_detector_constant_warmup_then_spike():
    """Regression: perfectly constant step times left var == 0 forever,
    so the first real straggler scored z = 0 (the zero-variance guard)
    and sailed through unflagged.  With var seeded from the first
    nonzero delta and an infinite z on zero variance, a constant warmup
    followed by a spike must FLAG the spike."""
    d = StragglerDetector(z_threshold=3.0, warmup=3)
    for i in range(10):
        assert not d.update(i, 0.10)  # identical latencies: var stays 0
    assert d.update(10, 0.5)  # 5x spike after zero-variance warmup
    assert d.flagged and d.flagged[0][0] == 10
    # the outlier was NOT folded into the mean
    assert d.mean == pytest.approx(0.10)


def test_ema_mean_var_seeds_var_from_first_delta():
    from repro.runtime.resilience import EMAMeanVar

    e = EMAMeanVar(alpha=0.1)
    e.fold(0.10)
    assert e.mean == pytest.approx(0.10) and e.var == 0.0
    e.fold(0.12)  # first nonzero delta seeds var, not alpha-shrunk
    assert e.var == pytest.approx(0.02**2)
    assert e.std > 0
    # zero-variance + nonzero delta -> infinite z (always past threshold)
    e2 = EMAMeanVar()
    e2.fold(1.0)
    assert e2.zscore(1.0) == 0.0
    assert e2.zscore(2.0) == float("inf")


def test_lm_batches_deterministic_and_learnable():
    cfg = get_config("qwen2-0.5b").reduced()
    a = lm_batch(cfg, 5, 4, 32)
    b = lm_batch(cfg, 5, 4, 32)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = lm_batch(cfg, 6, 4, 32)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    # next-token structure: targets are the shifted stream
    np.testing.assert_array_equal(np.asarray(a["tokens"][:, 1:]),
                                  np.asarray(a["targets"][:, :-1]))


def test_sr_pairs_consistent():
    lr, hr = sr_pair_batch(3, 2, lr_shape=(12, 16), scale=3)
    assert lr.shape == (2, 12, 16, 3) and hr.shape == (2, 36, 48, 3)
    from repro.data.synthetic import downsample
    np.testing.assert_allclose(np.asarray(downsample(hr[0], 3)),
                               np.asarray(lr[0]), atol=1e-6)


def test_prefetcher_orders_and_closes():
    seen = []
    pf = Prefetcher(lambda s: {"x": s}, depth=2)
    for _ in range(5):
        step, batch = next(pf)
        seen.append((step, batch["x"]))
    pf.close()
    assert seen == [(i, i) for i in range(5)]
