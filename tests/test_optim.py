"""AdamW + schedule + clipping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.optim.adamw import adamw_update, global_norm, init_opt_state, lr_schedule


def test_adamw_converges_on_quadratic():
    tcfg = TrainConfig(learning_rate=0.05, warmup_steps=5, total_steps=200,
                       weight_decay=0.0)
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, tcfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_grad_clipping_caps_update():
    tcfg = TrainConfig(grad_clip=1.0, learning_rate=1.0, warmup_steps=0,
                       total_steps=10, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = init_opt_state(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(huge, opt, params, tcfg)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.int32(s), tcfg)) for s in range(101)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1e-3, rel=1e-6)
    assert lrs[100] == pytest.approx(1e-4, rel=0.01)  # decays to 10%
    assert all(b <= a * 1.2001 for a, b in zip(lrs[10:], lrs[11:]))


def test_bf16_moments_supported():
    tcfg = TrainConfig(optimizer_dtype="bfloat16", learning_rate=0.1,
                       warmup_steps=0)  # update must exceed bf16 ulp at 1.0
    params = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    opt = init_opt_state(params, jnp.bfloat16)
    g = {"w": jnp.full((8, 8), 0.1, jnp.bfloat16)}
    new_p, new_opt, _ = adamw_update(g, opt, params, tcfg)
    assert new_opt["m"]["w"].dtype == jnp.bfloat16
    assert new_p["w"].dtype == jnp.bfloat16
    assert float(jnp.abs(new_p["w"] - params["w"]).max()) > 0


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
