"""Partitioning rules, multi-device grad sync, elastic re-mesh, dry-run.

Multi-device tests run in subprocesses with XLA_FLAGS-forced device counts
so the main pytest process keeps its single CPU device.
"""

import jax
import numpy as np
import pytest

from repro.distributed import partitioning as pt


# ----------------------------------------------------------------------
# Rule resolution (single device; no mesh required for pure logic)
# ----------------------------------------------------------------------
class FakeMesh:
    def __init__(self, shape, names):
        import numpy as _np

        self.devices = _np.empty(shape, dtype=object)
        self.axis_names = names


def test_logical_to_spec_drops_missing_axes():
    mesh = FakeMesh((4, 2), ("data", "model"))
    spec = pt.logical_to_spec(("batch", None, "mlp"), mesh, pt.BASE_RULES)
    assert spec == jax.sharding.PartitionSpec("data", None, "model")


def test_shape_aware_divisibility():
    mesh = FakeMesh((4, 2), ("data", "model"))
    # 6 % 2 == 0 -> sharded; 3 % 2 != 0 -> replicated
    s1 = pt.shape_aware_spec(("mlp",), (6,), mesh, pt.BASE_RULES)
    s2 = pt.shape_aware_spec(("mlp",), (3,), mesh, pt.BASE_RULES)
    assert s1 == jax.sharding.PartitionSpec("model")
    assert s2 == jax.sharding.PartitionSpec(None)


def test_shape_aware_multi_axis_prefix():
    mesh = FakeMesh((2, 4, 2), ("pod", "data", "model"))
    # batch 2 divides pod(2) but not pod*data(8) -> keep only 'pod'
    spec = pt.shape_aware_spec(("batch",), (2,), mesh, pt.BASE_RULES)
    assert spec == jax.sharding.PartitionSpec("pod")


def test_mesh_axis_used_once():
    mesh = FakeMesh((4, 2), ("data", "model"))
    spec = pt.shape_aware_spec(("heads", "mlp"), (4, 4), mesh, pt.BASE_RULES)
    # both want 'model'; first wins, second replicates
    assert spec == jax.sharding.PartitionSpec("model", None)


def test_fsdp_rules_extend_embed():
    rules = pt.fsdp_rules()
    assert rules["embed"] == "data"
    assert pt.BASE_RULES["embed"] is None  # base untouched


def test_pshard_is_identity_off_mesh():
    x = jax.numpy.ones((4, 4))
    assert pt.pshard(x, "batch", "mlp") is x


# ----------------------------------------------------------------------
# SR serving rules (engine.sharding resolves frame batches through these)
# ----------------------------------------------------------------------
def test_sr_rules_is_a_copy():
    rules = pt.sr_rules()
    rules["sr_rows"] = "mangled"
    assert pt.sr_rules()["sr_rows"] == "bands"
    assert pt.SR_RULES["sr_rows"] == "bands"


def test_sr_rules_resolve_on_full_serving_mesh():
    mesh = FakeMesh((2, 4), ("replica", "bands"))
    spec = pt.logical_to_spec(("sr_batch", "sr_rows", "sr_cols", "sr_chan"),
                              mesh, pt.sr_rules())
    assert spec == jax.sharding.PartitionSpec("replica", "bands", None, None)


def test_sr_rules_drop_replica_on_band_submesh():
    # each replica's executor compiles over a 1-D bands mesh: the batch
    # axis must fall back to replication, rows stay band-sharded
    mesh = FakeMesh((4,), ("bands",))
    spec = pt.logical_to_spec(("sr_batch", "sr_rows", "sr_cols", "sr_chan"),
                              mesh, pt.sr_rules())
    assert spec == jax.sharding.PartitionSpec(None, "bands", None, None)


def test_sr_rules_shape_aware_row_divisibility():
    mesh = FakeMesh((4,), ("bands",))
    # 48 rows / 4 band shards -> sharded; 42 rows do not divide -> replicated
    ok = pt.shape_aware_spec(("sr_rows",), (48,), mesh, pt.sr_rules())
    bad = pt.shape_aware_spec(("sr_rows",), (42,), mesh, pt.sr_rules())
    assert ok == jax.sharding.PartitionSpec("bands")
    assert bad == jax.sharding.PartitionSpec(None)


# ----------------------------------------------------------------------
# Multi-device behaviour (subprocess)
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_int8_ef_grad_sync_converges(subproc):
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.grad_sync import make_dp_grad_fn, init_ef_state

        mesh = make_mesh((8,), ("data",))
        target = jnp.arange(16.0).reshape(4, 4)
        def loss_fn(params, batch):
            pred = batch @ params["w"]
            return jnp.mean((pred - batch @ target) ** 2)

        params = {"w": jnp.zeros((4, 4))}
        ef = init_ef_state(params)
        fn = jax.jit(make_dp_grad_fn(loss_fn, mesh, compression="int8_ef"))
        fn_raw = jax.jit(make_dp_grad_fn(loss_fn, mesh, compression="none"))
        losses = []
        for step in range(300):
            batch = jax.random.normal(jax.random.PRNGKey(step), (8, 4))
            loss, grads, ef = fn(params, batch, ef)
            params = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
            losses.append(float(loss))
        assert losses[-1] < 1e-3 * losses[0], losses[::50]
        # compressed and raw grads agree in direction far from convergence
        # (at the optimum both are ~0 and cosine is meaningless)
        params = {"w": jax.random.normal(jax.random.PRNGKey(5), (4, 4))}
        batch = jax.random.normal(jax.random.PRNGKey(999), (8, 4))
        _, gq, _ = fn(params, batch, init_ef_state(params))
        _, gr, _ = fn_raw(params, batch, init_ef_state(params))
        cos = (jnp.sum(gq["w"] * gr["w"]) /
               (jnp.linalg.norm(gq["w"]) * jnp.linalg.norm(gr["w"]) + 1e-9))
        assert float(cos) > 0.99, float(cos)
        print("OK")
    """)
    assert "OK" in out


def test_elastic_remesh_8_to_4(subproc):
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.partitioning import axis_rules, BASE_RULES
        from repro.runtime.resilience import elastic_remesh

        mesh8 = make_mesh((4, 2), ("data", "model"))
        mesh4 = make_mesh((2, 2), ("data", "model"))
        state = {"w": jnp.arange(32.0).reshape(8, 4), "b": jnp.ones((4,))}
        axes = {"w": ("batch", "mlp"), "b": ("mlp",)}
        with axis_rules(mesh8, BASE_RULES):
            from repro.distributed.partitioning import shape_aware_spec
            placed = elastic_remesh(state, axes, mesh8)
        moved = elastic_remesh(placed, axes, mesh4)
        assert moved["w"].sharding.mesh.devices.size == 4
        np.testing.assert_array_equal(np.asarray(moved["w"]),
                                      np.asarray(state["w"]))
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_reduced_cell(subproc):
    """End-to-end dry-run machinery on the real production mesh shape."""
    out = subproc("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"
        from repro.launch.dryrun_lib import run_cell
        rec = run_cell("qwen2-0.5b", "train_4k", multi_pod=False, reduced=True)
        assert rec["status"] == "ok", rec.get("error")
        assert rec["parsed"]["flops"] > 0
        assert rec["memory"]["peak_estimate_bytes"] > 0
        rec2 = run_cell("qwen3-14b", "long_500k", multi_pod=False, reduced=True)
        assert rec2["status"] == "skipped"  # full-attention skip policy
        print("OK")
    """, devices=256)
    assert "OK" in out


def test_trainstate_shardings_resolve_for_all_archs(subproc):
    """Every arch's full state/batch sharding trees build on the
    production mesh (no divisibility or rule errors)."""
    out = subproc("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"
        import jax
        from repro.configs import LM_ARCH_IDS, get_config
        from repro.distributed import partitioning as pt
        from repro.distributed.steps import (train_state_axes,
            train_state_shapes, cache_axes_and_shapes)
        from repro.launch.mesh import make_production_mesh
        from repro.config import TrainConfig

        mesh = make_production_mesh()
        for arch in LM_ARCH_IDS:
            cfg = get_config(arch)
            rules = pt.fsdp_rules() if cfg.fsdp else pt.BASE_RULES
            with pt.axis_rules(mesh, rules):
                sds = train_state_shapes(cfg, TrainConfig())
                sh = pt.make_shardings(train_state_axes(cfg), sds)
                n = len(jax.tree_util.tree_leaves(sh))
                assert n == len(jax.tree_util.tree_leaves(sds))
                c_axes, c_sds = cache_axes_and_shapes(cfg, 16, 1024)
                pt.make_shardings(c_axes, c_sds)
        print("OK")
    """, devices=256)
    assert "OK" in out
