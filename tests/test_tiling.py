"""Geometry of the tilted schedule (paper §II, Fig. 2)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.tiling import TileSchedule, make_schedule, phantom_mask


def test_paper_design_point():
    # 640-wide image, C=8, L=7 (the accelerator's numbers)
    s = make_schedule(640, 8, 7)
    s.check_invariants()
    assert s.num_tiles == 81  # 80 interior + 1 epilogue flush tile
    # tile 0 layer 0 consumes input cols [-1, 9) -> 2 from overlap (init)
    assert s.in_cols(0, 0) == (-1, 9)
    assert s.overlap_cols(0, 0) == (-1, 1)
    # right-readiness at the deepest layer
    assert s.out_cols(0, 6) == (-6, 2)


@settings(max_examples=60, deadline=None)
@given(
    width=st.integers(4, 300),
    tile_cols=st.integers(2, 32),
    num_layers=st.integers(1, 12),
)
def test_invariants_hold_everywhere(width, tile_cols, num_layers):
    make_schedule(width, tile_cols, num_layers).check_invariants()


@settings(max_examples=30, deadline=None)
@given(
    width=st.integers(4, 200),
    tile_cols=st.integers(2, 16),
    num_layers=st.integers(1, 9),
)
def test_fresh_input_stream_is_disjoint_and_covering(width, tile_cols, num_layers):
    """The HBM-facing property: fresh input reads never overlap (this is
    what turns halo reads into clean BlockSpec streaming)."""
    s = make_schedule(width, tile_cols, num_layers)
    seen = set()
    for k in range(s.num_tiles):
        a, b = s.fresh_input_cols(k)
        cols = set(range(a, b))
        assert not cols & seen
        seen |= cols
    # every real input column is either streamed or the k=0 overlap column 0
    assert set(range(1, width)) <= seen
    assert s.fresh_input_cols(0)[0] == 1  # col 0 arrives via the initial overlap


def test_phantom_mask():
    m = phantom_mask(-2, 6, 3)
    assert m.tolist() == [False, False, True, True, True, False]


def test_invalid_schedule_rejected():
    with pytest.raises(ValueError):
        TileSchedule(width=0, tile_cols=8, num_layers=7)
