"""Pipelined serving: prepared-weight hoisting, async-vs-sync parity,
dispatch/complete stats, ragged-tail staging, stack lifecycle.

The acceptance checks for the pipelined serving path:

* weight prep (``prepare_layers`` / the kernel's pack) no longer executes
  inside the per-batch jitted call (jaxpr + prepare-call-count tests);
* async (``pipeline_depth`` >= 2) output is BIT-EXACT against sync
  (``pipeline_depth=1``) for every backend and precision;
* dispatch latency is recorded separately from complete latency, and a
  synchronous caller sees identical values;
* ragged tails reuse one staging buffer and never trigger a shape-driven
  recompile;
* evicting a cache entry releases its reference on the device-resident
  ``PreparedStack`` (no weight leak).
"""

import gc
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_compat import given, settings, strategies as st

from repro import engine
from repro.engine import executor as executor_mod
from repro.models.abpn import ABPNConfig, init_abpn

CFG = ABPNConfig()
LAYERS = init_abpn(jax.random.PRNGKey(2), CFG)
CLIP = jax.random.uniform(jax.random.PRNGKey(11), (7, 12, 16, 3))
LR = (12, 16, 3)


def small_session(**kw):
    kw.setdefault("backend", "tilted")
    kw.setdefault("max_bucket", 2)  # 7-frame clip -> 4 chunks (ragged tail)
    return engine.SRSession(LAYERS, **kw)


# ----------------------------------------------------------------------
# Weight prep is hoisted out of the per-batch jitted call
# ----------------------------------------------------------------------
def test_weight_prep_absent_from_jitted_program():
    """The serving executor's traced program contains NO quantisation ops:
    the int8 round-trip (jnp.round/clip) runs once in prepare_stack, so the
    per-batch jaxpr is pure conv datapath.  Enforced through the SAME
    ``program_audit`` pass CI runs (not a bespoke token match); the legacy
    self-contained path keeps tracing the round-trip in — the control that
    the audit rule means something."""
    from repro.analysis import program_audit

    plan = engine.make_plan(LAYERS, LR, band_rows=12, backend="tilted",
                            precision="int8")
    stack = engine.prepare_stack(plan, LAYERS)
    arts = executor_mod.executor_artifacts(
        plan, stack, 2, compiled=False
    )
    assert program_audit.audit_jaxpr(arts["jaxpr"], precision="int8") == []
    dummy = jnp.zeros((2, *LR))
    legacy = str(jax.make_jaxpr(
        lambda l, f: executor_mod._execute(plan, l, f))(list(LAYERS), dummy))
    rules = [f.rule for f in
             program_audit.audit_jaxpr(legacy, precision="int8")]
    assert "quant_in_hot_path" in rules  # the round-trip used to trace in


def test_prepare_stack_runs_once_per_session_numerics(monkeypatch):
    """Serving many buckets and resolutions prepares the weight stack
    exactly once — preparation is keyed by (precision, backend), which a
    session fixes."""
    import repro.engine.session as session_mod

    calls = []
    real = session_mod.prepare_stack
    monkeypatch.setattr(
        session_mod, "prepare_stack",
        lambda plan, layers: (calls.append(plan.stack_key), real(plan, layers))[1],
    )
    session = engine.SRSession(LAYERS, backend="tilted", precision="int8")
    for n in (1, 2, 3):  # buckets 1, 2, 4
        session.upscale(CLIP[:n])
    session.upscale(jnp.ones((1, 24, 16, 3)))  # second resolution
    assert calls == [("int8", "tilted")]
    stacks = session.cache_stats()["stacks"]
    assert len(stacks) == 1 and stacks[0]["refs"] == 4
    assert stacks[0]["resident_bytes"] > 0 and stacks[0]["prepare_s"] >= 0


# ----------------------------------------------------------------------
# Async == sync, bit-exact, all backends x precisions
# ----------------------------------------------------------------------
@pytest.mark.parametrize("backend,precision", [
    ("reference", "fp32"),
    ("reference", "bf16"),
    ("reference", "int8"),
    ("tilted", "fp32"),
    ("tilted", "bf16"),
    ("tilted", "int8"),
    pytest.param("kernel", "fp32", marks=pytest.mark.slow),
    pytest.param("kernel", "bf16", marks=pytest.mark.slow),
    pytest.param("kernel", "int8", marks=pytest.mark.slow),
])
def test_async_vs_sync_bit_exact(backend, precision):
    """pipeline_depth >= 2 serves the SAME compiled program over the SAME
    prepared stack as depth 1 — outputs must be bit-identical.  Against the
    legacy trace-prep-into-the-call oracle, fp32/bf16 are also bit-exact;
    int8 tolerates fused-vs-eager dequant ULP differences."""
    clip = CLIP[:5] if backend == "kernel" else CLIP  # keep interpret fast
    sync = small_session(backend=backend, precision=precision,
                         pipeline_depth=1)
    deep = small_session(backend=backend, precision=precision,
                         pipeline_depth=3)
    out_sync = np.asarray(sync.upscale(clip))
    out_deep = np.asarray(deep.upscale(clip))
    np.testing.assert_array_equal(out_sync, out_deep)
    oracle = np.asarray(engine.run(sync.plan_for(LR), LAYERS, clip))
    if precision == "int8":
        np.testing.assert_allclose(out_sync, oracle, atol=2e-5, rtol=0)
    else:
        np.testing.assert_array_equal(out_sync, oracle)


@settings(max_examples=6, deadline=None)
@given(depth=st.integers(min_value=1, max_value=3),
       t=st.integers(min_value=1, max_value=6))
def test_pipeline_depth_property(depth, t):
    """Any depth serves any clip length identically to the unpipelined
    engine; depth=1 degenerates to blocking (at most ONE chunk in flight),
    and in-flight chunks never exceed the configured depth."""
    session = small_session(pipeline_depth=depth)
    out = session.upscale(CLIP[:t])
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(engine.run(session.plan_for(LR), LAYERS, CLIP[:t])))
    chunks = -(-t // 2)  # bucket capped at 2
    assert session.stats()["peak_inflight"] == min(depth, chunks)


def test_host_float64_canonicalized_to_one_program():
    """numpy's default float64 serves through the SAME compiled program as
    float32 (jax canonicalizes without x64): one cache entry, labeled with
    the dtype actually served, and a later float32 request is a pure hit."""
    session = small_session()
    out64 = session.upscale(np.asarray(CLIP, np.float64)[:2])
    out32 = session.upscale(np.asarray(CLIP, np.float32)[:2])
    s = session.cache_stats()
    assert s["misses"] == 1 and s["hits"] == 1 and s["size"] == 1
    assert s["entries"][0]["dtype"] == "float32"
    np.testing.assert_array_equal(np.asarray(out64), np.asarray(out32))


def test_host_numpy_clip_staged_chunkwise():
    """numpy input stays host-resident and is device_put chunk by chunk;
    the result matches device-array input exactly."""
    session_np = small_session()
    session_jax = small_session()
    out_np = session_np.upscale(np.asarray(CLIP))
    out_jax = session_jax.upscale(CLIP)
    np.testing.assert_array_equal(np.asarray(out_np), np.asarray(out_jax))
    assert session_np.stats()["frames"] == 7


# ----------------------------------------------------------------------
# Dispatch vs complete latency
# ----------------------------------------------------------------------
def test_sync_caller_sees_identical_dispatch_and_complete():
    session = small_session()
    plan = session.plan_for(LR)
    session.serve_batch(plan, jnp.ones((2, *LR)))
    session.serve_batch(plan, jnp.ones((2, *LR)))
    assert session._dispatch_ms == session._complete_ms
    s = session.stats()
    assert s["dispatch_mean_ms"] == s["mean_ms"]
    assert s["dispatch_p50_ms"] == s["p50_ms"]
    assert s["batches"] == 2 and s["peak_inflight"] == 1


def test_pipelined_complete_never_precedes_dispatch():
    """Per chunk, complete (dispatch -> ready) >= dispatch (enqueue only):
    both are measured from the same dispatch start."""
    session = small_session(pipeline_depth=2)
    session.upscale(CLIP)  # 4 chunks
    d = np.asarray(session._dispatch_ms)
    c = np.asarray(session._complete_ms)
    assert d.shape == c.shape == (4,)
    assert (c >= d).all()
    s = session.stats()
    assert s["peak_inflight"] == 2
    assert s["p99_ms"] >= s["p95_ms"] >= s["p50_ms"]
    assert s["frames"] == 7 and s["fps"] > 0


def test_latency_stats_p99_total_span_and_empty():
    from repro.engine.session import latency_stats

    empty = latency_stats([], 0)
    assert empty["fps"] == 0.0 and empty["p99_ms"] == 0.0
    assert empty["dispatch_mean_ms"] == 0.0
    s = latency_stats([1.0, 2.0, 3.0, 100.0], 4,
                      dispatch_ms=[0.1, 0.1, 0.1, 0.1], total_s=0.05)
    assert s["p99_ms"] >= s["p95_ms"] >= s["p50_ms"] > 0
    assert s["fps"] == pytest.approx(4 / 0.05)  # span-based, not sum-based
    assert s["dispatch_mean_ms"] == pytest.approx(0.1)
    # degenerate span (clock too coarse) stays finite
    z = latency_stats([0.0], 2, total_s=0.0)
    assert z["fps"] == 0.0 and np.isfinite(z["fps"])


# ----------------------------------------------------------------------
# Ragged tails: one staging buffer, no shape-driven recompile
# ----------------------------------------------------------------------
def test_ragged_tails_never_recompile():
    """Clips of 7, 5 and 2 frames through a bucket-4 session: every chunk
    (ragged or not) hits the ONE compiled program — one cache miss, one
    trace on the executor's own jit."""
    session = engine.SRSession(LAYERS, backend="tilted", max_bucket=4)
    session.upscale(CLIP)  # compiles the one bucket-4 program
    entry = session._cache.entries()[0]
    assert entry.jitted is not None
    traced = entry.jitted._cache_size() if hasattr(
        entry.jitted, "_cache_size") else None
    for t in (5, 6):  # tails of 1 and 2 — same bucket, same program
        out = session.upscale(CLIP[:t])
        assert out.shape == (t, 36, 48, 3)
    s = session.cache_stats()
    assert s["misses"] == 1 and s["size"] == 1
    if traced is not None:  # no shape-driven retrace across ragged tails
        assert entry.jitted._cache_size() == traced
    # the tail staging buffer is reused, not reallocated per ragged tail
    np_session = engine.SRSession(LAYERS, backend="tilted", max_bucket=4)
    np_session.upscale(np.asarray(CLIP))  # tail 3 -> staging buffer built
    key, buf = np_session._staging
    np_session.upscale(np.asarray(CLIP[:5]))  # tail 1 -> SAME buffer
    assert np_session._staging[1] is buf
    np.testing.assert_array_equal(
        np.asarray(np_session.upscale(np.asarray(CLIP))),
        np.asarray(engine.run(np_session.plan_for(LR), LAYERS, CLIP)))


def test_padding_does_not_leak_into_real_frames():
    """Padded tail frames never contaminate real outputs (device path uses
    one fused jnp.pad, host path a zeroed staging buffer)."""
    session = small_session()
    out = session.upscale(CLIP[:3])  # chunks: 2 + 1(padded)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(engine.run(session.plan_for(LR), LAYERS, CLIP[:3])))


# ----------------------------------------------------------------------
# PreparedStack lifecycle: refcounts, eviction, clear
# ----------------------------------------------------------------------
def test_eviction_releases_stack_reference():
    """Evicting a cache entry releases its reference on the shared
    PreparedStack — refs always equal the number of LIVE entries, so
    churning resolutions through a small cache cannot leak weight
    buffers."""
    session = engine.SRSession(LAYERS, backend="tilted", precision="int8",
                               cache_capacity=1)
    session.upscale(jnp.ones((1, *LR)))
    assert session.cache_stats()["stacks"][0]["refs"] == 1
    session.upscale(jnp.ones((1, 24, 16, 3)))  # evicts the (12,16) entry
    s = session.cache_stats()
    assert s["evictions"] == 1 and s["size"] == 1
    assert s["stacks"][0]["refs"] == 1  # released on evict, not 2
    assert session._stacks[("int8", "tilted")].refs == 1


def test_clear_cache_frees_device_resident_weights():
    """clear_cache evicts every executor AND drops the prepared weight
    buffers (live-array count falls); the next request re-prepares and
    serves correctly."""
    session = engine.SRSession(LAYERS, backend="tilted", precision="int8")
    out = session.upscale(jnp.ones((2, *LR)))
    del out
    gc.collect()
    live_before = len(jax.live_arrays())
    assert len(session._stacks) == 1
    session.clear_cache()
    gc.collect()
    assert session._stacks == {}
    assert len(jax.live_arrays()) < live_before  # prepared weights freed
    assert session.cache_stats()["size"] == 0
    out = session.upscale(jnp.ones((2, *LR)))  # re-prepares + recompiles
    assert out.shape == (2, 36, 48, 3)


# ----------------------------------------------------------------------
# Donation
# ----------------------------------------------------------------------
def test_donating_executor_matches_non_donating():
    """donate_frames compiles with the batch donated; on CPU XLA ignores
    donation (with a warning) but the program must stay correct."""
    plan = engine.make_plan(LAYERS, LR, band_rows=12, backend="tilted")
    stack = engine.prepare_stack(plan, LAYERS)
    frames = jax.random.uniform(jax.random.PRNGKey(12), (2, *LR))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # cpu: "donated buffers not usable"
        fn = engine.build_stack_executor(plan, stack, donate_frames=True)
        out = np.asarray(fn(frames))
    assert fn.donates_frames
    np.testing.assert_array_equal(
        out, np.asarray(engine.run(plan, LAYERS, frames)))


def test_session_donation_gating_and_caller_safety():
    """donate_frames=None resolves per-backend (off on CPU); with donation
    forced on, upscale still never consumes the CALLER's array — only
    session-staged slabs are donated."""
    auto = engine.SRSession(LAYERS)
    assert auto._resolve_donate() == (jax.default_backend() != "cpu")
    assert engine.SRSession(LAYERS, donate_frames=True)._resolve_donate()
    forced = engine.SRSession(LAYERS, donate_frames=True, max_bucket=2)
    clip = jax.random.uniform(jax.random.PRNGKey(13), (2, *LR))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        first = np.asarray(forced.upscale(clip))   # exact-fit chunk is clip
        second = np.asarray(forced.upscale(clip))  # clip must still be live
    np.testing.assert_array_equal(first, second)
    assert forced.cache_stats()["entries"][0]["donates"] is True


# ----------------------------------------------------------------------
# Kernel backend: pre-packed weights (ops-level)
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_ops_pack_stack_matches_inline_packing():
    from repro.kernels import ops

    x = jax.random.uniform(jax.random.PRNGKey(14), (2, 12, 16, 3))
    inline = ops.tilted_fused_frames(x, LAYERS, band_rows=12)
    packed = ops.pack_stack(LAYERS, dtype=jnp.float32)
    pre = ops.tilted_fused_frames(x, band_rows=12, packed=packed,
                                  compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(inline), np.asarray(pre))
    with pytest.raises(ValueError, match="layers or packed"):
        ops.tilted_fused_frames(x, band_rows=12)


def test_video_stream_pins_blocking_depth():
    """The deprecated shim keeps legacy semantics: depth 1, no donation."""
    plan = engine.make_plan(LAYERS, (12, 16, 3), band_rows=12,
                            backend="tilted")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        stream = engine.VideoStream(plan, LAYERS, batch_size=2)
    assert stream.session.pipeline_depth == 1
    assert stream.session._resolve_donate() is False
    hr = stream.run(jax.random.uniform(jax.random.PRNGKey(15), (5, 12, 16, 3)))
    assert hr.shape == (5, 36, 48, 3)
    assert stream.session.stats()["peak_inflight"] == 1
