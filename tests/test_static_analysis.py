"""repro.analysis — the static-verification subsystem, end to end.

Three checker families, each proven BOTH ways:

* clean on the repo as it stands (the same sweeps CI gates on), and
* catching a deliberately-illegal fixture (the seeded red tests): a
  band-coverage gap, an insufficient halo margin, a past-budget kernel
  geometry, a quantise round-trip / host callback in a compiled program,
  a missing donation, a recompiled cache key, blocking-and-await under a
  held lock, and a lock-order cycle.

Plus the Table II cross-check: the Pallas kernel's buffer accounting
must match ``core.analysis.buffer_sizes`` exactly on logical elements
and stay within the documented padding tolerance on padded bytes.
"""

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.analysis import (
    PlanVerificationError,
    concurrency_lint,
    plan_check,
    program_audit,
    sweep,
)
from repro.analysis.findings import Finding, count_by_severity, errors
from repro.core import analysis as core_analysis
from repro.engine.plan import SRPlan
from repro.kernels.tilted_fusion import kernel_buffers, round_up_channels
from repro.models.abpn import ABPNConfig, init_abpn

LAYERS = init_abpn(jax.random.PRNGKey(2), ABPNConfig())
LR = (12, 16, 3)


def rules(findings):
    return [f.rule for f in findings]


# ----------------------------------------------------------------------
# Plan verifier: clean grid + seeded violations
# ----------------------------------------------------------------------
def test_design_point_plan_grid_is_clean():
    assert sweep.sweep_plans() == []


def test_plan_verify_method_clean():
    assert SRPlan(height=360, width=640).verify() == []


def test_band_coverage_violation_is_caught():
    """A height the bands do not partition exactly — constructible only by
    bypassing SRPlan validation, which is exactly what the checker must
    not rely on."""
    bad = dataclasses.replace(SRPlan(height=360, width=64))
    object.__setattr__(bad, "height", 100)  # 100 % 60 != 0
    findings = plan_check.verify_plan(bad)
    assert "band_coverage" in rules(errors(findings))


def test_halo_margin_measured_from_geometry():
    assert plan_check.measured_halo_margin(60, 7) == 7
    assert plan_check.required_halo_margin(7) == 7


def test_insufficient_halo_is_caught():
    plan = SRPlan(height=360, width=64, vertical_policy="halo")
    assert plan.verify() == []
    findings = plan.verify(halo_margin=plan.num_layers - 1)
    assert rules(errors(findings)) == ["halo_sufficiency"]


def test_budget_violation_past_design_point():
    """Doubling the band height blows the fixed Table II allocation: a
    hard error on the kernel backend (literal VMEM scratch), advisory on
    the pure-JAX tilted path."""
    kern = SRPlan(height=360, width=64, band_rows=120, backend="kernel")
    findings = kern.verify()
    assert rules(errors(findings)) == ["on_chip_budget"]
    tilted = SRPlan(height=360, width=64, band_rows=120, backend="tilted")
    findings = tilted.verify()
    assert errors(findings) == []
    assert "on_chip_budget" in rules(findings)  # warning-level


@pytest.mark.parametrize("band_rows", [12, 60])
def test_table2_crosscheck_exact_and_bounded(band_rows):
    """The kernel's logical element counts EQUAL the analytical model
    (independently coded, same equations); the padded allocation stays
    within the documented tolerance of the Table II budget."""
    x = plan_check.table2_crosscheck(band_rows=band_rows)
    assert x["kernel_overlap_kb"] == pytest.approx(x["model_overlap_kb"])
    assert x["kernel_residual_kb"] == pytest.approx(x["model_residual_kb"])
    assert x["kernel_weight_kb"] == pytest.approx(x["model_weight_kb"])
    if band_rows == 60:  # the design point is held to the paper budget
        assert x["table2_total_kb"] == pytest.approx(102.36)
        assert x["budget_ratio"] <= 1.0 + plan_check.BUDGET_TOLERANCE


def test_kernel_buffers_match_launch_scratch_shapes():
    """The introspection reports the SAME scratch shapes the pallas_call
    allocates (single source of truth)."""
    from repro.kernels.tilted_fusion import scratch_shapes

    rep = kernel_buffers(channels=core_analysis.ABPN_CHANNELS,
                         band_rows=60, tile_cols=8)
    overlap, residual = scratch_shapes(7, 60, 8, rep["chp"], rep["c0p"])
    assert rep["buffers"]["overlap"]["shape"] == overlap
    assert rep["buffers"]["residual"]["shape"] == residual
    assert rep["chp"] == round_up_channels(28) == 32
    assert rep["c0p"] == round_up_channels(3) == 8


def test_on_chip_budget_kb_exported():
    cfg = core_analysis.HWConfig()
    assert core_analysis.on_chip_budget_kb(cfg) == pytest.approx(
        core_analysis.buffer_sizes(cfg)["total_kb"]
    )
    assert "dram_reduction" in core_analysis.__all__


# ----------------------------------------------------------------------
# Degenerate plans: surfaced, counted, never fatal
# ----------------------------------------------------------------------
def test_degenerate_plans_counted_and_warned():
    session = engine.SRSession(LAYERS, autotune="off")
    with pytest.warns(RuntimeWarning, match="ONE 127-row band"):
        plan = session.plan_for((127, 16, 3))  # prime height: fallback
    assert plan.degenerate_bands
    assert session.tuning_stats()["degenerate_plans"] == 1
    findings = plan.verify()
    assert errors(findings) == []  # legal, just undesirable
    assert "degenerate_bands" in rules(findings)
    # a second shape with a fine decomposition does not count
    session.plan_for((120, 16, 3))
    assert session.tuning_stats()["degenerate_plans"] == 1


def test_strict_session_rejects_illegal_plan_before_compile():
    session = engine.SRSession(
        LAYERS, backend="kernel", band_rows=120, strict=True, autotune="off"
    )
    with pytest.raises(PlanVerificationError, match="on_chip_budget"):
        session.plan_for((360, 64, 3))
    assert session.cache_stats()["size"] == 0  # nothing compiled


def test_strict_session_serves_legal_plans():
    session = engine.SRSession(LAYERS, strict=True, autotune="off")
    hr = session.upscale(np.zeros(LR, np.float32))
    assert hr.shape == (36, 48, 3)


def test_open_accepts_strict():
    session = engine.SRSession.open("abpn_x3", strict=True, autotune="off")
    assert session.strict


# ----------------------------------------------------------------------
# Program audit: clean sessions + seeded violations
# ----------------------------------------------------------------------
def test_audit_clean_session():
    session = engine.SRSession(LAYERS, autotune="off")
    session.upscale(np.zeros(LR, np.float32))
    assert program_audit.audit_session(session) == []


def test_audit_catches_host_callback():
    """An executor compiled with a host callback — the seeded violation
    for the program pass — is flagged in BOTH the jaxpr and the HLO."""
    def cb(x):
        return x + jax.pure_callback(
            lambda a: np.asarray(a), jax.ShapeDtypeStruct(x.shape, x.dtype), x
        )

    x = jnp.zeros((4,))
    jaxpr = str(jax.make_jaxpr(cb)(x))
    assert "host_callback" in rules(program_audit.audit_jaxpr(jaxpr))
    hlo = jax.jit(cb).lower(x).compile().as_text()
    assert "host_callback" in rules(program_audit.audit_hlo(hlo))


def test_audit_catches_fp32_upcast():
    upcast = (
        "{ lambda ; a:bf16[2,12,16,3] b:f32[3,3,3,28].\n"
        "    c:f32[2,12,16,28] = conv_general_dilated[foo] a b\n"
        "    d:f32[2,12,16,28] = dot_general[bar] c c }"
    )
    found = program_audit.audit_jaxpr(upcast, precision="bf16")
    assert rules(found) == ["fp32_upcast"]
    # same program under an fp32/int8 plan: deliberate, no finding
    assert program_audit.audit_jaxpr(upcast, precision="fp32") == []
    assert program_audit.audit_jaxpr(upcast, precision="int8") == []


def test_real_bf16_program_has_no_upcast():
    session = engine.SRSession(LAYERS, precision="bf16", autotune="off")
    session.upscale(np.zeros(LR, np.float32))
    assert program_audit.audit_session(session) == []


def test_audit_catches_missing_donation():
    session = engine.SRSession(LAYERS, donate_frames=True, autotune="off")
    session.upscale(np.zeros(LR, np.float32))
    findings = program_audit.audit_session(session)
    if jax.default_backend() == "cpu":
        # donation honoured in the build; XLA:CPU ignoring it is an info
        assert errors(findings) == []
        assert "donation_ignored" in rules(findings)
    # break the entry: session wants donation, executor lost it
    for entry in session._cache.entries():
        entry.donates = False
        entry.fn.donates_frames = False
    assert "missing_donation" in rules(
        errors(program_audit.audit_session(session))
    )


def test_recompile_detection():
    session = engine.SRSession(LAYERS, cache_capacity=1, autotune="off")
    plan = session.plan_for(LR)
    session.serve_batch(plan, jnp.zeros((1, *LR)))
    session.serve_batch(plan, jnp.zeros((2, *LR)))  # evicts bucket 1
    session.serve_batch(plan, jnp.zeros((1, *LR)))  # re-miss: recompile
    assert session.cache_stats()["recompiles"] == 1
    findings = program_audit.audit_session(session)
    assert "recompile" in rules(findings)
    assert errors(findings) == []  # a warning, not a gate failure


# ----------------------------------------------------------------------
# Concurrency lint: clean engine sources + seeded snippets
# ----------------------------------------------------------------------
def test_engine_serving_sources_are_clean():
    assert concurrency_lint.lint_files() == []


def test_lint_default_targets_exist():
    targets = concurrency_lint.default_lint_targets()
    assert [p.name for p in targets] == [
        "server.py", "scheduler.py", "session.py", "band_diff.py",
        "delta_stream.py", "output_cache.py", "resilience.py"
    ]
    assert all(p.exists() for p in targets)


BLOCKING_SNIPPET = """
import threading, jax
class S:
    def __init__(self):
        self._lock = threading.Lock()
    def bad(self, hr):
        with self._lock:
            jax.block_until_ready(hr)
"""

AWAIT_SNIPPET = """
import threading
class S:
    def __init__(self):
        self._lock = threading.Lock()
    async def bad(self, fut):
        with self._lock:
            return await fut
"""

ASYNC_BLOCKING_SNIPPET = """
class S:
    async def bad(self, fut):
        return fut.result()
"""

CYCLE_SNIPPET = """
import threading
a_lock = threading.Lock()
b_lock = threading.Lock()
def one():
    with a_lock:
        with b_lock:
            pass
def two():
    with b_lock:
        with a_lock:
            pass
"""

WALL_CLOCK_SNIPPET = """
import time
class S:
    def expire(self, deadline):
        return time.time() >= deadline
"""

SAFE_SNIPPET = """
import threading, time
class S:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
    def ok(self):
        with self._cv:
            self._cv.wait()
            self._cv.notify_all()
    def also_ok(self, hr):
        import jax
        jax.block_until_ready(hr)  # off-lock: the sanctioned discipline
        with self._lock:
            self.done = True
    def deadline_ok(self, deadline):
        # the sanctioned clocks for deadline/latency math
        return time.monotonic() >= deadline or time.perf_counter() > 0
"""


@pytest.mark.parametrize("snippet,rule", [
    (BLOCKING_SNIPPET, "blocking_under_lock"),
    (AWAIT_SNIPPET, "await_under_lock"),
    (ASYNC_BLOCKING_SNIPPET, "blocking_in_async"),
    (CYCLE_SNIPPET, "lock_order_cycle"),
    (WALL_CLOCK_SNIPPET, "wall_clock"),
])
def test_lint_catches_seeded_violation(snippet, rule):
    findings = concurrency_lint.lint_source(snippet, "snippet.py")
    assert rule in rules(errors(findings))


def test_lint_safe_patterns_pass():
    assert concurrency_lint.lint_source(SAFE_SNIPPET, "safe.py") == []


def test_lock_order_consistent_is_clean():
    consistent = CYCLE_SNIPPET.replace(
        "with b_lock:\n        with a_lock:",
        "with a_lock:\n        with b_lock:",
    )
    findings = concurrency_lint.lint_source(consistent, "consistent.py")
    assert "lock_order_cycle" not in rules(findings)


# ----------------------------------------------------------------------
# Findings plumbing + CLI front door
# ----------------------------------------------------------------------
def test_finding_severity_validated():
    with pytest.raises(ValueError):
        Finding(checker="x", rule="y", severity="fatal", message="z")


def test_count_by_severity():
    fs = [
        Finding(checker="a", rule="r", severity="error", message="m"),
        Finding(checker="a", rule="r", severity="warning", message="m"),
        Finding(checker="a", rule="r", severity="warning", message="m"),
    ]
    assert count_by_severity(fs) == {"error": 1, "warning": 2, "info": 0}


def test_analysis_report_shape():
    report = sweep.analysis_report(programs=False)
    assert report["clean"] is True
    for checker in ("concurrency", "plan", "program"):
        assert set(report[checker]) == {"error", "warning", "info"}


def test_cli_lint_and_plans(subproc):
    out = subproc(
        "import sys\n"
        "from repro.analysis.__main__ import main\n"
        "sys.exit(main(['--lint', '--plans']))",
        devices=1,
    )
    assert "OK" in out


def test_cli_exits_nonzero_on_error_findings(tmp_path, monkeypatch):
    """Seed a lint violation into the CLI's target set: the gate must
    fail the build."""
    bad = tmp_path / "server.py"
    bad.write_text(BLOCKING_SNIPPET)
    monkeypatch.setattr(
        concurrency_lint, "default_lint_targets", lambda root=None: [bad]
    )
    from repro.analysis.__main__ import main

    assert main(["--lint"]) == 1
