"""Tilted layer fusion executor vs the plain conv stack (exactness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.fusion import (
    ConvLayer,
    conv_stack_reference,
    run_banded,
    tilted_fused_band,
)


def make_layers(key, channels, bias_scale=0.1):
    layers = []
    for i in range(len(channels) - 1):
        k1, k2, key = jax.random.split(key, 3)
        ci, co = channels[i], channels[i + 1]
        layers.append(
            ConvLayer(
                w=jax.random.normal(k1, (3, 3, ci, co)) * (2.0 / (9 * ci)) ** 0.5,
                b=jax.random.normal(k2, (co,)) * bias_scale,  # nonzero bias
                relu=(i < len(channels) - 2),                 # catches phantom leaks
            )
        )
    return layers


def test_single_band_bit_exact():
    """The paper's core claim: zero information loss left/right."""
    key = jax.random.PRNGKey(0)
    layers = make_layers(key, [3, 28, 28, 28, 28, 28, 28, 27])
    x = jax.random.uniform(jax.random.PRNGKey(1), (60, 64, 3))
    ref = conv_stack_reference(x, layers)
    til = tilted_fused_band(x, layers, tile_cols=8)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(til))


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(
    width=st.integers(5, 49),
    tile_cols=st.integers(2, 9),
    depth=st.integers(1, 5),
    ch=st.integers(1, 6),
    rows=st.integers(3, 12),
)
def test_band_exactness_property(width, tile_cols, depth, ch, rows):
    key = jax.random.PRNGKey(width * 131 + tile_cols)
    layers = make_layers(key, [2] + [ch] * depth)
    x = jax.random.uniform(jax.random.PRNGKey(3), (rows, width, 2))
    ref = conv_stack_reference(x, layers)
    til = tilted_fused_band(x, layers, tile_cols=tile_cols)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(til), atol=1e-5)


@pytest.mark.slow
def test_halo_policy_full_image_exact():
    key = jax.random.PRNGKey(5)
    layers = make_layers(key, [3, 8, 8, 5])
    img = jax.random.uniform(jax.random.PRNGKey(6), (90, 40, 3))
    ref = conv_stack_reference(img, layers)
    out = run_banded(img, layers, band_rows=30, tile_cols=4, vertical_policy="halo")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=1e-5)


def test_zero_policy_differs_only_at_band_boundaries():
    key = jax.random.PRNGKey(7)
    L = 4
    layers = make_layers(key, [3] + [6] * L)
    img = jax.random.uniform(jax.random.PRNGKey(8), (90, 40, 3))
    ref = np.asarray(conv_stack_reference(img, layers))
    out = np.asarray(
        run_banded(img, layers, band_rows=30, tile_cols=4, vertical_policy="zero")
    )
    diff = np.abs(ref - out).max(axis=(1, 2))
    # interior rows (further than L from any band boundary) must be exact
    for b0 in (0, 30, 60):
        interior = slice(b0 + L, b0 + 30 - L)
        assert diff[interior].max() == 0.0
    # and something must differ at the boundaries (otherwise no trade-off)
    assert diff.max() > 0


def test_replicate_policy_runs():
    key = jax.random.PRNGKey(9)
    layers = make_layers(key, [3, 4, 4])
    img = jax.random.uniform(jax.random.PRNGKey(10), (20, 16, 3))
    out = run_banded(img, layers, band_rows=10, tile_cols=4,
                     vertical_policy="replicate")
    assert out.shape == (20, 16, 4)
    assert np.isfinite(np.asarray(out)).all()


def test_tile_cols_must_cover_overlap():
    layers = make_layers(jax.random.PRNGKey(0), [3, 4])
    x = jnp.zeros((8, 16, 3))
    with pytest.raises(ValueError):
        tilted_fused_band(x, layers, tile_cols=1)
