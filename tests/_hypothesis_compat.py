"""Deterministic fallback for ``hypothesis`` when it is not installed.

The tier-1 suite must collect and run in a bare environment (jax + numpy +
pytest only).  When ``hypothesis`` is available the real package is used —
see the ``try/except ImportError`` at the top of each property-test module.
When it is not, this shim supplies the tiny subset the tests use
(``given``, ``settings``, ``strategies.integers/sampled_from/booleans``)
backed by a seeded PRNG, so the property tests still run as deterministic
multi-example smoke tests instead of being skipped wholesale.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

__all__ = ["given", "settings", "strategies"]

# Fallback sampling is a smoke pass, not a property search: cap the example
# count so interpret-mode Pallas properties stay fast in CI.
_MAX_FALLBACK_EXAMPLES = 8


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)


class strategies:
    """Namespace mirror of ``hypothesis.strategies`` (used as ``st``)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(options) -> _Strategy:
        options = list(options)
        return _Strategy(lambda rng: rng.choice(options))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)


def given(**strategy_kwargs):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_max_examples", _MAX_FALLBACK_EXAMPLES),
                    _MAX_FALLBACK_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strategy_kwargs.items()}
                fn(*args, **drawn, **kwargs)

        # Strip the strategy-drawn parameters from the visible signature so
        # pytest does not try to resolve them as fixtures.
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items()
                if name not in strategy_kwargs]
        del wrapper.__wrapped__  # stop inspect following back to fn
        wrapper.__signature__ = sig.replace(parameters=kept)
        wrapper.hypothesis_fallback = True
        return wrapper

    return decorate


def settings(max_examples: int = _MAX_FALLBACK_EXAMPLES, **_ignored):
    def decorate(fn):
        fn._max_examples = max_examples
        return fn

    return decorate
