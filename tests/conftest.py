import os
import subprocess
import sys
import textwrap

import jax
import pytest

# Tests run single-device by default; multi-device tests spawn subprocesses
# with XLA_FLAGS so the main process's jax device count stays untouched.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_py(code: str, devices: int = 8, timeout: int = 560,
           env_extra=None) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if env_extra:
        env.update(env_extra)
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


@pytest.fixture
def subproc():
    def _run(code, devices=8, timeout=560, env_extra=None):
        r = run_py(code, devices=devices, timeout=timeout, env_extra=env_extra)
        assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
        return r.stdout
    return _run


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _hermetic_tuning_db(tmp_path, monkeypatch):
    """Point the plan-tuning DB at a per-test temp path: sessions default
    to ``autotune="cached"``, so without this a developer's real
    ``~/.cache/repro-sr/tuning.json`` could steer schedules mid-test (and
    tests that tune would pollute it).  Tests that need a specific DB set
    the env var — or pass ``tuning_db=``/``tuner=`` — themselves."""
    monkeypatch.setenv("REPRO_SR_TUNING_DB", str(tmp_path / "tuning.json"))
