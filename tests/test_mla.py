"""MLA (deepseek-v2): decompressed train form vs absorbed decode form."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.layers.mla import init_mla_cache_spec, mla_block, mla_schema
from repro.layers.params import init_params


@pytest.mark.slow
def test_prefill_decode_matches_train_forward():
    """The absorbed decode path (attention in the 512-d latent space) must
    reproduce the decompressed path bit-for-bit (up to fp32 assoc)."""
    cfg = get_config("deepseek-v2-236b").reduced()
    assert cfg.attention == "mla"
    p = init_params(mla_schema(cfg), jax.random.PRNGKey(0))
    B, S = 2, 20
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model)) * 0.5
    positions = jnp.broadcast_to(jnp.arange(S + 1, dtype=jnp.int32), (B, S + 1))

    y_full, _ = mla_block(p, cfg, x, positions, mode="train")

    shape, dtype, _ = init_mla_cache_spec(cfg, B, S + 4)
    cache = jnp.zeros(shape, dtype)
    y_pre, cache = mla_block(p, cfg, x[:, :S], positions[:, :S], cache=cache,
                             cache_pos=jnp.int32(0), mode="prefill")
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :S]),
                               atol=3e-5, rtol=1e-3)

    y_dec, _ = mla_block(p, cfg, x[:, S:S + 1], positions[:, S:S + 1],
                         cache=cache, cache_pos=jnp.int32(S), mode="decode")
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, S]),
                               atol=3e-5, rtol=1e-3)


def test_cache_is_compressed():
    """The whole point of MLA: cache bytes/token = r_kv + rope_dim, not
    2 * heads * head_dim."""
    cfg = get_config("deepseek-v2-236b")
    shape, _, _ = init_mla_cache_spec(cfg, 1, 1)
    per_token = shape[-1]
    assert per_token == cfg.kv_lora_rank + cfg.rope_head_dim  # 576
    full_kv = 2 * cfg.num_heads * cfg.head_dim  # 32768
    assert per_token * 50 < full_kv  # >50x smaller


@pytest.mark.slow
def test_mla_grads_finite():
    cfg = get_config("deepseek-v2-236b").reduced()
    p = init_params(mla_schema(cfg), jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, cfg.d_model)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32), (1, 16))
    g = jax.grad(lambda pp: jnp.sum(mla_block(pp, cfg, x, pos)[0] ** 2))(p)
    assert all(np.isfinite(np.asarray(t)).all()
               for t in jax.tree_util.tree_leaves(g))
