"""SRServer front door: submit/future parity, cross-request micro-batching,
priority, backpressure, streaming, multi-model routing, input validation,
and PlanCache + PreparedStack refcounting under interleaved traffic.
All fast tier (tiny tilted shapes).
"""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.engine.scheduler import MicroBatchScheduler, QueueFullError
from repro.engine.server import SRFuture, SRServer
from repro.models.abpn import ABPNConfig, init_abpn

CFG = ABPNConfig()
LAYERS = init_abpn(jax.random.PRNGKey(2), CFG)
LR = (12, 16, 3)
CLIP = jax.random.uniform(jax.random.PRNGKey(21), (8, *LR))
ORACLE = None  # filled lazily (module import must stay cheap)


def oracle(frames):
    global ORACLE
    if ORACLE is None:
        plan = engine.make_plan(LAYERS, LR, band_rows=12, backend="tilted")
        ORACLE = np.asarray(engine.run(plan, LAYERS, CLIP))
    n = frames.shape[0]
    for i in range(CLIP.shape[0] - n + 1):
        if np.array_equal(np.asarray(frames), np.asarray(CLIP[i:i + n])):
            return ORACLE[i:i + n]
    raise AssertionError("frames are not a contiguous CLIP slice")


def make_session(**kw):
    kw.setdefault("backend", "tilted")
    return engine.SRSession(LAYERS, **kw)


def make_server(*, session_kw=None, **server_kw):
    session = make_session(**(session_kw or {}))
    return SRServer({"abpn": session}, **server_kw), session


# ----------------------------------------------------------------------
# Parity: submit == upscale == the unbatched engine oracle
# ----------------------------------------------------------------------
def test_submit_parity_with_upscale_and_oracle():
    server, session = make_server()
    hr = server.submit(CLIP[:3]).result()
    np.testing.assert_array_equal(np.asarray(hr), oracle(CLIP[:3]))
    # upscale IS submit().result() — bit-exact on a fresh same-weights session
    np.testing.assert_array_equal(
        np.asarray(make_session().upscale(CLIP[:3])), np.asarray(hr))
    # rank 3 and rank 5 round-trip through the future path
    single = server.submit(CLIP[0]).result()
    assert single.shape == (36, 48, 3)
    np.testing.assert_array_equal(np.asarray(single), oracle(CLIP[:1])[0])
    nested = server.submit(CLIP[:4].reshape(2, 2, *LR)).result()
    assert nested.shape == (2, 2, 36, 48, 3)
    np.testing.assert_array_equal(
        np.asarray(nested).reshape(4, 36, 48, 3), oracle(CLIP[:4]))


def test_submit_numpy_input_matches_device_input():
    server, _ = make_server(session_kw={"max_bucket": 4})
    out_np = server.submit(np.asarray(CLIP[:6])).result()
    np.testing.assert_array_equal(np.asarray(out_np), oracle(CLIP[:6]))


def test_upscale_uses_embedded_server_lazily():
    session = make_session()
    assert session._server is None
    out = session.upscale(CLIP[:2])
    assert session._server is not None
    np.testing.assert_array_equal(np.asarray(out), oracle(CLIP[:2]))
    assert session._server.scheduler_stats()["dispatches"] == 1
    assert session.stats()["frames"] == 2


# ----------------------------------------------------------------------
# Coalescing (the acceptance scenario)
# ----------------------------------------------------------------------
def test_two_half_bucket_requests_coalesce_into_one_full_dispatch():
    """Two concurrent same-plan requests of bucket/2 frames are served as
    ONE coalesced bucket-sized dispatch: 1 dispatch, fill ratio 1.0 —
    real frames fill the power-of-two bucket instead of padding."""
    bucket = 4
    server, session = make_server(session_kw={"max_bucket": bucket})
    f1 = server.submit(CLIP[:2])          # bucket/2 frames
    f2 = server.submit(CLIP[2:4])         # bucket/2 frames, same plan/dtype
    assert not f1.done() and not f2.done()  # queued, not yet dispatched
    r1 = f1.result()                      # drives the drain
    s = server.scheduler_stats()
    assert s["dispatches"] == 1
    assert s["coalesced_dispatches"] == 1
    assert s["mean_fill_ratio"] == 1.0
    assert s["frames_dispatched"] == 4 and s["padded_frames"] == 0
    assert f2.done()  # completed by the same dispatch
    np.testing.assert_array_equal(np.asarray(r1), oracle(CLIP[:2]))
    np.testing.assert_array_equal(np.asarray(f2.result()), oracle(CLIP[2:4]))
    d = s["recent_dispatches"][0]
    assert d["requests"] == 2 and d["bucket"] == bucket and d["fill"] == 1.0
    # the session compiled exactly one executor, for the full bucket
    assert [e["bucket"] for e in session.cache_stats()["entries"]] == [bucket]


def test_solo_request_pads_its_bucket():
    """The contrast case: a lone 3-frame request pads a 4-bucket (fill
    0.75) — the padding coalescing exists to eliminate."""
    server, _ = make_server()
    server.submit(CLIP[:3]).result()
    s = server.scheduler_stats()
    assert s["dispatches"] == 1 and s["coalesced_dispatches"] == 0
    assert s["mean_fill_ratio"] == pytest.approx(0.75)
    assert s["padded_frames"] == 1


def test_odd_requests_fill_one_bucket_with_real_frames():
    """1+3 concurrent frames -> one full 4-bucket: zero padding, where
    solo serving would have dispatched twice with a padded bucket."""
    server, _ = make_server(session_kw={"max_bucket": 4})
    f1 = server.submit(CLIP[0])           # 1 frame (rank 3)
    f2 = server.submit(CLIP[1:4])         # 3 frames
    server.flush()
    s = server.scheduler_stats()
    assert s["dispatches"] == 1 and s["mean_fill_ratio"] == 1.0
    np.testing.assert_array_equal(np.asarray(f1.result()), oracle(CLIP[:1])[0])
    np.testing.assert_array_equal(np.asarray(f2.result()), oracle(CLIP[1:4]))


def test_large_request_carries_its_bucket_and_tail_coalesces():
    """A request bigger than the max bucket spans dispatches at ONE pinned
    bucket (no tail-driven second compile), and a later request's frames
    top up the tail dispatch."""
    server, session = make_server(session_kw={"max_bucket": 4})
    f1 = server.submit(CLIP[:5])          # 4 + 1-frame tail
    f2 = server.submit(CLIP[5:8])         # 3 frames join the tail dispatch
    server.flush()
    s = server.scheduler_stats()
    assert s["dispatches"] == 2 and s["mean_fill_ratio"] == 1.0
    assert [e["bucket"] for e in session.cache_stats()["entries"]] == [4]
    np.testing.assert_array_equal(np.asarray(f1.result()), oracle(CLIP[:5]))
    np.testing.assert_array_equal(np.asarray(f2.result()), oracle(CLIP[5:8]))


def test_priority_picks_the_next_dispatch():
    """Across coalescing keys, the highest-priority pending request's key
    dispatches first (FIFO within a priority level)."""
    session = make_session()
    server = SRServer({"abpn": session})
    server.submit(jnp.ones((1, *LR)), priority=0)
    server.submit(jnp.ones((1, 24, 16, 3)), priority=5)  # other key
    server.flush()
    log = server.scheduler_stats()["recent_dispatches"]
    assert [d["lr_shape"] for d in log] == [[24, 16, 3], [12, 16, 3]]
    assert log[0]["priority"] == 5


# ----------------------------------------------------------------------
# Backpressure
# ----------------------------------------------------------------------
def test_backpressure_reject_policy():
    server, _ = make_server(max_inflight_frames=2, admission="reject")
    f1 = server.submit(CLIP[:2])
    with pytest.raises(QueueFullError, match="queue full"):
        server.submit(CLIP[2:3])
    assert server.scheduler_stats()["rejected"] == 1
    f1.result()  # drains the queue — space again
    np.testing.assert_array_equal(
        np.asarray(server.submit(CLIP[2:3]).result()), oracle(CLIP[2:3]))
    with pytest.raises(ValueError, match="can never fit"):
        server.submit(CLIP[:3])  # larger than the bound itself


def test_backpressure_block_policy_drains_to_admit():
    server, _ = make_server(max_inflight_frames=2, admission="block")
    futs = [server.submit(CLIP[i:i + 2]) for i in range(0, 8, 2)]
    server.flush()
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(
            np.asarray(f.result()), oracle(CLIP[2 * i:2 * i + 2]))
    s = server.scheduler_stats()
    assert s["rejected"] == 0 and s["pending_frames"] == 0
    assert s["peak_pending_frames"] <= 2


# ----------------------------------------------------------------------
# Multi-model routing
# ----------------------------------------------------------------------
def test_multi_model_routing_never_coalesces_across_models():
    sa, sb = make_session(), make_session(precision="int8")
    server = SRServer({"a": sa, "b": sb})
    fa = server.submit(CLIP[:2], model="a")
    fb = server.submit(CLIP[2:4], model="b")
    server.flush()
    s = server.scheduler_stats()
    assert s["dispatches"] == 2 and s["coalesced_dispatches"] == 0
    np.testing.assert_array_equal(np.asarray(fa.result()), oracle(CLIP[:2]))
    assert fb.result().shape == (2, 36, 48, 3)
    assert sa.stats()["frames"] == 2 and sb.stats()["frames"] == 2
    with pytest.raises(ValueError, match="unknown model"):
        server.submit(CLIP[:1], model="c")
    assert server.models == ("a", "b") and server.session("b") is sb
    # default model is the first hosted session
    assert server.session() is sa


def test_server_open_resolves_registry():
    server = SRServer.open("abpn_x3", backend="tilted", seed=3)
    assert server.models == ("abpn_x3",)
    out = server.submit(jnp.ones((1, *LR))).result()
    assert out.shape == (1, 36, 48, 3)
    with pytest.raises(ValueError, match="unknown SR model"):
        SRServer.open("espcn_x4")


# ----------------------------------------------------------------------
# Streaming
# ----------------------------------------------------------------------
def test_stream_yields_in_order_and_coalesces_lookahead():
    server, _ = make_server(session_kw={"max_bucket": 4})

    async def run():
        outs = []
        async for hr in server.stream(list(CLIP[:4]), lookahead=4):
            outs.append(np.asarray(hr))
        return outs

    outs = asyncio.run(run())
    assert len(outs) == 4
    np.testing.assert_array_equal(np.stack(outs), oracle(CLIP[:4]))
    s = server.scheduler_stats()
    # the lookahead window coalesced the four single frames into one bucket
    assert s["dispatches"] == 1 and s["mean_fill_ratio"] == 1.0


def test_two_concurrent_streams_share_the_server():
    server, _ = make_server(session_kw={"max_bucket": 4})

    async def one(clip):
        outs = []
        async for hr in server.stream(list(clip), lookahead=2):
            outs.append(np.asarray(hr))
        return outs

    async def both():
        return await asyncio.gather(one(CLIP[:3]), one(CLIP[3:6]))

    a, b = asyncio.run(both())
    np.testing.assert_array_equal(np.stack(a), oracle(CLIP[:3]))
    np.testing.assert_array_equal(np.stack(b), oracle(CLIP[3:6]))
    assert server.scheduler_stats()["frames_dispatched"] == 6


# ----------------------------------------------------------------------
# SRFuture API + failure propagation
# ----------------------------------------------------------------------
def test_future_api_done_callback_and_repeat_result():
    server, _ = make_server()
    fired = []
    fut = server.submit(CLIP[:1])
    fut.add_done_callback(lambda f: fired.append(f.done()))
    out = fut.result()
    assert fired == [True] and fut.done() and fut.exception() is None
    np.testing.assert_array_equal(np.asarray(fut.result()), np.asarray(out))
    # a callback added after completion fires immediately
    fut.add_done_callback(lambda f: fired.append("late"))
    assert fired == [True, "late"]


def test_done_callback_may_submit_follow_up_work():
    """Callbacks run OUTSIDE the server lock: chaining the next request
    from a done-callback (the natural use of the API) must not deadlock
    the draining thread."""
    server, _ = make_server()
    chained = []
    fut = server.submit(CLIP[:1])
    fut.add_done_callback(
        lambda f: chained.append(server.submit(CLIP[1:2])))
    out = fut.result()
    np.testing.assert_array_equal(np.asarray(out), oracle(CLIP[:1]))
    assert len(chained) == 1
    np.testing.assert_array_equal(
        np.asarray(chained[0].result()), oracle(CLIP[1:2]))


def test_dispatch_failure_sets_future_exception(monkeypatch):
    server, session = make_server()
    ok = server.submit(CLIP[:1]).result()  # compile the happy path first

    def boom(plan, bucket, dtype):
        raise RuntimeError("executor exploded")

    monkeypatch.setattr(session, "executor_for", boom)
    fut = server.submit(CLIP[1:3])
    with pytest.raises(RuntimeError, match="executor exploded"):
        fut.result()
    assert isinstance(fut.exception(), RuntimeError)
    assert server.scheduler_stats()["pending_frames"] == 0  # remainder dropped
    monkeypatch.undo()
    # the server keeps serving after a failed dispatch
    np.testing.assert_array_equal(
        np.asarray(server.submit(CLIP[:1]).result()), np.asarray(ok))


def test_empty_request_resolves_immediately():
    server, _ = make_server()
    fut = server.submit(jnp.zeros((0, *LR)))
    assert fut.done()
    assert fut.result().shape == (0, 36, 48, 3)
    s = server.scheduler_stats()
    assert s["dispatches"] == 0 and s["submitted_requests"] == 1


def test_closed_server_rejects_submits():
    server, _ = make_server()
    fut = server.submit(CLIP[:1])
    with server:
        pass  # __exit__ flushes + closes
    assert fut.done()
    with pytest.raises(RuntimeError, match="closed"):
        server.submit(CLIP[:1])


# ----------------------------------------------------------------------
# Input validation (satellite: clear errors at the front door)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("bad", ["nope", None, object()])
def test_submit_rejects_non_array_input(bad):
    session = make_session()
    with pytest.raises(ValueError, match=r"\(\.\.\., H, W, C\)"):
        session.submit(bad)
    with pytest.raises(ValueError, match=r"\(\.\.\., H, W, C\)"):
        session.upscale(bad)


def test_submit_rejects_wrong_channel_count_and_rank():
    session = make_session()
    with pytest.raises(ValueError, match="channels.*expects C=3"):
        session.upscale(jnp.ones((2, 12, 16, 4)))
    with pytest.raises(ValueError, match=r"\(H, W, C\)"):
        session.upscale(jnp.ones((12, 16)))  # rank 2
    with pytest.raises(ValueError, match=r"\(H, W, C\)"):
        session.upscale(jnp.ones((1, 1, 2, 12, 16, 3)))  # rank 6
    with pytest.raises(ValueError, match="numeric frames"):
        session.upscale(np.array([["a", "b"]], dtype=object))
    # nested numeric lists still serve (converted on the host path)
    out = session.upscale(np.zeros((12, 16, 3)).tolist())
    assert out.shape == (36, 48, 3)


# ----------------------------------------------------------------------
# Constructor validation (satellite: fail at construction, clearly)
# ----------------------------------------------------------------------
def test_session_constructor_validation():
    with pytest.raises(ValueError, match="cache_capacity=0"):
        engine.SRSession(LAYERS, cache_capacity=0)
    with pytest.raises(ValueError, match="pipeline_depth=0"):
        engine.SRSession(LAYERS, pipeline_depth=0)
    with pytest.raises(ValueError, match="max_bucket=0"):
        engine.SRSession(LAYERS, max_bucket=0)


def test_server_constructor_validation():
    session = make_session()
    with pytest.raises(ValueError, match="at least one session"):
        SRServer({})
    with pytest.raises(ValueError, match="max_inflight_frames=0"):
        SRServer({"a": session}, max_inflight_frames=0)
    with pytest.raises(ValueError, match="admission"):
        SRServer({"a": session}, admission="drop")
    with pytest.raises(ValueError, match="default_model"):
        SRServer({"a": session}, default_model="b")
    with pytest.raises(ValueError, match="must map to an SRSession"):
        SRServer({"a": object()})
    # a bare session is hosted under its model name
    named = SRServer(engine.SRSession.open("abpn_x3", layers=LAYERS))
    assert named.models == ("abpn_x3",)


# ----------------------------------------------------------------------
# PlanCache + PreparedStack refcounting under interleaved traffic
# (satellite: evictions hit live and dead stacks; no weight leak)
# ----------------------------------------------------------------------
def test_refcounting_under_interleaved_multi_model_traffic():
    """Two models alternating resolutions through capacity-1 caches: every
    miss evicts the other resolution's entry while its shared stack is
    still live; refs always equal live entries, and close() releases
    everything — no weight leak."""
    sa = make_session(precision="int8", cache_capacity=1)
    sb = make_session(precision="fp32", cache_capacity=1)
    server = SRServer({"a": sa, "b": sb})
    res = [(1, *LR), (1, 24, 16, 3)]
    for rep in range(2):
        for shape in res:
            for model in ("a", "b"):
                server.submit(jnp.ones(shape), model=model).result()
    for session, skey in ((sa, ("int8", "tilted")), (sb, ("fp32", "tilted"))):
        s = session.cache_stats()
        # 2 resolutions x 2 reps, capacity 1: every serve re-misses
        assert s["misses"] == 4 and s["hits"] == 0 and s["evictions"] == 3
        assert s["size"] == 1
        # the evictions hit a LIVE stack each time: the shared PreparedStack
        # survived (refcount moved 2 -> 1), never leaked a second copy
        assert len(session._stacks) == 1
        assert session._stacks[skey].refs == 1
        assert s["stacks"][0]["refs"] == 1
    sa.clear_cache()
    sb.clear_cache()
    assert sa._stacks == {} and sb._stacks == {}  # dead stacks dropped


def test_scheduler_counters_and_drop_bookkeeping():
    sched = MicroBatchScheduler()
    assert not sched.has_pending()
    s = sched.stats()
    assert s["dispatches"] == 0 and s["mean_fill_ratio"] == 0.0
    sched.note_rejected()
    assert sched.stats()["rejected"] == 1


def test_dropping_partial_request_releases_carry_bucket(monkeypatch):
    """A failed partially-served request must unpin its carry bucket:
    the next request on the key dispatches at its own natural bucket, not
    the dead request's."""
    server, session = make_server(session_kw={"max_bucket": 4})
    big = server.submit(CLIP[:6])  # 4 + 2-frame tail at carry bucket 4
    real_fn = session.executor_for
    calls = {"n": 0}

    def fail_second(plan, bucket, dtype):
        calls["n"] += 1
        if calls["n"] == 2:  # the tail dispatch
            raise RuntimeError("tail exploded")
        return real_fn(plan, bucket, dtype)

    monkeypatch.setattr(session, "executor_for", fail_second)
    with pytest.raises(RuntimeError, match="tail exploded"):
        big.result()
    monkeypatch.undo()
    fut = server.submit(CLIP[6:7])  # 1 frame — natural bucket 1, not 4
    np.testing.assert_array_equal(np.asarray(fut.result()), oracle(CLIP[6:7]))
    assert server.scheduler_stats()["recent_dispatches"][-1]["bucket"] == 1


def test_hosting_an_already_served_session_is_rejected():
    """A session that already has a front door (embedded or another host)
    cannot be hosted again — two schedulers/locks over one session's
    staging buffer and caches would race."""
    session = make_session()
    session.upscale(CLIP[:1])  # creates the embedded server
    with pytest.raises(ValueError, match="already served by another SRServer"):
        SRServer({"m": session})
    hosted = make_session()
    SRServer({"m": hosted})
    with pytest.raises(ValueError, match="already served by another SRServer"):
        SRServer({"again": hosted})
    # the same session under two names in ONE server is fine (aliasing)
    twin = make_session()
    server = SRServer({"x": twin, "y": twin})
    assert twin._server is server


def test_future_exception_returns_stored_timeout_error(monkeypatch):
    """A dispatch failure that IS a TimeoutError must be returned by
    exception(), not re-raised as if the wait timed out."""
    server, session = make_server()

    def slow(plan, bucket, dtype):
        raise TimeoutError("device timed out")

    monkeypatch.setattr(session, "executor_for", slow)
    fut = server.submit(CLIP[:1])
    exc = fut.exception()
    assert isinstance(exc, TimeoutError) and "device timed out" in str(exc)


def test_hosted_session_upscale_routes_through_hosting_server():
    """upscale/submit on a hosted session must use the HOSTING server (one
    scheduler, one lock over the session), not spawn a second embedded
    front door over the same mutable state."""
    sa, sb = make_session(), make_session()
    server = SRServer({"a": sa, "b": sb})
    assert sa._server is server and sb._server is server
    out = sb.upscale(CLIP[:2])
    np.testing.assert_array_equal(np.asarray(out), oracle(CLIP[:2]))
    s = server.scheduler_stats()
    assert s["submitted_requests"] == 1 and s["dispatches"] == 1
    assert s["recent_dispatches"][0]["model"] == "b"
    # a foreign session is rejected by identity-addressed submit
    with pytest.raises(ValueError, match="not hosted"):
        server.submit_for(make_session(), CLIP[:1])


def test_concurrent_submit_threads_coalesce_and_serve_correctly():
    """Many threads submitting + waiting concurrently: every result is
    bit-exact and the scheduler's frame accounting balances (the device
    wait releases the lock, so admission proceeds during drains)."""
    import threading

    server, _ = make_server(session_kw={"max_bucket": 8})
    results = {}

    def client(i):
        results[i] = np.asarray(server.submit(CLIP[i:i + 2]).result())

    threads = [threading.Thread(target=client, args=(i,)) for i in range(0, 6, 2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i in (0, 2, 4):
        np.testing.assert_array_equal(results[i], oracle(CLIP[i:i + 2]))
    s = server.scheduler_stats()
    assert s["frames_dispatched"] == 6 and s["pending_frames"] == 0
    assert s["inflight_dispatches"] == 0 and s["dispatches"] <= 3
