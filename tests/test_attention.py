"""Attention: flash vs direct softmax, custom VJP, GQA, decode == prefill."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.layers.attention import decode_attention, flash_attention
from repro.layers.rope import apply_rope


def direct(q, k, v, causal=True):
    D = q.shape[-1]
    s = jnp.einsum("bqkgd,bskd->bqkgs", q, k) / jnp.sqrt(D)
    if causal:
        S, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(S)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    return jnp.einsum("bqkgs,bskd->bqkgd", jax.nn.softmax(s, -1), v)


@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(
    sq=st.integers(3, 70),
    kh=st.integers(1, 3),
    g=st.integers(1, 4),
    d=st.sampled_from([8, 16]),
    chunk=st.sampled_from([8, 16, 64]),
    q_chunk=st.sampled_from([16, 24, 512]),
    causal=st.booleans(),
)
def test_flash_matches_direct(sq, kh, g, d, chunk, q_chunk, causal):
    ks = jax.random.split(jax.random.PRNGKey(sq * 7 + d), 3)
    q = jax.random.normal(ks[0], (2, sq, kh, g, d))
    k = jax.random.normal(ks[1], (2, sq, kh, d))
    v = jax.random.normal(ks[2], (2, sq, kh, d))
    out = flash_attention(q, k, v, causal=causal, chunk=chunk, q_chunk=q_chunk)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(direct(q, k, v, causal)), atol=2e-5, rtol=1e-4
    )


@pytest.mark.slow
def test_flash_vjp_matches_direct_grads():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 48, 2, 3, 16))
    k = jax.random.normal(ks[1], (2, 48, 2, 16))
    v = jax.random.normal(ks[2], (2, 48, 2, 20))  # Dv != Dqk (MLA case)
    f = lambda *a: jnp.sum(jnp.sin(flash_attention(*a, causal=True, chunk=16,
                                                   q_chunk=16)))
    r = lambda *a: jnp.sum(jnp.sin(direct(*a)))
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5,
                                   rtol=1e-3)


def test_decode_attention_matches_full_at_position():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, Kh, G, D = 2, 32, 2, 2, 8
    q_all = jax.random.normal(ks[0], (B, S, Kh, G, D))
    k = jax.random.normal(ks[1], (B, S, Kh, D))
    v = jax.random.normal(ks[2], (B, S, Kh, D))
    full = direct(q_all, k, v, causal=True)
    pos = 17
    # cache semantics: positions > pos are garbage and must be masked
    k_cache = k.at[:, pos + 1 :].set(99.0)
    v_cache = v.at[:, pos + 1 :].set(99.0)
    out = decode_attention(q_all[:, pos : pos + 1], k_cache, v_cache,
                           jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, pos]),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.slow
def test_prefill_then_decode_consistency_full_block():
    """attention_block: decode at position S must equal a train forward
    over S+1 tokens at its last position."""
    from repro.layers.attention import attention_block, init_kv_cache_spec
    from repro.layers.params import init_params
    from repro.layers.attention import gqa_schema

    cfg = get_config("qwen2-0.5b").reduced()
    p = init_params(gqa_schema(cfg), jax.random.PRNGKey(2))
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S + 1, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(S + 1, dtype=jnp.int32), (B, S + 1))
    y_full, _ = attention_block(p, cfg, x, positions, mode="train")

    shape, dtype, _ = init_kv_cache_spec(cfg, B, S + 4)
    cache = (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    y_pre, cache = attention_block(p, cfg, x[:, :S], positions[:, :S],
                                   cache=cache, cache_pos=jnp.int32(0),
                                   mode="prefill")
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :S]),
                               atol=2e-5, rtol=1e-4)
    y_dec, _ = attention_block(p, cfg, x[:, S : S + 1], positions[:, S : S + 1],
                               cache=cache, cache_pos=jnp.int32(S), mode="decode")
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, S]),
                               atol=2e-5, rtol=1e-4)


def test_rope_properties():
    B, S, H, D = 2, 16, 3, 8
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    y = apply_rope(x, pos, theta=1e4)
    # norm preservation per pair
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(5), (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(6), (1, 1, 1, D))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]), theta=1e4)
        kj = apply_rope(k, jnp.array([[j]]), theta=1e4)
        return float(jnp.sum(qi * kj))
    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), abs=1e-4)
    assert dot_at(5, 5) == pytest.approx(float(jnp.sum(q * k)), abs=1e-4)
